"""AOT pipeline tests: HLO text artifacts exist, parse structurally, and the
manifest agrees with the model's parameter spec. (Numeric round-trip through
PJRT is covered on the Rust side in ``rust/tests/``.)"""

import json
import os

import pytest

from compile import model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_families(manifest):
    for fam in model.FAMILIES:
        assert fam in manifest["families"], fam
        for kind in ("fwd", "train", "capture"):
            assert f"{kind}_{fam}" in manifest["artifacts"]


def test_artifact_files_exist_and_are_hlo(manifest):
    for name, art in manifest["artifacts"].items():
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), f"{name}: not HLO text"


def test_manifest_param_shapes_match_spec(manifest):
    for fam, cfg in model.FAMILIES.items():
        spec = model.param_spec(cfg)
        man = manifest["families"][fam]["params"]
        assert len(man) == len(spec)
        for (name, shape), entry in zip(spec, man):
            assert entry["name"] == name
            assert tuple(entry["shape"]) == tuple(shape)


def test_train_artifact_io_arity(manifest):
    for fam, cfg in model.FAMILIES.items():
        n = len(model.param_spec(cfg))
        art = manifest["artifacts"][f"train_{fam}"]
        assert len(art["inputs"]) == 3 * n + 2
        assert len(art["outputs"]) == 3 * n + 1


def test_capture_artifact_output_count(manifest):
    for fam, cfg in model.FAMILIES.items():
        art = manifest["artifacts"][f"capture_{fam}"]
        assert len(art["outputs"]) == 4 * cfg.n_layers


def test_fwd_logits_shape(manifest):
    b, s = manifest["batch"], manifest["seq"]
    for fam, cfg in model.FAMILIES.items():
        art = manifest["artifacts"][f"fwd_{fam}"]
        assert art["outputs"][0]["shape"] == [b, s, cfg.vocab]


def test_fused_artifact_has_qlr_inputs(manifest):
    art = manifest["artifacts"]["fwd_fused_tl-7s"]
    cfg = model.config("tl-7s")
    names = [i["name"] for i in art["inputs"]]
    for pname in model.projection_names(cfg):
        for suffix in (".Q", ".L", ".R"):
            assert pname + suffix in names
    r = manifest["fused_rank"]
    # L shapes carry the baked rank.
    l0 = next(i for i in art["inputs"] if i["name"] == "layer0.wq.L")
    assert l0["shape"] == [cfg.d_model, r]


def test_no_serialized_protos_in_artifacts(manifest):
    # Guard the image gotcha: interchange must be HLO *text*.
    for art in manifest["artifacts"].values():
        assert art["file"].endswith(".hlo.txt")
