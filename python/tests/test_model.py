"""Layer-2 model tests: shapes, invariances, training signal, capture
consistency, compressed-forward equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module", params=["tl-7s", "tl3-8s", "tg-2s"])
def family(request):
    cfg = model.config(request.param)
    params = model.init_params(cfg, seed=1)
    return cfg, params


def toks(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.array(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)


def test_param_spec_counts():
    cfg = model.config("tl-7s")
    spec = model.param_spec(cfg)
    assert len(spec) == 1 + 9 * cfg.n_layers + 2
    names = [n for n, _ in spec]
    assert len(set(names)) == len(names), "duplicate param names"
    # Projections subset of params.
    assert set(model.projection_names(cfg)) <= set(names)


def test_forward_shapes(family):
    cfg, params = family
    logits = model.forward(cfg, params, toks(cfg, 2, 16))
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_is_causal(family):
    # Changing a future token must not affect earlier logits.
    cfg, params = family
    t1 = toks(cfg, 1, 12, seed=3)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab)
    l1 = model.forward(cfg, params, t1)
    l2 = model.forward(cfg, params, t2)
    np.testing.assert_allclose(
        np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), atol=1e-4
    )


def test_initial_loss_near_uniform(family):
    cfg, params = family
    l = model.loss_fn(cfg, params, toks(cfg, 4, 33))
    assert abs(float(l) - np.log(cfg.vocab)) < 0.6


def test_train_step_decreases_loss():
    cfg = model.config("tl-7s")
    params = model.init_params(cfg, seed=2)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    batch = toks(cfg, 8, 65, seed=5)  # overfit one batch
    losses = []
    for step in range(20):
        params, m, v, loss = model.train_step(cfg, params, m, v,
                                              float(step), batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_capture_shapes_and_values(family):
    cfg, params = family
    t = toks(cfg, 2, 8)
    caps = model.capture_acts(cfg, params, t)
    assert len(caps) == 4 * cfg.n_layers
    samples = 2 * 8
    for i in range(cfg.n_layers):
        attn_in, attn_ctx, mlp_in, mlp_mid = caps[4 * i:4 * i + 4]
        assert attn_in.shape == (cfg.d_model, samples)
        assert attn_ctx.shape == (cfg.d_model, samples)
        assert mlp_in.shape == (cfg.d_model, samples)
        assert mlp_mid.shape == (cfg.d_ff, samples)
    # attn_in is RMSNorm output: per-sample RMS ≈ ln gain (init 1).
    rms = jnp.sqrt(jnp.mean(caps[0] ** 2, axis=0))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=0.2)


def test_capture_does_not_change_forward(family):
    cfg, params = family
    t = toks(cfg, 1, 8)
    l1 = model.forward(cfg, params, t)
    sink = []
    l2 = model.forward(cfg, params, t, capture=sink)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))


def test_compressed_forward_exact_with_lossless_qlr():
    """If Q = W and L,R = 0, the fused deploy forward must reproduce the
    dense forward exactly — the end-to-end composition check for the
    Pallas fused kernel inside the model."""
    cfg = model.config("tl-7s")
    params = model.init_params(cfg, seed=3)
    spec = dict(model.param_spec(cfg))
    r = 8
    qlr = []
    for pname in model.projection_names(cfg):
        out_d, in_d = spec[pname]
        w = params[[n for n, _ in model.param_spec(cfg)].index(pname)]
        qlr += [w, jnp.zeros((out_d, r)), jnp.zeros((r, in_d))]
    t = toks(cfg, 1, 8)
    dense = model.forward(cfg, params, t)
    fused = model.forward_compressed(cfg, params, qlr, t, r)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def test_compressed_forward_splits_q_and_lr():
    """Q + LR decomposition of each W must also be exact when Q = W − LR."""
    cfg = model.config("tl-7s")
    params = model.init_params(cfg, seed=4)
    names = [n for n, _ in model.param_spec(cfg)]
    r = 4
    key = jax.random.PRNGKey(0)
    qlr = []
    for pname in model.projection_names(cfg):
        w = params[names.index(pname)]
        out_d, in_d = w.shape
        key, k1, k2 = jax.random.split(key, 3)
        l = jax.random.normal(k1, (out_d, r)) * 0.05
        rr = jax.random.normal(k2, (r, in_d)) * 0.05
        qlr += [w - l @ rr, l, rr]
    t = toks(cfg, 1, 8)
    dense = model.forward(cfg, params, t)
    fused = model.forward_compressed(cfg, params, qlr, t, r)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                               rtol=2e-3, atol=2e-3)


def test_gqa_heads_divide():
    for name, cfg in model.FAMILIES.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.n_heads % cfg.n_kv_heads == 0, name
        assert cfg.head_dim % 2 == 0, f"{name}: RoPE needs even head dim"
