"""Layer-1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the CORE
correctness signal for the compute hot-spots that end up inside the HLO
artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_qlr import (
    dense_flops,
    fused_qlr_matmul,
    mxu_flops,
    vmem_bytes,
)
from compile.kernels.fwht import fwht_rows
from compile.kernels.quantize import quantize_block

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------- quantize

@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 96),
    groups=st.integers(1, 6),
    group=st.sampled_from([8, 16, 32]),
    bits=st.sampled_from([2, 3, 4, 8]),
    block_m=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(m, groups, group, bits, block_m, seed):
    n = groups * group
    w = rand(seed, m, n, scale=3.0)
    got = quantize_block(w, bits=bits, group=group, block_m=block_m)
    want = ref.quantize_block_ref(w, bits, group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_quantize_error_bound():
    w = rand(7, 64, 64, scale=2.0)
    q = quantize_block(w, bits=4, group=32)
    step = jnp.max(jnp.abs(w)) / 7.0  # worst-case group scale
    assert float(jnp.max(jnp.abs(w - q))) <= float(step) / 2 + 1e-6


def test_quantize_idempotent():
    w = rand(9, 16, 32)
    q1 = quantize_block(w, bits=4, group=16)
    q2 = quantize_block(q1, bits=4, group=16)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-5)


def test_quantize_preserves_zeros():
    w = jnp.zeros((8, 32))
    np.testing.assert_array_equal(np.asarray(quantize_block(w)), np.zeros((8, 32)))


def test_quantize_rejects_bad_group():
    with pytest.raises(AssertionError):
        quantize_block(rand(1, 4, 30), bits=4, group=32)


# ---------------------------------------------------------------- fused qlr

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 128),
    n=st.integers(1, 96),
    r=st.integers(1, 24),
    b=st.integers(1, 12),
    block_m=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_qlr_matches_ref(m, n, r, b, block_m, seed):
    q = rand(seed, m, n)
    l = rand(seed + 1, m, r)
    rr = rand(seed + 2, r, n)
    x = rand(seed + 3, n, b)
    got = fused_qlr_matmul(q, l, rr, x, block_m=block_m)
    want = ref.fused_qlr_ref(q, l, rr, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_qlr_zero_rank_path():
    # L=0 or R=0 ⇒ plain Q @ x.
    q = rand(1, 32, 16)
    x = rand(2, 16, 4)
    got = fused_qlr_matmul(q, jnp.zeros((32, 8)), jnp.zeros((8, 16)), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(q @ x), rtol=1e-5)


def test_fused_flops_advantage():
    # The fused path must be asymptotically cheaper than materializing LR.
    m = n = 4096
    r, b = 64, 16
    # Fused avoids the m·n·r materialization: with b ≪ r the advantage is
    # ≈ (r + b)/b ≈ 5× here, and grows as b shrinks.
    assert mxu_flops(m, n, r, b) < dense_flops(m, n, b, r) / 4
    assert mxu_flops(m, n, r, 1) < dense_flops(m, n, 1, r) / 30


def test_vmem_accounting_positive():
    assert vmem_bytes(64, 4096, 64, 16) > 0


# ---------------------------------------------------------------- fwht

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    logn=st.integers(0, 8),
    block_m=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_ref(m, logn, block_m, seed):
    n = 2 ** logn
    w = rand(seed, m, n)
    got = fwht_rows(w, block_m=block_m)
    want = ref.fwht_ref(w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_fwht_involutive():
    w = rand(11, 16, 64)
    back = fwht_rows(fwht_rows(w))
    np.testing.assert_allclose(np.asarray(back), np.asarray(w), atol=1e-4)


def test_fwht_preserves_norm():
    w = rand(12, 8, 128)
    t = fwht_rows(w)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(t)), float(jnp.linalg.norm(w)), rtol=1e-5
    )


def test_fwht_rejects_non_pow2():
    with pytest.raises(AssertionError):
        fwht_rows(rand(1, 4, 12))
