"""AOT compiler: lowers every Layer-2 entry point to HLO **text** artifacts
plus a JSON manifest the Rust runtime consumes.

HLO text — NOT ``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per model family F:
  fwd_F       (params…, tokens[B,S])            → logits[B,S,V]
  train_F     (params…, m…, v…, step, tokens)   → (params…, m…, v…, loss)
  capture_F   (params…, tokens[B,S])            → 4·n_layers activation mats

Plus the Layer-1 kernel demos (standalone, fixed shapes) and the fused
deploy forward for tl-7s (every projection as Q+LR through the Pallas
fused kernel).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.fused_qlr import fused_qlr_matmul
from .kernels.fwht import fwht_rows
from .kernels.quantize import quantize_block

FUSED_RANK = 32  # rank baked into the fused deploy artifact


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def spec_entry(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"artifacts": {}, "families": {}, "batch": model.BATCH,
                         "seq": model.SEQ, "fused_rank": FUSED_RANK}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, in_specs, in_names):
        """Lower fn(*in_specs) and write the artifact + manifest entry.

        ``keep_unused=True`` is load-bearing: the capture/fused entry points
        don't read every parameter (e.g. `unembed` in capture), and without
        it JAX prunes those arguments from the HLO — the Rust side would
        then supply more buffers than the compiled program expects.
        """
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        leaves = jax.tree_util.tree_leaves(outs)
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [spec_entry(n, s) for n, s in zip(in_names, in_specs)],
            "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)}
                        for o in leaves],
        }
        print(f"  {name}: {len(in_specs)} inputs, {len(leaves)} outputs, "
              f"{len(text) // 1024} KiB")

    def family(self, fname: str):
        cfg = model.config(fname)
        spec = model.param_spec(cfg)
        n = len(spec)
        b, s = model.BATCH, model.SEQ
        p_specs = [f32(*shape) for _, shape in spec]
        p_names = [name for name, _ in spec]
        self.manifest["families"][fname] = {
            "params": [{"name": nm, "shape": list(sh)} for nm, sh in spec],
            "projections": model.projection_names(cfg),
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_ff": cfg.d_ff, "mlp": cfg.mlp,
        }

        # fwd: logits for PPL / zero-shot eval.
        def fwd(*args):
            return (model.forward(cfg, list(args[:n]), args[n]),)

        self.emit(f"fwd_{fname}", fwd, p_specs + [i32(b, s)],
                  p_names + ["tokens"])

        # train: one AdamW step.
        def train(*args):
            params = list(args[:n])
            m_st = list(args[n:2 * n])
            v_st = list(args[2 * n:3 * n])
            step = args[3 * n]
            tokens = args[3 * n + 1]
            new_p, new_m, new_v, loss = model.train_step(
                cfg, params, m_st, v_st, step, tokens)
            return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

        train_specs = p_specs + p_specs + p_specs + [f32(), i32(b, s + 1)]
        train_names = (p_names + [f"m.{x}" for x in p_names]
                       + [f"v.{x}" for x in p_names] + ["step", "tokens"])
        self.emit(f"train_{fname}", train, train_specs, train_names)

        # capture: calibration activations.
        def capture(*args):
            return tuple(model.capture_acts(cfg, list(args[:n]), args[n]))

        self.emit(f"capture_{fname}", capture, p_specs + [i32(b, s)],
                  p_names + ["tokens"])

    def fused_forward(self, fname: str):
        """Deploy-path forward with every projection as (Q, L, R) through
        the Pallas fused kernel — proves L1∘L2∘L3 composition."""
        cfg = model.config(fname)
        spec = model.param_spec(cfg)
        n = len(spec)
        b, s = model.BATCH, model.SEQ
        r = FUSED_RANK
        dense_specs = [f32(*shape) for _, shape in spec]
        dense_names = [name for name, _ in spec]
        qlr_specs, qlr_names = [], []
        for pname in model.projection_names(cfg):
            shape = dict(spec)[pname]
            out_d, in_d = shape
            qlr_specs += [f32(out_d, in_d), f32(out_d, r), f32(r, in_d)]
            qlr_names += [f"{pname}.Q", f"{pname}.L", f"{pname}.R"]

        def fwd_fused(*args):
            dense = list(args[:n])
            qlr = list(args[n:n + len(qlr_specs)])
            tokens = args[n + len(qlr_specs)]
            return (model.forward_compressed(cfg, dense, qlr, tokens, r),)

        self.emit(f"fwd_fused_{fname}", fwd_fused,
                  dense_specs + qlr_specs + [i32(b, s)],
                  dense_names + qlr_names + ["tokens"])

    def kernels(self):
        """Standalone Layer-1 kernel artifacts (runtime integration tests +
        the serve/kernel benches)."""
        self.emit("kernel_quantize",
                  lambda w: (quantize_block(w, bits=4, group=32, block_m=32),),
                  [f32(128, 128)], ["w"])
        self.emit("kernel_fused_qlr",
                  lambda q, l, r, x: (fused_qlr_matmul(q, l, r, x, block_m=64),),
                  [f32(128, 128), f32(128, 32), f32(32, 128), f32(128, 16)],
                  ["q", "l", "r", "x"])
        self.emit("kernel_fwht",
                  lambda w: (fwht_rows(w, block_m=64),),
                  [f32(128, 128)], ["w"])

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--families", nargs="*", default=list(model.FAMILIES))
    args = ap.parse_args()
    b = Builder(args.out)
    print("lowering kernels…")
    b.kernels()
    for fname in args.families:
        print(f"lowering {fname}…")
        b.family(fname)
    print("lowering fused deploy forward (tl-7s)…")
    b.fused_forward("tl-7s")
    b.finish()


if __name__ == "__main__":
    main()
