"""Layer-1 Pallas kernel: blocked fast Walsh–Hadamard transform.

Used by the QuIP#-style incoherence pre-processing. Rows are tiled into
VMEM blocks; the log2(n) butterfly stages run entirely in-VMEM per tile
(the CUDA version's shared-memory butterflies map 1:1 onto this)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_kernel(w_ref, o_ref):
    x = w_ref[...]
    bm, n = x.shape
    h = 1
    while h < n:
        x = x.reshape(bm, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    o_ref[...] = x.reshape(bm, n) * (1.0 / jnp.sqrt(float(n)))


@functools.partial(jax.jit, static_argnames=("block_m",))
def fwht_rows(w: jnp.ndarray, block_m: int = 64) -> jnp.ndarray:
    """Orthonormal FWHT along the last axis (must be a power of two)."""
    m, n = w.shape
    assert n & (n - 1) == 0 and n > 0, f"n={n} must be a power of two"
    bm = min(block_m, m)
    pad = (-m) % bm
    wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
    mp = m + pad
    out = pl.pallas_call(
        _fwht_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, n), w.dtype),
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        interpret=True,
    )(wp)
    return out[:m] if pad else out
