"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every kernel in this package is
pytest-checked against the matching function here (exact shapes, then
hypothesis sweeps over shapes/dtypes in ``python/tests/test_kernels.py``).
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_block_ref(w: jnp.ndarray, bits: int, group: int) -> jnp.ndarray:
    """Symmetric per-group absmax fake-quantization (matches the Rust
    ``UniformQuantizer`` and the Pallas ``quantize_block`` kernel).

    Groups are contiguous runs of ``group`` entries along the last axis;
    the last axis must be divisible by ``group``.
    """
    m, n = w.shape
    assert n % group == 0, f"n={n} not divisible by group={group}"
    qmax = float(2 ** (bits - 1) - 1)
    g = w.reshape(m, n // group, group)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax)
    return (q * scale).reshape(m, n)


def fused_qlr_ref(
    q: jnp.ndarray, l: jnp.ndarray, r: jnp.ndarray, x: jnp.ndarray
) -> jnp.ndarray:
    """y = (Q + L R) x without materializing L R (two skinny matmuls)."""
    return q @ x + l @ (r @ x)


def fwht_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal Walsh–Hadamard transform along the last axis (power of
    two), matching the Rust ``fwht_rows``/``fwht_normalized``."""
    m, n = w.shape
    assert n & (n - 1) == 0, f"n={n} must be a power of two"
    x = w
    h = 1
    while h < n:
        x = x.reshape(m, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return x.reshape(m, n) / jnp.sqrt(float(n))
