"""Layer-1 Pallas kernel: fused compressed-weight product y = (Q + L R) x.

This is the deployment hot-spot of the paper's decomposition: the compressed
layer multiplies activations by ``Q + L R`` WITHOUT materializing the m×n
product ``L R``. The GPU story (QuIP#/CALDERA CUDA kernels) stages Q tiles in
shared memory and threads the low-rank path through registers; the TPU
rethinking tiles ``Q`` into (block_m × n) VMEM blocks targeted at the MXU,
with the rank-r path computed as two skinny MXU matmuls per tile:

    t = R @ x            (r × b)   — computed once, broadcast to all tiles
    y_tile = Q_tile @ x + L_tile @ t

``t`` is computed by a first Pallas kernel (it is shared across the grid —
the HBM↔VMEM analogue of CUDA's "one block computes, all blocks reuse"), and
the tiled kernel fuses the two products per output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rx_kernel(r_ref, x_ref, t_ref):
    t_ref[...] = r_ref[...] @ x_ref[...]


def _tile_kernel(q_ref, l_ref, x_ref, t_ref, o_ref):
    # One (block_m)-row slab of the output: MXU matmul on the Q tile plus
    # the rank-r correction.
    o_ref[...] = q_ref[...] @ x_ref[...] + l_ref[...] @ t_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m",))
def fused_qlr_matmul(
    q: jnp.ndarray,
    l: jnp.ndarray,
    r: jnp.ndarray,
    x: jnp.ndarray,
    block_m: int = 64,
) -> jnp.ndarray:
    """y = (Q + L @ R) @ x with Q (m,n), L (m,r), R (r,n), x (n,b)."""
    m, n = q.shape
    mr, rank = l.shape
    rr, nr = r.shape
    nx, b = x.shape
    assert (mr, rr, nr, nx) == (m, rank, n, n), "shape mismatch"
    bm = min(block_m, m)
    pad = (-m) % bm
    qp = jnp.pad(q, ((0, pad), (0, 0))) if pad else q
    lp = jnp.pad(l, ((0, pad), (0, 0))) if pad else l
    mp = m + pad

    # Stage 1: t = R @ x (single grid step; r and b are small).
    t = pl.pallas_call(
        _rx_kernel,
        out_shape=jax.ShapeDtypeStruct((rank, b), x.dtype),
        interpret=True,
    )(r, x)

    # Stage 2: row-tiled fused product.
    y = pl.pallas_call(
        _tile_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, b), x.dtype),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),       # Q tile
            pl.BlockSpec((bm, rank), lambda i: (i, 0)),    # L tile
            pl.BlockSpec((n, b), lambda i: (0, 0)),        # x (broadcast)
            pl.BlockSpec((rank, b), lambda i: (0, 0)),     # t (broadcast)
        ],
        out_specs=pl.BlockSpec((bm, b), lambda i: (i, 0)),
        interpret=True,
    )(qp, lp, x, t)
    return y[:m] if pad else y


def vmem_bytes(block_m: int, n: int, rank: int, b: int, dtype_bytes: int = 4) -> int:
    """Per-step VMEM residency: Q tile + L tile + x + t + output tile."""
    return dtype_bytes * (block_m * n + block_m * rank + n * b + rank * b + block_m * b)


def mxu_flops(m: int, n: int, rank: int, b: int) -> int:
    """MXU MAC count for one call (fused path)."""
    return 2 * (m * n * b + rank * n * b + m * rank * b)


def dense_flops(m: int, n: int, b: int, rank: int) -> int:
    """MACs if LR were materialized first (the naive path)."""
    return 2 * (m * n * rank + m * n * b)
