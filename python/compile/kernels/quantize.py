"""Layer-1 Pallas kernel: per-group symmetric fake-quantization.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows into
VMEM-resident blocks; the per-group absmax reduction happens entirely
in-register per tile (the GPU version's warp-reduce). ``interpret=True`` is
mandatory on this image — real TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(w_ref, o_ref, *, bits: int, group: int):
    """One grid step: quantize a (block_m, n) tile."""
    w = w_ref[...]
    bm, n = w.shape
    qmax = float(2 ** (bits - 1) - 1)
    g = w.reshape(bm, n // group, group)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax)
    o_ref[...] = (q * scale).reshape(bm, n)


@functools.partial(jax.jit, static_argnames=("bits", "group", "block_m"))
def quantize_block(
    w: jnp.ndarray, bits: int = 4, group: int = 32, block_m: int = 32
) -> jnp.ndarray:
    """Fake-quantize ``w`` (m, n) with per-group absmax scales.

    ``n`` must be divisible by ``group``; rows are processed in
    ``block_m``-row VMEM tiles.
    """
    m, n = w.shape
    assert n % group == 0, f"n={n} % group={group} != 0"
    bm = min(block_m, m)
    # Pad rows to a multiple of the block.
    pad = (-m) % bm
    wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
    mp = m + pad
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits, group=group),
        out_shape=jax.ShapeDtypeStruct((mp, n), w.dtype),
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(wp)
    return out[:m] if pad else out


# VMEM/MXU accounting used by DESIGN.md §Perf (analytic, since interpret
# mode gives CPU-numpy timings that say nothing about TPU).
def vmem_bytes(block_m: int, n: int, dtype_bytes: int = 4) -> int:
    """Per-step VMEM: input tile + output tile."""
    return 2 * block_m * n * dtype_bytes
