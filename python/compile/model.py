"""Layer-2: tiny Llama-style transformer families in JAX (build-time only).

Five scaled-down model families stand in for the paper's evaluation models
(DESIGN.md §2 documents the substitution):

=========  ============================  =========================
family     stands in for                 distinguishing knobs
=========  ============================  =========================
tl-7s      Llama2-7B                     MHA, SwiGLU
tl-13s     Llama2-13B                    wider + deeper MHA
tl3-8s     Llama3-8B                     GQA, larger vocab
tm-7s      Mistral-7B                    GQA, wider FFN
tg-2s      Gemma2-2B                     GeGLU, post-norm scaling
=========  ============================  =========================

Everything here is lowered once by ``aot.py`` to HLO text; the Rust runtime
executes the artifacts. Parameters travel as a FLAT LIST in ``param_spec``
order — the manifest records names/shapes so the Rust side can assemble and
consume the same order.

Weight convention matches the paper: ``W`` is (out, in) and layers compute
``y = x @ W.T`` — so the calibration activations for a matrix are its INPUT
vectors and ``H = X Xᵀ`` with X (in_dim, samples).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    rope_theta: float = 10000.0
    # 'swiglu' (silu(gate)*up) or 'geglu' (gelu(gate)*up, Gemma-style)
    mlp: str = "swiglu"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


FAMILIES = {
    "tl-7s": ModelConfig("tl-7s", vocab=256, d_model=128, n_layers=4,
                         n_heads=4, n_kv_heads=4, d_ff=352),
    "tl-13s": ModelConfig("tl-13s", vocab=256, d_model=192, n_layers=5,
                          n_heads=6, n_kv_heads=6, d_ff=512),
    "tl3-8s": ModelConfig("tl3-8s", vocab=384, d_model=128, n_layers=4,
                          n_heads=4, n_kv_heads=2, d_ff=384),
    "tm-7s": ModelConfig("tm-7s", vocab=256, d_model=128, n_layers=4,
                         n_heads=4, n_kv_heads=2, d_ff=448),
    "tg-2s": ModelConfig("tg-2s", vocab=256, d_model=96, n_layers=3,
                         n_heads=4, n_kv_heads=4, d_ff=320, mlp="geglu"),
}

# Batch/sequence shape every artifact is lowered with. Small enough for
# snappy CPU execution, large enough for meaningful Hessians.
BATCH = 8
SEQ = 96  # long enough for the longest zero-shot prompt + choice + padding


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Flat parameter layout: (name, shape) in artifact order."""
    spec: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.kv_dim, cfg.d_model)),
            (p + "wv", (cfg.kv_dim, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2", (cfg.d_model,)),
            (p + "wgate", (cfg.d_ff, cfg.d_model)),
            (p + "wup", (cfg.d_ff, cfg.d_model)),
            (p + "wdown", (cfg.d_model, cfg.d_ff)),
        ]
    spec += [("ln_f", (cfg.d_model,)), ("unembed", (cfg.vocab, cfg.d_model))]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Scaled-normal initialization in spec order."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-1]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / math.sqrt(fan_in)
            )
    return params


def _rms_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def _rope(x: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding over (B, S, H, Dh)."""
    b, s, h, dh = x.shape
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _unpack(cfg: ModelConfig, params: List[jnp.ndarray]):
    names = [n for n, _ in param_spec(cfg)]
    return dict(zip(names, params))


def _layer(cfg: ModelConfig, p, i: int, x: jnp.ndarray, mask, capture=None):
    """One transformer block. Returns the new residual stream; if `capture`
    is a list, appends the four calibration activation matrices
    (attn_in, attn_ctx, mlp_in, mlp_mid), each (in_dim, B·S)."""
    b, s, d = x.shape
    pre = f"layer{i}."
    h = _rms_norm(x, p[pre + "ln1"])
    if capture is not None:
        capture.append(h.reshape(-1, d).T)  # attn_in
    q = h @ p[pre + "wq"].T
    k = h @ p[pre + "wk"].T
    v = h @ p[pre + "wv"].T
    hd = cfg.head_dim
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = _rope(q, cfg.rope_theta)
    k = _rope(k, cfg.rope_theta)
    if cfg.n_kv_heads != cfg.n_heads:
        rep = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    att = att + mask
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
    if capture is not None:
        capture.append(ctx.reshape(-1, d).T)  # attn_ctx
    x = x + ctx @ p[pre + "wo"].T

    h2 = _rms_norm(x, p[pre + "ln2"])
    if capture is not None:
        capture.append(h2.reshape(-1, d).T)  # mlp_in
    gate = h2 @ p[pre + "wgate"].T
    up = h2 @ p[pre + "wup"].T
    act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate)
    mid = act * up
    if capture is not None:
        capture.append(mid.reshape(-1, cfg.d_ff).T)  # mlp_mid
    x = x + mid @ p[pre + "wdown"].T
    return x


def forward(cfg: ModelConfig, params: List[jnp.ndarray], tokens: jnp.ndarray,
            capture=None) -> jnp.ndarray:
    """Dense forward: tokens (B, S) int32 → logits (B, S, V)."""
    p = _unpack(cfg, params)
    b, s = tokens.shape
    x = p["embed"][tokens]
    mask = jnp.where(
        jnp.tril(jnp.ones((s, s), bool)), 0.0, -1e9
    )[None, None, :, :]
    for i in range(cfg.n_layers):
        x = _layer(cfg, p, i, x, mask, capture)
    x = _rms_norm(x, p["ln_f"])
    return x @ p["unembed"].T


def loss_fn(cfg: ModelConfig, params: List[jnp.ndarray],
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy. tokens: (B, S+1) int32."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, params, m_state, v_state, step, tokens,
               lr: float = 3e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    """One AdamW step, fully functional. Returns
    (new_params, new_m, new_v, loss) — all flat lists + scalar."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, tokens)
    )(params)
    t = step + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    new_p, new_m, new_v = [], [], []
    for (name, _shape), p_i, g, m, v in zip(
        param_spec(cfg), params, grads, m_state, v_state
    ):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        decay = 0.0 if name.endswith(("ln1", "ln2", "ln_f")) else wd
        new_p.append(p_i - lr * (upd + decay * p_i))
        new_m.append(m2)
        new_v.append(v2)
    return new_p, new_m, new_v, loss


def capture_acts(cfg: ModelConfig, params, tokens) -> List[jnp.ndarray]:
    """Calibration activations: for each layer, four matrices
    (attn_in, attn_ctx, mlp_in, mlp_mid), each (in_dim, B·S)."""
    caps: List[jnp.ndarray] = []
    forward(cfg, params, tokens, capture=caps)
    return caps


# ---------------------------------------------------------------------------
# Compressed deploy forward: the L1 fused kernel inside the L2 model.
# ---------------------------------------------------------------------------

def fused_linear(q, l, r, x2d):
    """Compressed linear on (tokens, in_dim) activations via the Pallas
    fused kernel: returns (tokens, out_dim)."""
    from .kernels.fused_qlr import fused_qlr_matmul

    # Kernel computes (Q + LR) @ X with X (in_dim, tokens).
    return fused_qlr_matmul(q, l, r, x2d.T, block_m=64).T


def forward_compressed(cfg: ModelConfig, dense: List[jnp.ndarray],
                       qlr: List[jnp.ndarray], tokens: jnp.ndarray,
                       rank: int) -> jnp.ndarray:
    """Deploy-path forward where every projection matrix is (Q, L, R).

    ``dense`` carries the uncompressed params (embed/norms/unembed; the
    projection slots in `dense` are ignored). ``qlr`` is a flat list with
    3 entries (Q, L, R) per projection matrix, in ``param_spec`` order of
    the 7 projections per layer.
    """
    p = _unpack(cfg, dense)
    b, s = tokens.shape
    x = p["embed"][tokens]
    mask = jnp.where(jnp.tril(jnp.ones((s, s), bool)), 0.0, -1e9)[None, None]
    it = iter(range(0, len(qlr), 3))

    def nxt():
        j = next(it)
        return qlr[j], qlr[j + 1], qlr[j + 2]

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        d = cfg.d_model
        h = _rms_norm(x, p[pre + "ln1"])
        h2d = h.reshape(-1, d)
        q_w = nxt()
        k_w = nxt()
        v_w = nxt()
        q = fused_linear(*q_w, h2d).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = fused_linear(*k_w, h2d).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = fused_linear(*v_w, h2d).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q, cfg.rope_theta)
        k = _rope(k, cfg.rope_theta)
        if cfg.n_kv_heads != cfg.n_heads:
            rep = cfg.n_heads // cfg.n_kv_heads
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(cfg.head_dim)
        att = jax.nn.softmax(att + mask, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
        o_w = nxt()
        x = x + fused_linear(*o_w, ctx.reshape(-1, d)).reshape(b, s, d)
        h2 = _rms_norm(x, p[pre + "ln2"])
        h2_2d = h2.reshape(-1, d)
        gate_w = nxt()
        up_w = nxt()
        gate = fused_linear(*gate_w, h2_2d)
        up = fused_linear(*up_w, h2_2d)
        act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate)
        mid = act * up
        down_w = nxt()
        x = x + fused_linear(*down_w, mid).reshape(b, s, d)
    x = _rms_norm(x, p["ln_f"])
    return x @ p["unembed"].T


def projection_names(cfg: ModelConfig) -> List[str]:
    """Names of the 7·n_layers compressible projection matrices, in order."""
    out = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        out += [p + w for w in ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")]
    return out


@functools.lru_cache(maxsize=None)
def config(name: str) -> ModelConfig:
    return FAMILIES[name]
