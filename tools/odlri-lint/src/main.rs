//! `odlri-lint` — a repo-specific static analysis pass over `rust/src`.
//!
//! The repo's core claims (bit-exact `Q` decode, bit-sound prefix sharing,
//! bit-exact preempt/resume, speculative == plain greedy) rest on invariants
//! that a general-purpose linter cannot know about. This tool makes them
//! machine-checked: it does a token-level scan (comments and string literals
//! are masked out, `#[cfg(test)]` items are skipped) and fails the build on
//! any violation. Run as `cargo run -p odlri-lint -- rust/src`; CI runs the
//! same command as a required job.
//!
//! ## Rules
//!
//! * **hot-path-panic** — no `.unwrap()` / `.expect(` / `panic!` / `todo!` /
//!   `unimplemented!` in non-test code under `serve/`, `engine/`, `fused/`,
//!   `runtime/`, or `quant/packed.rs`. A panic on the scheduler or decode
//!   hot path kills every in-flight session of the process; failures there
//!   must be typed errors the scheduler can route (preempt / reject / retry).
//! * **checked-narrowing** — inside container read paths (functions named
//!   `read_from` / `parse*` in `quant/packed.rs`, `fused/mod.rs`,
//!   `runtime/manifest.rs`), `as`-casts to a sub-64-bit integer type
//!   (`u8/u16/u32/i8/i16/i32`) are refused: a wrapped cast while
//!   deserializing turns a corrupt container into wrong logits instead of a
//!   ranged error. Use `try_into()` / `T::from()` with a typed error.
//! * **error-tag-sync** — `runtime/kvpool.rs` classifies `KvError` values
//!   across the vendored no-downcast `anyhow` by scanning `{e:#}` chains for
//!   stable `*_TAG` strings. Every `*_TAG` const must have a matching
//!   `is_<tag>` classifier and vice versa, and every tag must appear in the
//!   `Display` impl — a tag without a classifier silently demotes a typed
//!   refusal to a fatal error.
//! * **cli-help-sync** — every flag/switch registered in `cli::COMMANDS`
//!   must appear as a `--flag` token in `cli::HELP`, and every `--flag`
//!   token in `HELP` must exist in the registry. Undocumented flags and
//!   documented-but-rejected flags are both failures.
//! * **lock-across-forward** — no lock guard (a `let` binding whose
//!   initializer contains `.lock(`) may be live across a call to `fwd_*` /
//!   `prefill*` / `project` / `verify_step*` (brace-depth guard-lifetime
//!   heuristic). Holding the KV pool mutex across a forward serializes every
//!   other session's decode behind one matmul — and deadlocks if the forward
//!   re-enters the pool.
//! * **typed-response-terminal** — in `serve/`, any element removal
//!   (`remove` / `swap_remove` / `pop_front` / `pop_back` / `drain`) from a
//!   scheduler holding area (`active`, `prefilling`, `preempted`, `queues`)
//!   must be followed, in the same function body, by a typed terminal
//!   (`finish*` / `retire` / `reject` / `shed`) or a re-park (a push back
//!   into a holding area / queue insert). A removal with neither silently
//!   drops a request — its client blocks forever and the "every submitted
//!   request terminates with exactly one typed Response" invariant breaks.
//!   Wholesale `.clear()` on the fatal teardown path is out of scope: there
//!   the responders are dropped en masse, which *is* the wake-up.
//!
//! ## Escapes
//!
//! A violation that is provably fine carries a narrowly scoped allow on the
//! same line or the line directly above:
//!
//! ```text
//! // lint:allow(hot-path-panic) <one-line justification, required>
//! ```
//!
//! An allow with an empty justification is itself a violation, and so is an
//! allow that matches nothing (they rot otherwise).

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const NARROW_TARGETS: [&str; 6] = ["u8", "u16", "u32", "i8", "i16", "i32"];

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl Violation {
    fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

/// A `// lint:allow(<rule>) <justification>` directive.
#[derive(Debug)]
struct Allow {
    line: usize,
    rule: String,
    justified: bool,
    used: std::cell::Cell<bool>,
}

/// Source with comments and string/char literals blanked (byte-for-byte, so
/// offsets and line numbers survive), plus the allow directives found in the
/// stripped line comments.
struct Masked {
    text: Vec<u8>,
    allows: Vec<Allow>,
}

impl Masked {
    fn allowed(&self, rule: &str, line: usize) -> bool {
        for a in &self.allows {
            if a.rule == rule && a.justified && (a.line == line || a.line + 1 == line) {
                a.used.set(true);
                return true;
            }
        }
        false
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank comments and string/char literals with spaces (newlines kept), and
/// collect `lint:allow` directives from line comments.
fn mask(src: &str) -> Masked {
    let bytes = src.as_bytes();
    let mut out = bytes.to_vec();
    let mut allows = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    let blank = |out: &mut [u8], from: usize, to: usize| {
        for b in &mut out[from..to] {
            if *b != b'\n' {
                *b = b' ';
            }
        }
    };
    while i < bytes.len() {
        let b = bytes[i];
        if b == b'\n' {
            line += 1;
            i += 1;
        } else if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < bytes.len() && bytes[i] != b'\n' {
                i += 1;
            }
            parse_allow(&src[start..i], line, &mut allows);
            blank(&mut out, start, i);
        } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < bytes.len() && depth > 0 {
                if bytes[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start, i);
        } else if b == b'"' {
            // Raw string? Count `#`s directly before the quote; raw iff the
            // char before them is an `r` not glued to an identifier.
            let mut hashes = 0usize;
            while i > hashes && bytes[i - 1 - hashes] == b'#' {
                hashes += 1;
            }
            let r_at = i.checked_sub(hashes + 1);
            let raw = r_at.is_some_and(|k| {
                bytes[k] == b'r' && (k == 0 || !is_ident(bytes[k - 1]) || bytes[k - 1] == b'b')
            });
            let start = i;
            i += 1;
            if raw {
                let close: Vec<u8> = std::iter::once(b'"')
                    .chain(std::iter::repeat(b'#').take(hashes))
                    .collect();
                while i < bytes.len() && !bytes[i..].starts_with(&close) {
                    if bytes[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
                i = (i + close.len()).min(bytes.len());
            } else {
                while i < bytes.len() && bytes[i] != b'"' {
                    if bytes[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if bytes[i] == b'\\' {
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                i = (i + 1).min(bytes.len());
            }
            blank(&mut out, start, i);
        } else if b == b'\'' {
            // Char literal vs lifetime.
            let start = i;
            if bytes.get(i + 1) == Some(&b'\\') {
                i += 2;
                while i < bytes.len() && bytes[i] != b'\'' {
                    i += 1;
                }
                i = (i + 1).min(bytes.len());
                blank(&mut out, start, i);
            } else if bytes.get(i + 2) == Some(&b'\'')
                || (bytes.get(i + 1).is_some_and(|c| *c >= 0x80)
                    && bytes[i + 1..].iter().take(5).any(|c| *c == b'\''))
            {
                i += 2;
                while i < bytes.len() && bytes[i] != b'\'' {
                    i += 1;
                }
                i = (i + 1).min(bytes.len());
                blank(&mut out, start, i);
            } else {
                i += 1; // lifetime: leave the identifier in place
            }
        } else {
            i += 1;
        }
    }
    Masked { text: out, allows }
}

fn parse_allow(comment: &str, line: usize, allows: &mut Vec<Allow>) {
    let Some(pos) = comment.find("lint:allow(") else {
        return;
    };
    let rest = &comment[pos + "lint:allow(".len()..];
    let Some(close) = rest.find(')') else {
        allows.push(Allow {
            line,
            rule: String::new(),
            justified: false,
            used: std::cell::Cell::new(false),
        });
        return;
    };
    let rule = rest[..close].trim().to_string();
    let justified = !rest[close + 1..].trim().is_empty();
    allows.push(Allow {
        line,
        rule,
        justified,
        used: std::cell::Cell::new(false),
    });
}

/// Line number (1-based) of a byte offset.
fn line_of(text: &[u8], offset: usize) -> usize {
    1 + text[..offset].iter().filter(|b| **b == b'\n').count()
}

/// Line ranges covered by `#[cfg(test)]` items (attribute → matching close
/// brace of the next braced item; brace-less items are skipped).
fn test_regions(masked: &[u8]) -> Vec<(usize, usize)> {
    let needle = b"#[cfg(test)]";
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = find(&masked[from..], needle) {
        let attr = from + rel;
        from = attr + needle.len();
        let mut i = from;
        let mut open = None;
        while i < masked.len() {
            match masked[i] {
                b'{' => {
                    open = Some(i);
                    break;
                }
                b';' => break, // brace-less item (e.g. `#[cfg(test)] use ...;`)
                _ => i += 1,
            }
        }
        let Some(open) = open else { continue };
        let mut depth = 0usize;
        let mut close = masked.len();
        for (j, b) in masked.iter().enumerate().skip(open) {
            if *b == b'{' {
                depth += 1;
            } else if *b == b'}' {
                depth -= 1;
                if depth == 0 {
                    close = j;
                    break;
                }
            }
        }
        out.push((line_of(masked, attr), line_of(masked, close)));
        from = close.min(masked.len().saturating_sub(1)) + 1;
    }
    out
}

fn in_regions(regions: &[(usize, usize)], line: usize) -> bool {
    regions.iter().any(|(a, b)| (*a..=*b).contains(&line))
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack
        .windows(needle.len())
        .position(|w| w == needle)
}

// ----------------------------------------------------------- rule 1: panics

fn hot_path_scope(rel: &str) -> bool {
    rel.starts_with("serve/")
        || rel.starts_with("engine/")
        || rel.starts_with("fused/")
        || rel.starts_with("runtime/")
        || rel == "quant/packed.rs"
}

fn check_hot_path_panic(
    rel: &str,
    masked: &Masked,
    regions: &[(usize, usize)],
    out: &mut Vec<Violation>,
) {
    let text = &masked.text;
    let tokens: [(&[u8], &str); 5] = [
        (b".unwrap()", "`.unwrap()`"),
        (b".expect(", "`.expect(...)`"),
        (b"panic!", "`panic!`"),
        (b"todo!", "`todo!`"),
        (b"unimplemented!", "`unimplemented!`"),
    ];
    for (needle, label) in tokens {
        let mut from = 0usize;
        while let Some(rel_pos) = find(&text[from..], needle) {
            let at = from + rel_pos;
            from = at + needle.len();
            // Token boundary on the left for the macro names.
            if needle[0] != b'.' && at > 0 && is_ident(text[at - 1]) {
                continue;
            }
            let line = line_of(text, at);
            if in_regions(regions, line) || masked.allowed("hot-path-panic", line) {
                continue;
            }
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule: "hot-path-panic",
                msg: format!(
                    "{label} on the serving hot path — return a typed error \
                     or add `// lint:allow(hot-path-panic) <why infallible>`"
                ),
            });
        }
    }
}

// -------------------------------------------------- rule 2: narrowing casts

fn narrowing_scope(rel: &str) -> bool {
    rel == "quant/packed.rs" || rel == "fused/mod.rs" || rel == "runtime/manifest.rs"
}

/// Body spans (byte ranges) of every `fn` whose name passes `keep`.
/// Closures are not matched, so a site inside a closure resolves to its
/// enclosing named function.
fn fn_body_spans(masked: &[u8], keep: fn(&[u8]) -> bool) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = find(&masked[from..], b"fn ") {
        let at = from + rel;
        from = at + 3;
        if at > 0 && is_ident(masked[at - 1]) {
            continue;
        }
        let mut j = at + 3;
        while j < masked.len() && masked[j] == b' ' {
            j += 1;
        }
        let name_start = j;
        while j < masked.len() && is_ident(masked[j]) {
            j += 1;
        }
        if !keep(&masked[name_start..j]) {
            continue;
        }
        let mut depth = 0usize;
        let mut open = None;
        for (k, b) in masked.iter().enumerate().skip(j) {
            match *b {
                b'{' if depth == 0 => {
                    open = Some(k);
                    break;
                }
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth = depth.saturating_sub(1),
                b';' if depth == 0 => break, // trait method without a body
                _ => {}
            }
        }
        let Some(open) = open else { continue };
        let mut depth = 0usize;
        for (k, b) in masked.iter().enumerate().skip(open) {
            if *b == b'{' {
                depth += 1;
            } else if *b == b'}' {
                depth -= 1;
                if depth == 0 {
                    out.push((open, k));
                    from = from.max(at + 3);
                    break;
                }
            }
        }
    }
    out
}

fn check_checked_narrowing(
    rel: &str,
    masked: &Masked,
    regions: &[(usize, usize)],
    out: &mut Vec<Violation>,
) {
    let text = &masked.text;
    // Exactly the container deserializers — bit-twiddling helpers like
    // `read_code` cast as part of field extraction, not untrusted counts.
    let readers = fn_body_spans(text, |name| {
        name == b"read_from" || name.starts_with(b"parse")
    });
    for (start, end) in readers {
        let mut from = start;
        while let Some(rel_pos) = find(&text[from..end], b"as ") {
            let at = from + rel_pos;
            from = at + 3;
            if at > 0 && is_ident(text[at - 1]) {
                continue; // `alias `, `has ` ...
            }
            let mut j = at + 3;
            while j < end && text[j] == b' ' {
                j += 1;
            }
            let ty_start = j;
            while j < end && is_ident(text[j]) {
                j += 1;
            }
            let ty = std::str::from_utf8(&text[ty_start..j]).unwrap_or("");
            if !NARROW_TARGETS.contains(&ty) {
                continue;
            }
            let line = line_of(text, at);
            if in_regions(regions, line) || masked.allowed("checked-narrowing", line) {
                continue;
            }
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule: "checked-narrowing",
                msg: format!(
                    "`as {ty}` inside a container read path can wrap on corrupt \
                     input — use `try_into()`/`{ty}::from()` with a ranged error"
                ),
            });
        }
    }
}

// ------------------------------------------------------ rule 3: error tags

fn check_error_tag_sync(rel: &str, raw: &str, out: &mut Vec<Violation>) {
    let mut tags: Vec<(String, usize)> = Vec::new();
    let mut classifiers: Vec<(String, usize)> = Vec::new();
    for (ln, line) in raw.lines().enumerate() {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("pub const ") {
            if let Some(name_end) = rest.find("_TAG:") {
                tags.push((rest[..name_end].to_lowercase(), ln + 1));
            }
        }
        if t.contains("pub fn is_") && t.contains("&anyhow::Error") {
            if let Some(pos) = t.find("pub fn is_") {
                let rest = &t[pos + "pub fn is_".len()..];
                if let Some(p) = rest.find('(') {
                    classifiers.push((rest[..p].to_string(), ln + 1));
                }
            }
        }
    }
    for (tag, ln) in &tags {
        if !classifiers.iter().any(|(c, _)| c == tag) {
            out.push(Violation {
                file: rel.to_string(),
                line: *ln,
                rule: "error-tag-sync",
                msg: format!(
                    "tag const `{}_TAG` has no `is_{tag}` classifier — callers \
                     cannot route this error",
                    tag.to_uppercase()
                ),
            });
        }
        let ident = format!("{}_TAG", tag.to_uppercase());
        if raw.matches(&ident).count() < 3 {
            out.push(Violation {
                file: rel.to_string(),
                line: *ln,
                rule: "error-tag-sync",
                msg: format!(
                    "tag const `{ident}` is not referenced outside its declaration \
                     and classifier — the Display impl must emit it"
                ),
            });
        }
    }
    for (c, ln) in &classifiers {
        if !tags.iter().any(|(t, _)| t == c) {
            out.push(Violation {
                file: rel.to_string(),
                line: *ln,
                rule: "error-tag-sync",
                msg: format!("classifier `is_{c}` matches no `*_TAG` const — dead matcher"),
            });
        }
    }
}

// -------------------------------------------------------- rule 4: cli help

/// Quoted string contents inside `raw[span]` (no escape handling: registry
/// flag names are plain `[a-z0-9-]`).
fn quoted_strings(span: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = span;
    while let Some(open) = rest.find('"') {
        let tail = &rest[open + 1..];
        let Some(close) = tail.find('"') else { break };
        out.push(tail[..close].to_string());
        rest = &tail[close + 1..];
    }
    out
}

fn check_cli_help_sync(rel: &str, raw: &str, masked: &Masked, out: &mut Vec<Violation>) {
    let text = &masked.text;
    // Registry span: `const COMMANDS ... = &[` to the matching `]`.
    let Some(cmd_at) = find(text, b"const COMMANDS") else {
        out.push(Violation {
            file: rel.to_string(),
            line: 1,
            rule: "cli-help-sync",
            msg: "no `const COMMANDS` registry found".into(),
        });
        return;
    };
    let Some(open_rel) = find(&text[cmd_at..], b"= &[") else {
        return;
    };
    let open = cmd_at + open_rel + 3;
    let mut depth = 0usize;
    let mut close = text.len();
    for (k, b) in text.iter().enumerate().skip(open) {
        if *b == b'[' {
            depth += 1;
        } else if *b == b']' {
            depth -= 1;
            if depth == 0 {
                close = k;
                break;
            }
        }
    }
    let mut registry: BTreeSet<String> = BTreeSet::new();
    let span = &raw[open..close];
    let span_masked = &text[open..close];
    for list_kw in [&b"flags:"[..], &b"switches:"[..]] {
        let mut from = 0usize;
        while let Some(rel_pos) = find(&span_masked[from..], list_kw) {
            let at = from + rel_pos;
            from = at + list_kw.len();
            let Some(lo) = span_masked[at..].iter().position(|b| *b == b'[') else {
                continue;
            };
            let Some(hi) = span_masked[at + lo..].iter().position(|b| *b == b']') else {
                continue;
            };
            for s in quoted_strings(&span[at + lo..at + lo + hi]) {
                registry.insert(s);
            }
        }
    }
    // HELP span: the string literal after `const HELP`.
    let Some(help_at) = find(text, b"const HELP") else {
        out.push(Violation {
            file: rel.to_string(),
            line: 1,
            rule: "cli-help-sync",
            msg: "no `const HELP` text found".into(),
        });
        return;
    };
    let bytes = raw.as_bytes();
    let Some(q_rel) = bytes[help_at..].iter().position(|b| *b == b'"') else {
        return;
    };
    let mut j = help_at + q_rel + 1;
    let help_start = j;
    while j < bytes.len() && bytes[j] != b'"' {
        if bytes[j] == b'\\' {
            j += 2;
        } else {
            j += 1;
        }
    }
    let help = &raw[help_start..j.min(bytes.len())];
    let help_line = line_of(text, help_at);
    let mut documented: BTreeSet<String> = BTreeSet::new();
    let hb = help.as_bytes();
    let mut k = 0usize;
    while k + 2 < hb.len() {
        if hb[k] == b'-' && hb[k + 1] == b'-' && hb[k + 2].is_ascii_alphanumeric() {
            let start = k + 2;
            let mut e = start;
            while e < hb.len() && (hb[e].is_ascii_alphanumeric() || hb[e] == b'-') {
                e += 1;
            }
            documented.insert(help[start..e].trim_end_matches('-').to_string());
            k = e;
        } else {
            k += 1;
        }
    }
    for f in registry.difference(&documented) {
        out.push(Violation {
            file: rel.to_string(),
            line: line_of(text, cmd_at),
            rule: "cli-help-sync",
            msg: format!("registered flag `--{f}` is not documented in HELP"),
        });
    }
    for f in documented.difference(&registry) {
        out.push(Violation {
            file: rel.to_string(),
            line: help_line,
            rule: "cli-help-sync",
            msg: format!("HELP documents `--{f}` but no command registers it"),
        });
    }
}

// ----------------------------------------------- rule 5: lock across forward

/// True when the identifier starting at `at` names a forward-like call.
fn forward_call_at(text: &[u8], at: usize) -> Option<(usize, String)> {
    let mut j = at;
    while j < text.len() && is_ident(text[j]) {
        j += 1;
    }
    if j >= text.len() || text[j] != b'(' {
        return None;
    }
    let name = std::str::from_utf8(&text[at..j]).ok()?;
    let hit = name.starts_with("fwd_")
        || name.starts_with("prefill")
        || name.starts_with("verify_step")
        || name == "project";
    hit.then(|| (j, name.to_string()))
}

fn check_lock_across_forward(
    rel: &str,
    masked: &Masked,
    regions: &[(usize, usize)],
    out: &mut Vec<Violation>,
) {
    let text = &masked.text;
    let mut from = 0usize;
    while let Some(rel_pos) = find(&text[from..], b".lock(") {
        let at = from + rel_pos;
        from = at + 6;
        let guard_line = line_of(text, at);
        if in_regions(regions, guard_line) {
            continue;
        }
        // Only `let`-bound guards outlive their statement.
        let line_start = text[..at].iter().rposition(|b| *b == b'\n').map_or(0, |p| p + 1);
        let lead = std::str::from_utf8(&text[line_start..at]).unwrap_or("");
        if !lead.trim_start().starts_with("let ") {
            continue;
        }
        // Guard is live until the enclosing block closes.
        let mut depth = 0isize;
        let mut k = at;
        while k < text.len() {
            match text[k] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth < 0 {
                        break;
                    }
                }
                b'a'..=b'z' | b'A'..=b'Z' | b'_' => {
                    if !is_ident(text[k.saturating_sub(1)]) && text[k.saturating_sub(1)] != b'.' {
                        if let Some((end, name)) = forward_call_at(text, k) {
                            let line = line_of(text, k);
                            if !in_regions(regions, line)
                                && !masked.allowed("lock-across-forward", line)
                            {
                                out.push(Violation {
                                    file: rel.to_string(),
                                    line,
                                    rule: "lock-across-forward",
                                    msg: format!(
                                        "`{name}(...)` runs while the lock guard taken on \
                                         line {guard_line} is still live — drop the guard \
                                         before any forward"
                                    ),
                                });
                            }
                            k = end;
                            continue;
                        }
                    }
                    while k < text.len() && is_ident(text[k]) {
                        k += 1;
                    }
                    continue;
                }
                _ => {}
            }
            k += 1;
        }
    }
}

// ------------------------------------- rule 6: typed-response terminals

/// The scheduler's request holding areas. A request lives in exactly one
/// of these between submission and its typed terminal response.
const HOLDING_AREAS: [&str; 4] = ["active", "prefilling", "preempted", "queues"];

/// Calls that end (or legitimately re-park) a removed request: the typed
/// terminals (`finish` / `finish_prefill` / `retire` / `reject` / `shed`)
/// and the re-insertion paths (resume into `active`, park into
/// `preempted`, requeue / admission hand-off).
const TERMINAL_CONTINUATIONS: [&[u8]; 9] = [
    b"self.finish",
    b"self.retire(",
    b"self.reject(",
    b"self.shed(",
    b"self.active.push(",
    b"self.preempted.push(",
    b"score_batch.push(",
    b"self.admit_generate",
    b"q.insert(",
];

fn check_typed_response_terminal(
    rel: &str,
    masked: &Masked,
    regions: &[(usize, usize)],
    out: &mut Vec<Violation>,
) {
    let text = &masked.text;
    let bodies = fn_body_spans(text, |_| true);
    let removals: [&[u8]; 5] = [
        b".swap_remove(",
        b".remove(",
        b".pop_front(",
        b".pop_back(",
        b".drain(",
    ];
    for needle in removals {
        let mut from = 0usize;
        while let Some(rel_pos) = find(&text[from..], needle) {
            let at = from + rel_pos;
            from = at + needle.len();
            // The dotted receiver path ending at the removal must name a
            // holding area; removals from unrelated containers are fine.
            let line_start = text[..at]
                .iter()
                .rposition(|b| *b == b'\n')
                .map_or(0, |p| p + 1);
            let mut r = at;
            while r > line_start
                && (is_ident(text[r - 1]) || b".[]():".contains(&text[r - 1]))
            {
                r -= 1;
            }
            let recv = std::str::from_utf8(&text[r..at]).unwrap_or("");
            if !HOLDING_AREAS.iter().any(|h| recv.contains(h)) {
                continue;
            }
            let line = line_of(text, at);
            if in_regions(regions, line) || masked.allowed("typed-response-terminal", line) {
                continue;
            }
            // Innermost enclosing function body; a removal in a const
            // initializer or macro arm has no body to scan and is skipped.
            let Some(&(_, end)) = bodies
                .iter()
                .filter(|(s, e)| *s <= at && at <= *e)
                .max_by_key(|(s, _)| *s)
            else {
                continue;
            };
            if TERMINAL_CONTINUATIONS
                .iter()
                .any(|c| find(&text[at..end], c).is_some())
            {
                continue;
            }
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule: "typed-response-terminal",
                msg: format!(
                    "removal from `{}` reaches no typed terminal (finish/retire/\
                     reject/shed) or re-park in this function — the request's \
                     client would block forever; answer it or add \
                     `// lint:allow(typed-response-terminal) <why>`",
                    recv.trim_start_matches("self.")
                ),
            });
        }
    }
}

// ------------------------------------------------------------------ driver

fn rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let p = entry?.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn check_file(rel: &str, raw: &str, out: &mut Vec<Violation>) {
    let masked = mask(raw);
    let regions = test_regions(&masked.text);
    if hot_path_scope(rel) {
        check_hot_path_panic(rel, &masked, &regions, out);
        check_lock_across_forward(rel, &masked, &regions, out);
    }
    if rel.starts_with("serve/") {
        check_typed_response_terminal(rel, &masked, &regions, out);
    }
    if narrowing_scope(rel) {
        check_checked_narrowing(rel, &masked, &regions, out);
    }
    if rel == "runtime/kvpool.rs" {
        check_error_tag_sync(rel, raw, out);
    }
    if rel == "cli.rs" {
        check_cli_help_sync(rel, raw, &masked, out);
    }
    for a in &masked.allows {
        if !a.justified {
            out.push(Violation {
                file: rel.to_string(),
                line: a.line,
                rule: "lint-allow",
                msg: "lint:allow without a justification — say why the \
                      invariant holds"
                    .into(),
            });
        } else if !a.used.get() {
            out.push(Violation {
                file: rel.to_string(),
                line: a.line,
                rule: "lint-allow",
                msg: format!("lint:allow({}) matches no violation — remove it", a.rule),
            });
        }
    }
}

fn run(root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut out = Vec::new();
    for path in rs_files(root)? {
        let rel: String = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let raw = fs::read_to_string(&path)?;
        check_file(&rel, &raw, &mut out);
    }
    Ok(out)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(root) = args.first() else {
        eprintln!("usage: odlri-lint <src-root>   (e.g. `cargo run -p odlri-lint -- rust/src`)");
        return ExitCode::from(2);
    };
    match run(Path::new(root)) {
        Ok(violations) if violations.is_empty() => {
            println!("odlri-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                eprintln!("{}", v.render());
            }
            eprintln!("odlri-lint: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("odlri-lint: {e}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(rel: &str, src: &str) -> Vec<Violation> {
        let mut out = Vec::new();
        check_file(rel, src, &mut out);
        out
    }

    fn rules(vs: &[Violation]) -> Vec<&str> {
        vs.iter().map(|v| v.rule).collect()
    }

    // ---- masking ----

    #[test]
    fn masks_strings_comments_and_chars() {
        let src = "let x = \"panic!\"; // panic! here\nlet c = '\\n'; /* .unwrap() */\n";
        let m = mask(src);
        let text = String::from_utf8(m.text).unwrap();
        assert!(!text.contains("panic!"), "masked: {text}");
        assert!(!text.contains(".unwrap()"), "masked: {text}");
        assert_eq!(text.len(), src.len());
        assert_eq!(text.matches('\n').count(), 2);
    }

    #[test]
    fn masks_raw_strings_and_keeps_lifetimes() {
        let src = "let s = r#\"json \"panic!\" body\"#;\nfn f<'a>(x: &'a str) {}\n";
        let m = mask(src);
        let text = String::from_utf8(m.text).unwrap();
        assert!(!text.contains("panic!"));
        assert!(text.contains("<'a>"), "lifetime survived: {text}");
    }

    // ---- rule 1: hot-path-panic ----

    #[test]
    fn flags_panics_on_the_hot_path() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
        let vs = lint("serve/mod.rs", src);
        assert_eq!(rules(&vs), ["hot-path-panic"], "{vs:?}");
        assert_eq!(vs[0].line, 2);
        // Same code outside the scope dirs is fine.
        assert!(lint("quant/mod.rs", src).is_empty());
    }

    #[test]
    fn flags_expect_todo_and_macros() {
        let src = "fn f() {\n    g().expect(\"x\");\n    todo!();\n    panic!(\"y\");\n}\n";
        let vs = lint("engine/mod.rs", src);
        assert_eq!(vs.len(), 3, "{vs:?}");
    }

    #[test]
    fn ignores_test_code_and_unwrap_or() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap_or(0)\n}\n\
                   #[cfg(test)]\nmod tests {\n    fn g(x: Option<u8>) -> u8 { x.unwrap() }\n}\n";
        assert!(lint("runtime/native.rs", src).is_empty());
    }

    #[test]
    fn allow_with_justification_passes_without_fails() {
        let ok = "fn f(x: Option<u8>) -> u8 {\n\
                  // lint:allow(hot-path-panic) x is Some by construction\n    x.unwrap()\n}\n";
        assert!(lint("serve/mod.rs", ok).is_empty());
        let bare = "fn f(x: Option<u8>) -> u8 {\n    // lint:allow(hot-path-panic)\n    x.unwrap()\n}\n";
        let vs = lint("serve/mod.rs", bare);
        assert!(rules(&vs).contains(&"lint-allow"), "{vs:?}");
        let unused = "// lint:allow(hot-path-panic) nothing here\nfn f() {}\n";
        let vs = lint("serve/mod.rs", unused);
        assert_eq!(rules(&vs), ["lint-allow"], "{vs:?}");
    }

    // ---- rule 2: checked-narrowing ----

    #[test]
    fn flags_narrowing_casts_in_readers_only() {
        let src = "fn read_from(n: u64) -> u32 {\n    n as u32\n}\n\
                   fn write_to(n: u64) -> u32 {\n    n as u32\n}\n";
        let vs = lint("quant/packed.rs", src);
        assert_eq!(rules(&vs), ["checked-narrowing"], "{vs:?}");
        assert_eq!(vs[0].line, 2);
    }

    #[test]
    fn widening_and_usize_casts_are_fine() {
        let src = "fn parse(n: u32, m: u8) -> usize {\n    n as usize + m as u64 as usize\n}\n";
        assert!(lint("runtime/manifest.rs", src).is_empty());
        // Out-of-scope file: same cast passes.
        let narrow = "fn read_from(n: u64) -> u32 {\n    n as u32\n}\n";
        assert!(lint("runtime/mod.rs", narrow).is_empty());
    }

    // ---- rule 3: error-tag-sync ----

    const TAGGED: &str = "impl KvError {\n\
        pub const POOL_EXHAUSTED_TAG: &'static str = \"kv pool exhausted\";\n\
        pub fn is_pool_exhausted(e: &anyhow::Error) -> bool { chain_has(e, Self::POOL_EXHAUSTED_TAG) }\n\
        }\nimpl Display for KvError { fn fmt(&self) { write(Self::POOL_EXHAUSTED_TAG) } }\n";

    #[test]
    fn tag_and_classifier_in_sync_is_clean() {
        assert!(lint("runtime/kvpool.rs", TAGGED).is_empty());
    }

    #[test]
    fn tag_without_classifier_fails() {
        let src = TAGGED.replace("is_pool_exhausted", "is_something_else");
        let vs = lint("runtime/kvpool.rs", &src);
        assert_eq!(vs.len(), 2, "{vs:?}"); // missing classifier + dead matcher
        assert!(vs.iter().all(|v| v.rule == "error-tag-sync"));
    }

    #[test]
    fn tag_missing_from_display_fails() {
        let src = TAGGED.replace("write(Self::POOL_EXHAUSTED_TAG)", "write(\"hardcoded\")");
        let vs = lint("runtime/kvpool.rs", &src);
        assert_eq!(rules(&vs), ["error-tag-sync"], "{vs:?}");
    }

    // ---- rule 4: cli-help-sync ----

    fn cli_src(flags: &str, help: &str) -> String {
        format!(
            "pub const COMMANDS: &[CommandSpec] = &[\n\
             CommandSpec {{ name: \"train\", flags: &[{flags}], switches: &[\"json\"] }},\n\
             ];\npub const HELP: &str = \"{help}\";\n"
        )
    }

    #[test]
    fn help_and_registry_in_sync_is_clean() {
        let src = cli_src("\"steps\", \"seed\"", "--steps N --seed S --json");
        assert!(lint("cli.rs", &src).is_empty());
    }

    #[test]
    fn undocumented_flag_fails() {
        let src = cli_src("\"steps\", \"seed\"", "--steps N --json");
        let vs = lint("cli.rs", &src);
        assert_eq!(rules(&vs), ["cli-help-sync"], "{vs:?}");
        assert!(vs[0].msg.contains("--seed"), "{vs:?}");
    }

    #[test]
    fn phantom_help_flag_fails() {
        let src = cli_src("\"steps\"", "--steps N --bogus X --json");
        let vs = lint("cli.rs", &src);
        assert_eq!(rules(&vs), ["cli-help-sync"], "{vs:?}");
        assert!(vs[0].msg.contains("--bogus"), "{vs:?}");
    }

    // ---- rule 5: lock-across-forward ----

    #[test]
    fn guard_live_across_forward_fails() {
        let src = "fn f(&self) -> Result<()> {\n\
                   let inner = self.pool.lock();\n\
                   let y = fwd_decode(&inner);\n    Ok(())\n}\n";
        let vs = lint("serve/mod.rs", src);
        assert_eq!(rules(&vs), ["lock-across-forward"], "{vs:?}");
        assert_eq!(vs[0].line, 3);
    }

    #[test]
    fn guard_dropped_before_forward_is_clean() {
        let src = "fn f(&self) -> Result<()> {\n\
                   {\n        let inner = self.pool.lock();\n        inner.touch();\n    }\n\
                   let y = fwd_decode(1);\n    Ok(())\n}\n";
        assert!(lint("serve/mod.rs", src).is_empty());
        // Non-`let` temporary guards drop at end of statement.
        let tmp = "fn f(&self) {\n    self.pool.lock().touch();\n    prefill(1);\n}\n";
        assert!(lint("serve/mod.rs", tmp).is_empty());
    }

    #[test]
    fn allowed_guard_passes() {
        let src = "fn f(&self) -> Result<()> {\n\
                   let inner = self.pool.lock();\n\
                   // lint:allow(lock-across-forward) forward never re-enters this pool\n\
                   let y = verify_step(&inner);\n    Ok(())\n}\n";
        assert!(lint("serve/mod.rs", src).is_empty());
    }

    // ---- rule 6: typed-response-terminal ----

    #[test]
    fn silent_drop_from_a_holding_area_fails() {
        let src = "fn f(&mut self) {\n\
                   let ag = self.active.swap_remove(0);\n    drop(ag);\n}\n";
        let vs = lint("serve/mod.rs", src);
        assert_eq!(rules(&vs), ["typed-response-terminal"], "{vs:?}");
        assert_eq!(vs[0].line, 2);
        // Outside serve/ the rule does not apply (other subsystems have no
        // response contract).
        assert!(lint("engine/mod.rs", src).is_empty());
    }

    #[test]
    fn removal_with_a_typed_terminal_or_repark_is_clean() {
        let finished = "fn f(&mut self) {\n\
                        let ag = self.active.swap_remove(0);\n\
                        self.finish(ag.id, ag.submitted, &ag.done, Response::TimedOut);\n}\n";
        assert!(lint("serve/mod.rs", finished).is_empty());
        let parked = "fn park(&mut self, idx: usize) {\n\
                      let ag = self.active.remove(idx);\n\
                      self.preempted.push(make_parked(ag));\n}\n";
        assert!(lint("serve/mod.rs", parked).is_empty());
        let requeued = "fn requeue(&mut self, vi: usize) {\n\
                        let v = self.prefilling.remove(vi);\n\
                        let q = &mut self.queues[v.class.index()];\n\
                        q.insert(0, rearm(v));\n}\n";
        assert!(lint("serve/mod.rs", requeued).is_empty());
        let drained = "fn tick(&mut self) {\n\
                       let done: Vec<ActiveGen> = self.active.drain(..).collect();\n\
                       for ag in done {\n        self.retire(ag);\n    }\n}\n";
        assert!(lint("serve/mod.rs", drained).is_empty());
    }

    #[test]
    fn unrelated_containers_and_allows_are_exempt() {
        // Removing from a container that is not a holding area is fine.
        let other = "fn f(&mut self) {\n    let x = self.latencies.remove(0);\n    drop(x);\n}\n";
        assert!(lint("serve/mod.rs", other).is_empty());
        // A justified allow passes (and an unused one would fail lint-allow).
        let allowed = "fn f(&mut self) {\n\
                       // lint:allow(typed-response-terminal) teardown: dropping the responder wakes the client\n\
                       let ag = self.active.swap_remove(0);\n    drop(ag);\n}\n";
        assert!(lint("serve/mod.rs", allowed).is_empty());
    }

    #[test]
    fn terminal_in_a_different_function_does_not_count() {
        // The finish lives in `g`, not in `f` where the removal happens —
        // the same-function requirement must flag `f`.
        let src = "fn f(&mut self) {\n\
                   let ag = self.active.swap_remove(0);\n    drop(ag);\n}\n\
                   fn g(&mut self) {\n    self.finish(0, t, &d, Response::Aborted);\n}\n";
        let vs = lint("serve/mod.rs", src);
        assert_eq!(rules(&vs), ["typed-response-terminal"], "{vs:?}");
    }

    // ---- the live tree ----

    #[test]
    fn live_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
        if !root.exists() {
            return; // sparse checkout: nothing to scan
        }
        let vs = run(&root).expect("scanning rust/src");
        let report: Vec<String> = vs.iter().map(|v| v.render()).collect();
        assert!(vs.is_empty(), "live tree has violations:\n{}", report.join("\n"));
    }
}
