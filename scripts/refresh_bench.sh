#!/usr/bin/env bash
# Refresh the committed bench snapshots (BENCH_kernels.json /
# BENCH_runtime.json / BENCH_serve.json) in place. Run from anywhere
# inside the repo; needs a Rust toolchain. CI runs the same bench
# commands, fails if any JSON still carries the placeholder empty
# `entries` array, and on pushes to main the `bench-commit` job commits
# the refreshed files back automatically from the `bench-json` artifact
# — so committing by hand is only needed off-main.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo bench --bench bench_kernels -- --fast decode
cargo bench --bench bench_runtime -- --fast
cargo bench --bench bench_serve -- --fast

for f in BENCH_kernels.json BENCH_runtime.json BENCH_serve.json; do
  if python3 -c "import json,sys; sys.exit(0 if json.load(open('$f'))['entries'] else 1)"; then
    echo "refreshed $f"
  else
    echo "error: $f still has an empty entries array after the bench run" >&2
    exit 1
  fi
done
