#!/usr/bin/env bash
# Refresh the committed bench snapshots (BENCH_kernels.json /
# BENCH_runtime.json) in place. Run from anywhere inside the repo; needs
# a Rust toolchain. CI runs the same two bench commands and fails if the
# JSON still carries the placeholder empty `entries` arrays, so commit
# the refreshed files (or take them from the CI `bench-json` artifact).
set -euo pipefail
cd "$(dirname "$0")/.."

cargo bench --bench bench_kernels -- --fast decode
cargo bench --bench bench_runtime -- --fast

for f in BENCH_kernels.json BENCH_runtime.json; do
  if python3 -c "import json,sys; sys.exit(0 if json.load(open('$f'))['entries'] else 1)"; then
    echo "refreshed $f"
  else
    echo "error: $f still has an empty entries array after the bench run" >&2
    exit 1
  fi
done
