//! Ablation: how the number of outlier columns k affects ODLRI (Table 5's
//! question, swept finely at the matrix level — no artifacts needed).
//!
//! ```bash
//! cargo run --release --example ablation_k
//! ```

use odlri::calib::{synthetic_calib, synthetic_weight};
use odlri::decompose::{Initializer, JointConfig, JointOptimizer};
use odlri::lowrank::LowRankConfig;
use odlri::quant::E8Lattice;

fn main() {
    let n = 128;
    let rank = 16;
    let true_outliers = 4;
    let calib = synthetic_calib(n, 4 * n, true_outliers, 20.0, 7);
    let w = synthetic_weight(128, n, &calib.outlier_channels, 7);
    let quant = E8Lattice::new(2);

    println!("true outlier channels: {:?}", calib.outlier_channels);
    println!("paper's schedule k = {}", Initializer::odlri_k(rank, n));
    println!("\n{:>5} {:>14} {:>14}", "k", "act-err", "quant-scale");
    for k in [1usize, 2, 4, 8, 12, 16] {
        let cfg = JointConfig {
            outer_iters: 8,
            lowrank: LowRankConfig {
                rank,
                lr_bits: 4,
                lplr_iters: 5,
                reg: 1e-4,
            },
            ..Default::default()
        };
        let opt = JointOptimizer::new(&quant, cfg);
        let d = opt.run(&w, &calib.hessian, &Initializer::Odlri { k });
        let last = d.metrics.last().unwrap();
        let marker = if k == true_outliers { "  ← true count" } else { "" };
        println!(
            "{k:>5} {:>14.4e} {:>14.5}{marker}",
            last.act_err, last.quant_scale
        );
    }
    println!(
        "\nExpected shape (paper §4.4): small k < r concentrates the LR\n\
         budget on true outliers and wins; k = r spreads it thin."
    );
}
