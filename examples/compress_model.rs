//! END-TO-END DRIVER (DESIGN.md §4 `e2e`): the full three-layer system on a
//! real small workload.
//!
//! 1. **Train** a ~0.9M-param Llama-style model (`tl-7s`) from scratch by
//!    driving the AOT `train_tl-7s` HLO artifact (Layer-2 AdamW step) from
//!    Rust, logging the loss curve.
//! 2. **Inject outliers** (function-preserving; simulates the 7B-scale
//!    activation-outlier phenomenon).
//! 3. **Calibrate** per-matrix Hessians through the `capture_tl-7s`
//!    artifact.
//! 4. **Compress** with CALDERA (zero-init) vs CALDERA+ODLRI.
//! 5. **Evaluate** perplexity + 5 zero-shot proxies for FP32 / both methods.
//!
//! ```bash
//! make artifacts && cargo run --release --example compress_model
//! ```
//! Results land in results/e2e.md; the run is recorded in EXPERIMENTS.md.

use std::path::Path;

use odlri::calib::{calibrate, CalibConfig};
use odlri::coordinator::{
    BudgetPlanner, CompressionPipeline, InitKind, PipelineConfig, Planner,
};
use odlri::engine::NativeEngine;
use odlri::eval::evaluate;
use odlri::model::inject_outliers;
use odlri::report::Table;
use odlri::runtime::XlaRuntime;
use odlri::train::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(250);
    let rt = XlaRuntime::open(&odlri::runtime::default_artifact_dir())?;

    // ---- 1. train --------------------------------------------------------
    eprintln!("[e2e] training tl-7s for {steps} steps via AOT train_step…");
    let t0 = std::time::Instant::now();
    let tr = train(
        &rt,
        &TrainConfig {
            family: "tl-7s".into(),
            steps,
            seed: 0,
            log_every: 25,
            ..Default::default()
        },
    )?;
    let train_secs = t0.elapsed().as_secs_f64();
    let mut params = tr.params;
    println!(
        "loss curve: {} → {:.3} (final), {:.2} s/step",
        tr.losses
            .iter()
            .step_by((steps / 8).max(1))
            .map(|(s, l)| format!("{s}:{l:.2}"))
            .collect::<Vec<_>>()
            .join(" "),
        tr.losses.last().unwrap().1,
        train_secs / steps as f64
    );

    // ---- 2. outlier injection -------------------------------------------
    let planted = inject_outliers(&mut params, 4, 16.0, 0)?;
    eprintln!(
        "[e2e] planted outliers, e.g. {} → channels {:?}",
        planted[0].0, planted[0].1
    );

    // ---- 3. calibrate ----------------------------------------------------
    eprintln!("[e2e] calibrating Hessians…");
    let hessians = calibrate(&rt, &params, &CalibConfig { batches: 8, seed: 0 })?;

    // ---- 4+5. compress & evaluate ---------------------------------------
    let mut table = Table::new(
        "End-to-end: tl-7s, Q 2-bit E8 + LR 4-bit, rank 16",
        &[
            "Method", "AvgBits", "Wiki-sim", "C4-sim", "Wino", "RTE", "PiQA",
            "ArcE", "ArcC", "Compress s",
        ],
    );
    eprintln!("[e2e] evaluating FP32 baseline…");
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let base = evaluate(&NativeEngine::new(&params, batch, seq)?, 30, 64, 1000)?;
    let taskfmt = |r: &odlri::eval::EvalReport| -> Vec<String> {
        r.tasks.iter().map(|t| format!("{:.1}", t.accuracy * 100.0)).collect()
    };
    let mut row = vec![
        "FP32".to_string(),
        "32".into(),
        format!("{:.3}", base.ppl_wiki),
        format!("{:.3}", base.ppl_c4),
    ];
    row.extend(taskfmt(&base));
    row.push("-".into());
    table.row(row);

    for init in [InitKind::Caldera, InitKind::Odlri] {
        eprintln!("[e2e] compressing with {}…", init.name());
        let cfg = PipelineConfig {
            init: init.clone(),
            rank: 16,
            lr_bits: 4,
            outer_iters: 15,
            lplr_iters: 10,
            verbose: true,
            ..Default::default()
        };
        let out = CompressionPipeline::new(cfg).run(&params, &hessians)?;
        let applied = out.model.apply_to(&params)?;
        let rep = evaluate(&NativeEngine::new(&applied, batch, seq)?, 30, 64, 1000)?;
        let label = match init {
            InitKind::Caldera => "CALDERA",
            _ => "+ODLRI",
        };
        let mut row = vec![
            label.to_string(),
            format!("{:.2}", out.model.avg_bits()),
            format!("{:.3}", rep.ppl_wiki),
            format!("{:.3}", rep.ppl_c4),
        ];
        row.extend(taskfmt(&rep));
        row.push(format!("{:.1}", out.wall_secs));
        table.row(row);
    }

    // Per-projection budget plan: same base recipe, but the planner's
    // Hessian-diagonal probe decides which projections get the rank/bits.
    let budget = 2.5;
    eprintln!("[e2e] compressing with a budget-{budget} per-projection plan…");
    let base = PipelineConfig {
        init: InitKind::Odlri,
        rank: 16,
        lr_bits: 4,
        outer_iters: 15,
        lplr_iters: 10,
        verbose: true,
        ..Default::default()
    };
    let plan = BudgetPlanner::new(budget, base.clone()).plan(&params, &hessians)?;
    plan.table(&params.family)?.print();
    let out = CompressionPipeline::new(base).run_plan(&params, &hessians, &plan)?;
    let applied = out.model.apply_to(&params)?;
    let rep = evaluate(&NativeEngine::new(&applied, batch, seq)?, 30, 64, 1000)?;
    let mut row = vec![
        format!("+ODLRI@{budget}b"),
        format!("{:.2}", out.model.avg_bits()),
        format!("{:.3}", rep.ppl_wiki),
        format!("{:.3}", rep.ppl_c4),
    ];
    row.extend(taskfmt(&rep));
    row.push(format!("{:.1}", out.wall_secs));
    table.row(row);

    table.print();
    table.save(Path::new("results"), "e2e")?;
    // Persist the loss curve too.
    let curve: String = tr.losses.iter().map(|(s, l)| format!("{s},{l}\n")).collect();
    std::fs::write("results/e2e_losscurve.csv", format!("step,loss\n{curve}"))?;
    println!("saved results/e2e.md and results/e2e_losscurve.csv");
    Ok(())
}
