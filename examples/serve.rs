//! Serving example: a threaded batch server over the compressed model.
//!
//! Client threads submit single-sequence generation-scoring requests; the
//! leader batches them up to the artifact's batch size (dynamic batching
//! with a deadline, vLLM-router-style) and executes the `fwd_tl-7s`
//! artifact. Reports p50/p95 latency and throughput.
//!
//! ```bash
//! cargo run --release --example serve -- 200   # number of requests
//! ```

use std::sync::mpsc;
use std::time::{Duration, Instant};

use odlri::corpus;
use odlri::model::ModelParams;
use odlri::runtime::{Value, XlaRuntime};
use odlri::util::rng::Pcg64;

struct Request {
    tokens: Vec<i32>, // length = seq
    done: mpsc::Sender<f32>, // mean NLL of the sequence (the "score")
    submitted: Instant,
}

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(120);
    let rt = XlaRuntime::open(&odlri::runtime::default_artifact_dir())?;
    let fam = rt.manifest.family("tl-7s")?.clone();
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);

    // Use trained weights if the e2e run produced them, else random init
    // (the serving path is identical either way).
    let params = std::fs::metadata("runs/tl-7s.odw")
        .ok()
        .and_then(|_| ModelParams::load(&fam, std::path::Path::new("runs/tl-7s.odw")).ok())
        .unwrap_or_else(|| ModelParams::init(&fam, 1));
    rt.warm("fwd_tl-7s")?;

    let (tx, rx) = mpsc::channel::<Request>();
    let mut latencies: Vec<f64> = Vec::new();
    let t_start = Instant::now();

    std::thread::scope(|s| -> anyhow::Result<()> {
        // Client threads: each submits a burst of requests with jitter.
        let n_clients = 4;
        for c in 0..n_clients {
            let tx = tx.clone();
            s.spawn(move || {
                let mut rng = Pcg64::new(c as u64, 77);
                let data = corpus::generate(corpus::Split::C4Sim, 200_000, c as u64);
                let per_client = n_requests / n_clients;
                for _ in 0..per_client {
                    let start = rng.below(data.len() - seq - 1);
                    let tokens: Vec<i32> =
                        data[start..start + seq].iter().map(|&b| b as i32).collect();
                    let (dtx, drx) = mpsc::channel();
                    tx.send(Request {
                        tokens,
                        done: dtx,
                        submitted: Instant::now(),
                    })
                    .ok();
                    // Wait for completion (closed-loop client).
                    let _score = drx.recv().ok();
                    std::thread::sleep(Duration::from_millis(rng.below(5) as u64));
                }
            });
        }
        drop(tx);

        // Leader: dynamic batcher. Collect up to `batch` requests or 10 ms.
        let deadline = Duration::from_millis(10);
        let mut pending: Vec<Request> = Vec::new();
        loop {
            let req = if pending.is_empty() {
                match rx.recv() {
                    Ok(r) => Some(r),
                    Err(_) => break, // all clients done
                }
            } else {
                match rx.recv_timeout(deadline) {
                    Ok(r) => Some(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => None,
                }
            };
            if let Some(r) = req {
                pending.push(r);
                if pending.len() < batch {
                    continue;
                }
            }
            if pending.is_empty() {
                break;
            }
            // Build the batch (pad by repeating the first request).
            let mut tokens = Vec::with_capacity(batch * seq);
            for b in 0..batch {
                let r = pending.get(b).unwrap_or(&pending[0]);
                tokens.extend(&r.tokens);
            }
            let mut inputs = params.values.clone();
            inputs.push(Value::from_vec_i32(vec![batch, seq], tokens));
            let outs = rt.exec("fwd_tl-7s", &inputs)?;
            let logits = outs[0].to_matrix_2d()?;
            for (b, r) in pending.drain(..).enumerate() {
                // Mean NLL over the sequence = the response payload.
                let mut nll = 0f64;
                for t in 0..seq - 1 {
                    let row = logits.row(b * seq + t);
                    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v)) as f64;
                    let lse: f64 = row.iter().map(|&v| (v as f64 - mx).exp()).sum::<f64>().ln() + mx;
                    nll += lse - row[r.tokens[t + 1] as usize] as f64;
                }
                latencies.push(r.submitted.elapsed().as_secs_f64());
                r.done.send((nll / (seq - 1) as f64) as f32).ok();
            }
        }
        Ok(())
    })?;

    let total = t_start.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = latencies.len();
    println!(
        "served {n} requests in {total:.2}s  ({:.0} req/s, {:.0} tok/s)",
        n as f64 / total,
        (n * seq) as f64 / total
    );
    println!(
        "latency p50 = {:.1} ms   p95 = {:.1} ms   max = {:.1} ms",
        latencies[n / 2] * 1e3,
        latencies[(n as f64 * 0.95) as usize % n] * 1e3,
        latencies[n - 1] * 1e3
    );
    Ok(())
}
