//! Serving example: the continuous-batching server from [`odlri::serve`]
//! over either engine.
//!
//! Client threads submit typed requests; the leader admits them FIFO,
//! groups equal-length scoring requests into variable-size batches, and
//! advances every in-flight generation session one token per step against
//! its KV cache (vLLM-style continuous batching). Runs artifact-free on
//! the native engine; add `--fused` to serve the bit-packed `(Q+LR)·x`
//! engine, `--generate` for the incremental-decoding workload.
//!
//! ```bash
//! cargo run --release --example serve -- 200              # score, dense
//! cargo run --release --example serve -- 200 --fused      # packed engine
//! cargo run --release --example serve -- 60 --fused --generate
//! ```

use odlri::engine::{Engine, NativeEngine};
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::Runtime;
use odlri::serve::{run_server, ServeConfig, Workload};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = argv
        .iter()
        .find_map(|a| a.parse().ok())
        .unwrap_or(120);
    let fused = argv.iter().any(|a| a == "--fused");
    let generate = argv.iter().any(|a| a == "--generate");

    let rt = Runtime::open(&odlri::runtime::default_artifact_dir())?;
    if rt.is_native() {
        eprintln!("[serve] native engine (no XLA artifacts needed)");
    }
    let fam = rt.manifest.family("tl-7s")?.clone();
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);

    // Use trained weights if the e2e run produced them, else random init
    // (the serving path is identical either way).
    let params = std::fs::metadata("runs/tl-7s.odw")
        .ok()
        .and_then(|_| ModelParams::load(&fam, std::path::Path::new("runs/tl-7s.odw")).ok())
        .unwrap_or_else(|| ModelParams::init(&fam, 1));

    let engine: Box<dyn Engine> = if fused {
        // Pack the projections at 8 bits (near-lossless) and serve the
        // dequant-on-the-fly kernels — no dense W is ever materialized.
        let fm = FusedModel::pack_dense(&params, "uniform", 8, 64)?.with_shape(batch, seq);
        eprintln!(
            "[serve] fused engine: {:.2} bits/weight packed ({} total)",
            fm.avg_bits(),
            odlri::util::human_bytes(fm.packed_bytes())
        );
        Box::new(fm)
    } else {
        Box::new(NativeEngine::new(&params, batch, seq)?)
    };

    let cfg = ServeConfig {
        requests: n_requests,
        clients: 4,
        workload: if generate {
            Workload::Generate { max_new_tokens: 16 }
        } else {
            Workload::Score
        },
        prompt_len: if generate { 32 } else { 0 },
        ..Default::default()
    };
    let report = run_server(engine.as_ref(), &cfg)?;

    let n = report.completed.len();
    println!(
        "served {n} requests in {:.2}s  ({:.0} req/s; {} forwards + {} decode steps)",
        report.wall_secs,
        report.requests_per_sec(),
        report.batches,
        report.decode_steps
    );
    println!(
        "request latency p50 = {:.1} ms   p95 = {:.1} ms",
        report.p50_ms(),
        report.p95_ms()
    );
    if generate {
        println!(
            "generated {} tokens ({} via KV-cached decode at {:.0} tok/s; per-step p50 {:.2} ms)",
            report.generated_tokens,
            report.decoded_tokens,
            report.decode_tokens_per_sec(),
            report.decode_p50_ms()
        );
    } else {
        let finite = report.scores.iter().filter(|s| s.is_finite()).count();
        println!("finite scores: {finite}/{n}");
    }
    Ok(())
}
