//! Serving example: the threaded dynamic-batching server from
//! [`odlri::serve`] over either forward path.
//!
//! Client threads submit single-sequence scoring requests; the leader
//! batches them up to the model's batch size (deadline-based dynamic
//! batching, vLLM-router-style) and executes one forward per batch.
//! Runs artifact-free on the native engine; add `--fused` to serve the
//! bit-packed `(Q+LR)·x` engine instead of dense weights.
//!
//! ```bash
//! cargo run --release --example serve -- 200           # dense, 200 requests
//! cargo run --release --example serve -- 200 --fused   # packed fused engine
//! ```

use odlri::eval::RuntimeForward;
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::Runtime;
use odlri::serve::{run_batch_server, ServeConfig};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = argv
        .iter()
        .find_map(|a| a.parse().ok())
        .unwrap_or(120);
    let fused = argv.iter().any(|a| a == "--fused");

    let rt = Runtime::open(&odlri::runtime::default_artifact_dir())?;
    if rt.is_native() {
        eprintln!("[serve] native engine (no XLA artifacts needed)");
    }
    let fam = rt.manifest.family("tl-7s")?.clone();

    // Use trained weights if the e2e run produced them, else random init
    // (the serving path is identical either way).
    let params = std::fs::metadata("runs/tl-7s.odw")
        .ok()
        .and_then(|_| ModelParams::load(&fam, std::path::Path::new("runs/tl-7s.odw")).ok())
        .unwrap_or_else(|| ModelParams::init(&fam, 1));

    let cfg = ServeConfig {
        requests: n_requests,
        clients: 4,
        ..Default::default()
    };
    let report = if fused {
        // Pack the projections at 8 bits (near-lossless) and serve the
        // dequant-on-the-fly kernels — no dense W is ever materialized.
        let fm = FusedModel::pack_dense(&params, "uniform", 8, 64)?;
        eprintln!(
            "[serve] fused engine: {:.2} bits/weight packed ({} total)",
            fm.avg_bits(),
            odlri::util::human_bytes(fm.packed_bytes())
        );
        run_batch_server(&fm, &cfg)?
    } else {
        rt.warm("fwd_tl-7s")?;
        let fwd = RuntimeForward {
            rt: &rt,
            params: &params,
        };
        run_batch_server(&fwd, &cfg)?
    };

    let n = report.scores.len();
    let seq = rt.manifest.seq;
    println!(
        "served {n} requests in {:.2}s  ({:.0} req/s, {:.0} tok/s)",
        report.wall_secs,
        report.requests_per_sec(),
        report.requests_per_sec() * seq as f64
    );
    println!(
        "latency p50 = {:.1} ms   p95 = {:.1} ms   batches = {}",
        report.p50_ms(),
        report.p95_ms(),
        report.batches
    );
    let finite = report.scores.iter().filter(|s| s.is_finite()).count();
    println!("finite scores: {finite}/{n}");
    Ok(())
}
