//! Quickstart: decompose one weight matrix three ways and watch the roles.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — this exercises the pure-Rust algorithm layer on a
//! synthetic problem with planted activation outliers (the phenomenon
//! ODLRI exploits). It prints the per-iteration quantization scale and
//! activation-aware error for Zero / LRApprox(W) / ODLRI initializations.

use odlri::calib::{synthetic_calib, synthetic_weight};
use odlri::decompose::{Initializer, JointConfig, JointOptimizer};
use odlri::lowrank::LowRankConfig;
use odlri::quant::E8Lattice;

fn main() {
    // A 128×128 "key projection" with 4 outlier channels boosted ~20×.
    let calib = synthetic_calib(128, 512, 4, 20.0, 42);
    let w = synthetic_weight(128, 128, &calib.outlier_channels, 42);
    println!(
        "problem: 128x128 weight, outlier channels {:?}",
        calib.outlier_channels
    );

    let quant = E8Lattice::new(2);
    let rank = 8;
    let k = Initializer::odlri_k(rank, 128).max(4);
    let inits = [
        Initializer::Zero,
        Initializer::LrApproxW,
        Initializer::Odlri { k },
    ];

    println!("\n{:<12} {:>5} {:>12} {:>12} {:>8} {:>8}",
             "init", "iter", "quant-scale", "act-err", "|QX|", "|LRX|");
    for init in &inits {
        let cfg = JointConfig {
            outer_iters: 8,
            lowrank: LowRankConfig {
                rank,
                lr_bits: 4,
                lplr_iters: 5,
                reg: 1e-4,
            },
            ..Default::default()
        };
        let opt = JointOptimizer::new(&quant, cfg);
        let d = opt.run(&w, &calib.hessian, init);
        for it in d.metrics.iterations().skip(1) {
            println!(
                "{:<12} {:>5} {:>12.5} {:>12.4e} {:>8.3} {:>8.3}",
                init.name(),
                it.iter,
                it.quant_scale,
                it.act_err,
                it.q_norm,
                it.lr_norm
            );
        }
        let last = d.metrics.last().unwrap();
        println!(
            "{:<12} final: act-err {:.4e}, reconstruction rel-err {:.4}\n",
            init.name(),
            last.act_err,
            d.reconstruct().rel_err(&w)
        );
    }
    println!("Expected shape: ODLRI shows the lowest quant-scale and act-err");
    println!("at every iteration (the paper's Figures 2–3).");
}
