//! Calibration Hessians `H = X Xᵀ` and the paper's outlier-restricted
//! submatrix `H_o` (Eq. 1).
//!
//! For a linear layer `y = W x` with inputs `x ∈ ℝⁿ`, the activation-aware
//! error `‖(W−Ŵ)X‖²_F = tr((W−Ŵ) H (W−Ŵ)ᵀ)` depends on X only through
//! `H = X Xᵀ`. The calibration driver accumulates H streaming over batches;
//! ODLRI then selects the top-k diagonal entries (the outlier channels 𝓘)
//! and zeroes everything outside 𝓘×𝓘 to form `H_o`.

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// A symmetric PSD calibration Hessian with sample bookkeeping.
#[derive(Clone, Debug)]
pub struct Hessian {
    h: Matrix,
    /// Number of activation samples accumulated.
    pub samples: usize,
}

impl Hessian {
    pub fn zeros(n: usize) -> Hessian {
        Hessian {
            h: Matrix::zeros(n, n),
            samples: 0,
        }
    }

    /// Build directly from an activation matrix X (n × d; columns are
    /// samples).
    pub fn from_acts(x: &Matrix) -> Hessian {
        Hessian {
            h: x.dot_t(&x),
            samples: x.cols(),
        }
    }

    /// Wrap an existing symmetric matrix.
    pub fn from_matrix(h: Matrix, samples: usize) -> Result<Hessian> {
        if h.rows() != h.cols() {
            bail!("Hessian must be square, got {}x{}", h.rows(), h.cols());
        }
        Ok(Hessian { h, samples })
    }

    /// Streaming accumulation: H += X Xᵀ for a batch X (n × d).
    pub fn accumulate(&mut self, x: &Matrix) {
        assert_eq!(x.rows(), self.h.rows(), "activation dim mismatch");
        let xxt = x.dot_t(&x);
        self.h.add_assign(&xxt);
        self.samples += x.cols();
    }

    pub fn dim(&self) -> usize {
        self.h.rows()
    }

    pub fn matrix(&self) -> &Matrix {
        &self.h
    }

    /// Mean-normalized Hessian (divide by sample count) — scale-stable
    /// across calibration sizes.
    pub fn normalized(&self) -> Matrix {
        if self.samples == 0 {
            return self.h.clone();
        }
        self.h.scale(1.0 / self.samples as f32)
    }

    /// H + λ·mean(diag)·I (CALDERA's regularization convention).
    pub fn regularized(&self, lambda: f32) -> Matrix {
        let n = self.dim();
        let mean_diag = {
            let s: f64 = (0..n).map(|i| self.h.at(i, i) as f64).sum();
            (s / n.max(1) as f64) as f32
        };
        let mut out = self.h.clone();
        let jit = lambda * mean_diag.max(1e-12);
        for i in 0..n {
            *out.at_mut(i, i) += jit;
        }
        out
    }

    /// Indices 𝓘 of the top-k diagonal entries — the outlier-sensitive
    /// channels (paper App. B.2 selects k = p·n of them). Returned sorted
    /// ascending for deterministic masking.
    pub fn topk_diag(&self, k: usize) -> Vec<usize> {
        let n = self.dim();
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            self.h
                .at(b, b)
                .partial_cmp(&self.h.at(a, a))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut top: Vec<usize> = idx[..k].to_vec();
        top.sort_unstable();
        top
    }

    /// The restricted Hessian H_o of Eq. 1: (H_o)_ij = H_ij for i,j ∈ 𝓘,
    /// 0 otherwise. Full n×n shape.
    pub fn restricted(&self, idx: &[usize]) -> Matrix {
        let n = self.dim();
        let mut mask = vec![false; n];
        for &i in idx {
            mask[i] = true;
        }
        Matrix::from_fn(n, n, |i, j| {
            if mask[i] && mask[j] {
                self.h.at(i, j)
            } else {
                0.0
            }
        })
    }

    /// The dense k×k submatrix H[𝓘, 𝓘] (what the whitening actually
    /// factorizes — the zero-padded version has rank ≤ k by construction).
    pub fn submatrix(&self, idx: &[usize]) -> Matrix {
        let k = idx.len();
        Matrix::from_fn(k, k, |a, b| self.h.at(idx[a], idx[b]))
    }

    // ---- serialization ----

    pub fn write_to(&self, w: &mut impl std::io::Write) -> Result<()> {
        w.write_all(b"ODH1")?;
        w.write_all(&(self.samples as u64).to_le_bytes())?;
        self.h.write_to(w)
    }

    pub fn read_from(r: &mut impl std::io::Read) -> Result<Hessian> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"ODH1" {
            bail!("bad hessian magic");
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let samples = u64::from_le_bytes(b8) as usize;
        let h = Matrix::read_from(r)?;
        Hessian::from_matrix(h, samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Pcg64;

    #[test]
    fn accumulate_matches_batch() {
        let mut rng = Pcg64::new(140, 1);
        let x = Matrix::randn(16, 40, 1.0, &mut rng);
        let whole = Hessian::from_acts(&x);
        let mut streamed = Hessian::zeros(16);
        streamed.accumulate(&x.slice(0, 16, 0, 15));
        streamed.accumulate(&x.slice(0, 16, 15, 40));
        assert_eq!(streamed.samples, 40);
        assert!(streamed.matrix().rel_err(whole.matrix()) < 1e-4);
    }

    #[test]
    fn topk_finds_planted_outliers() {
        testing::quick("topk-outliers", |rng| {
            let n = testing::gen_dim(rng, 16, 48);
            let k = testing::gen_dim(rng, 1, 4);
            let (x, planted) = testing::gen_outlier_acts(rng, n, 3 * n, k);
            let h = Hessian::from_acts(&x);
            assert_eq!(h.topk_diag(k), planted);
        });
    }

    #[test]
    fn restricted_matches_eq1() {
        let mut rng = Pcg64::new(141, 1);
        let x = Matrix::randn(10, 30, 1.0, &mut rng);
        let h = Hessian::from_acts(&x);
        let idx = vec![2usize, 5, 7];
        let ho = h.restricted(&idx);
        for i in 0..10 {
            for j in 0..10 {
                let expect = if idx.contains(&i) && idx.contains(&j) {
                    h.matrix().at(i, j)
                } else {
                    0.0
                };
                assert_eq!(ho.at(i, j), expect);
            }
        }
        // H_o must equal X_o X_oᵀ where X_o keeps only rows 𝓘 (App. B.1).
        let xo = x.mask_rows(&idx);
        assert!(ho.rel_err(&xo.dot_t(&xo)) < 1e-4);
    }

    #[test]
    fn submatrix_is_dense_block() {
        let mut rng = Pcg64::new(142, 1);
        let x = Matrix::randn(8, 24, 1.0, &mut rng);
        let h = Hessian::from_acts(&x);
        let idx = vec![1usize, 3, 6];
        let sub = h.submatrix(&idx);
        assert_eq!(sub.shape(), (3, 3));
        for a in 0..3 {
            for b in 0..3 {
                assert_eq!(sub.at(a, b), h.matrix().at(idx[a], idx[b]));
            }
        }
    }

    #[test]
    fn regularization_makes_pd() {
        // Rank-deficient H (fewer samples than dims).
        let mut rng = Pcg64::new(143, 1);
        let x = Matrix::randn(20, 5, 1.0, &mut rng);
        let h = Hessian::from_acts(&x);
        assert!(crate::linalg::cholesky(h.matrix()).is_err());
        let reg = h.regularized(1e-4);
        assert!(crate::linalg::cholesky(&reg).is_ok());
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Pcg64::new(144, 1);
        let x = Matrix::randn(12, 20, 1.0, &mut rng);
        let h = Hessian::from_acts(&x);
        let mut buf = Vec::new();
        h.write_to(&mut buf).unwrap();
        let back = Hessian::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.samples, 20);
        assert!(back.matrix().rel_err(h.matrix()) == 0.0);
    }
}
