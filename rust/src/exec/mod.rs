//! Minimal work-stealing-free thread pool (std-only).
//!
//! The coordinator fans per-matrix decomposition jobs out over this pool.
//! Jobs are indexed; results are returned in job order regardless of
//! completion order, so pipeline output is deterministic and independent of
//! the worker count (proptested in `coordinator`).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `f(i)` for every i in 0..n across `workers` threads and collect the
/// results in index order. Panics in a job propagate to the caller.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = Arc::new(Mutex::new(0usize));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let f = &f;
            s.spawn(move || loop {
                let i = {
                    let mut g = next.lock().unwrap();
                    if *g >= n {
                        return;
                    }
                    let i = *g;
                    *g += 1;
                    i
                };
                let out = f(i);
                if tx.send((i, out)).is_err() {
                    return;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            slots[i] = Some(v);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, v)| v.unwrap_or_else(|| panic!("job {i} produced no result (worker panic)")))
            .collect()
    })
}

/// Fire-and-collect variant with a progress callback invoked on the caller
/// thread as results arrive (used for pipeline progress lines).
pub fn parallel_map_progress<T, F, P>(n: usize, workers: usize, f: F, mut progress: P) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    P: FnMut(usize, &T),
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    let next = Arc::new(Mutex::new(0usize));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let f = &f;
            s.spawn(move || loop {
                let i = {
                    let mut g = next.lock().unwrap();
                    if *g >= n {
                        return;
                    }
                    let i = *g;
                    *g += 1;
                    i
                };
                let out = f(i);
                if tx.send((i, out)).is_err() {
                    return;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            progress(i, &v);
            slots[i] = Some(v);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, v)| v.unwrap_or_else(|| panic!("job {i} produced no result (worker panic)")))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = parallel_map(250, 7, |i| {
            counter.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(counter.load(Ordering::SeqCst), 250);
        assert_eq!(out.len(), 250);
    }

    #[test]
    fn independent_of_worker_count() {
        let a = parallel_map(37, 1, |i| i as f64 * 1.5);
        let b = parallel_map(37, 4, |i| i as f64 * 1.5);
        let c = parallel_map(37, 16, |i| i as f64 * 1.5);
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    fn zero_jobs() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn progress_sees_every_job() {
        let mut seen = vec![false; 64];
        parallel_map_progress(64, 5, |i| i, |i, &v| {
            assert_eq!(i, v);
            seen[i] = true;
        });
        assert!(seen.iter().all(|&s| s));
    }
}
