//! Low-rank approximation: plain truncated SVD, activation-aware whitened
//! SVD (the `LRApprox` step of Algorithm 1), and the LPLR low-precision
//! factorization (Saha et al. 2023) used when `L`, `R` are quantized to
//! 4-bit (paper §4.1: 10 inner iterations).

use crate::linalg::{cholesky_jittered, solve_lower_transpose, truncated_svd};
use crate::quant::{Quantizer, UniformQuantizer};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// A rank-r factor pair `A ≈ L R` with L (m×r), R (r×n).
#[derive(Clone, Debug)]
pub struct LrPair {
    pub l: Matrix,
    pub r: Matrix,
}

impl LrPair {
    pub fn zeros(m: usize, n: usize, rank: usize) -> LrPair {
        LrPair {
            l: Matrix::zeros(m, rank),
            r: Matrix::zeros(rank, n),
        }
    }

    pub fn rank(&self) -> usize {
        self.l.cols()
    }

    pub fn product(&self) -> Matrix {
        self.l.dot(&self.r)
    }

    /// ‖L R X‖_F without materializing LR (two skinny products).
    pub fn act_norm(&self, x: &Matrix) -> f32 {
        self.l.dot(&self.r.dot(x)).frob_norm()
    }
}

/// Configuration for the LRApprox step.
#[derive(Clone, Debug)]
pub struct LowRankConfig {
    pub rank: usize,
    /// 16 = keep factors in full precision; < 16 quantizes both factors
    /// (uniform, per-row groups) via LPLR alternation.
    pub lr_bits: u32,
    /// LPLR inner iterations (paper default 10 when lr_bits = 4).
    pub lplr_iters: usize,
    /// Hessian regularization λ.
    pub reg: f32,
}

impl Default for LowRankConfig {
    fn default() -> Self {
        LowRankConfig {
            rank: 64,
            lr_bits: 16,
            lplr_iters: 10,
            reg: 1e-4,
        }
    }
}

/// Plain (activation-agnostic) truncated-SVD factorization.
pub fn svd_lr(a: &Matrix, rank: usize, rng: &mut Pcg64) -> LrPair {
    let svd = truncated_svd(a, rank, rng);
    let (l, r) = svd.split_lr();
    LrPair { l, r }
}

/// Activation-aware whitened SVD (SVD-LLM-style):
/// minimize ‖(A − LR) S‖_F with H = S Sᵀ ⇒ SVD(A S) truncated to r, then
/// `L = U√Σ`, `R = √Σ Vᵀ S⁻¹`.
///
/// `h` must be the (already regularized) n×n Hessian.
pub fn whitened_svd_lr(a: &Matrix, h: &Matrix, rank: usize, rng: &mut Pcg64) -> LrPair {
    let (s, _lam) = cholesky_jittered(h, 1e-6).expect("whitening cholesky failed");
    let b = a.dot(&s);
    let svd = truncated_svd(&b, rank, rng);
    let (l, rt) = svd.split_lr(); // rt = √Σ Vᵀ, shape (r × n)
    // R = rt S⁻¹ ⇔ R Sᵀ... careful: solve R S = rt for R: Sᵀ Rᵀ = rtᵀ.
    let r_t = solve_lower_transpose(&s, &rt.transpose()); // (n × r)
    LrPair {
        l,
        r: r_t.transpose(),
    }
}

/// The `LRApprox` step of Algorithm 1: whitened SVD, then (optionally) LPLR
/// alternation with quantized factors.
pub fn lr_approx(a: &Matrix, h: &Matrix, cfg: &LowRankConfig, rng: &mut Pcg64) -> LrPair {
    let init = whitened_svd_lr(a, h, cfg.rank, rng);
    if cfg.lr_bits >= 16 {
        return init;
    }
    lplr(a, h, init, cfg)
}

/// LPLR: alternate between quantizing one factor and re-solving the other
/// against the activation-aware objective, keeping the best iterate.
///
/// Fix L (quantized): minimize ‖(A − L R) S‖ over R ⇒ with B = A S and
/// R̃ = R S, R̃* = argmin ‖B − L R̃‖ = lstsq(L, B), R = R̃ S⁻¹.
/// Fix R (quantized): L* = A H Rᵀ (R H Rᵀ)⁻¹.
pub fn lplr(a: &Matrix, h: &Matrix, init: LrPair, cfg: &LowRankConfig) -> LrPair {
    // Group-32 scales: the paper's 4-bit factors go through QuIP#-grade
    // quantizers; coarser scales (per-row or per-direction) measurably
    // flip the Q-vs-LR error balance at this matrix scale (see
    // EXPERIMENTS.md §Deviations for the ablation).
    let quant = UniformQuantizer::new(cfg.lr_bits, 32);
    let quant_l = |l: &Matrix| quant.quantize_dense(l).0;
    let quant_r = |r: &Matrix| quant.quantize_dense(r).0;
    let (s, _lam) = cholesky_jittered(h, 1e-6).expect("lplr cholesky failed");
    let objective = |p: &LrPair| -> f64 {
        let resid = a.sub(&p.product());
        let e = resid.dot(&s).frob_norm() as f64;
        e * e
    };

    let mut l = quant_l(&init.l);
    let mut r = init.r.clone();
    let mut best = LrPair {
        l: l.clone(),
        r: quant_r(&r),
    };
    let mut best_err = objective(&best);

    for _ in 0..cfg.lplr_iters.max(1) {
        // R-step: R = lstsq(L, A S) S⁻¹, then quantize.
        let b = a.dot(&s);
        let rt = if l.frob_norm() > 0.0 {
            crate::linalg::lstsq(&l, &b) // (r × n) in whitened coords
        } else {
            Matrix::zeros(l.cols(), b.cols())
        };
        let r_unwhite = solve_lower_transpose(&s, &rt.transpose()).transpose();
        r = quant_r(&r_unwhite);

        // L-step: L = A H Rᵀ (R H Rᵀ)⁻¹, then quantize.
        let rh = r.dot(h); // (r × n)
        let rhr = rh.dot_t(&r); // (r × r), SPD-ish
        let ahr = a.dot_t(&rh); // (m × r)
        let l_new = match cholesky_jittered(&rhr, 1e-6) {
            Ok((c, _)) => {
                // Solve (R H Rᵀ) Xᵀ = (A H Rᵀ)ᵀ  ⇒ L = Xᵀ... we need
                // L (RHRᵀ) = AHRᵀ ⇒ (RHRᵀ) Lᵀ = (AHRᵀ)ᵀ.
                let y = crate::linalg::solve_lower(&c, &ahr.transpose());
                solve_lower_transpose(&c, &y).transpose()
            }
            Err(_) => l.clone(),
        };
        l = quant_l(&l_new);

        let cand = LrPair {
            l: l.clone(),
            r: r.clone(),
        };
        let err = objective(&cand);
        if err < best_err {
            best_err = err;
            best = cand;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Pcg64;

    fn act_err(a: &Matrix, p: &LrPair, x: &Matrix) -> f32 {
        a.sub(&p.product()).dot(x).frob_norm()
    }

    #[test]
    fn svd_lr_recovers_planted() {
        testing::quick("svd-lr-planted", |rng| {
            let m = testing::gen_dim(rng, 8, 32);
            let n = testing::gen_dim(rng, 8, 32);
            let r = testing::gen_dim(rng, 1, 4);
            let a = testing::gen_lowrank_plus_noise(rng, m, n, r, 0.0);
            let p = svd_lr(&a, r, rng);
            assert!(p.product().rel_err(&a) < 1e-3);
        });
    }

    #[test]
    fn whitened_beats_plain_on_skewed_activations() {
        // When activations have dominant channels, the activation-aware
        // factorization must achieve lower ‖(A−LR)X‖ than plain SVD.
        let mut wins = 0;
        let trials = 20;
        for t in 0..trials {
            let mut rng = Pcg64::new(150, t + 1);
            let m = 24;
            let n = 32;
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (x, _) = testing::gen_outlier_acts(&mut rng, n, 64, 3);
            let h = x.dot_t(&x);
            let plain = svd_lr(&a, 4, &mut rng);
            let aware = whitened_svd_lr(&a, &h, 4, &mut rng);
            if act_err(&a, &aware, &x) < act_err(&a, &plain, &x) {
                wins += 1;
            }
        }
        assert!(wins >= 18, "aware won only {wins}/{trials}");
    }

    #[test]
    fn whitened_svd_optimal_vs_random_perturbation() {
        // Local optimality: perturbing the solution increases the objective.
        let mut rng = Pcg64::new(151, 1);
        let a = Matrix::randn(16, 20, 1.0, &mut rng);
        let x = Matrix::randn(20, 50, 1.0, &mut rng);
        let h = x.dot_t(&x);
        let p = whitened_svd_lr(&a, &h, 5, &mut rng);
        let base = act_err(&a, &p, &x);
        for _ in 0..5 {
            let dl = Matrix::randn(16, 5, 0.05, &mut rng);
            let perturbed = LrPair {
                l: p.l.add(&dl),
                r: p.r.clone(),
            };
            assert!(act_err(&a, &perturbed, &x) >= base - 1e-3);
        }
    }

    #[test]
    fn lplr_improves_over_naive_factor_quantization() {
        let mut wins = 0;
        let trials = 15;
        for t in 0..trials {
            let mut rng = Pcg64::new(152, t + 1);
            let a = testing::gen_lowrank_plus_noise(&mut rng, 24, 32, 8, 0.3);
            let x = Matrix::randn(32, 64, 1.0, &mut rng);
            let h = x.dot_t(&x);
            let cfg = LowRankConfig {
                rank: 8,
                lr_bits: 4,
                lplr_iters: 10,
                reg: 1e-4,
            };
            // Naive: whitened SVD then quantize both factors once.
            let init = whitened_svd_lr(&a, &h, 8, &mut rng);
            let qz = UniformQuantizer::new(4, usize::MAX);
            let naive = LrPair {
                l: qz.quantize(&init.l).deq,
                r: qz.quantize(&init.r).deq,
            };
            let tuned = lplr(&a, &h, init, &cfg);
            if act_err(&a, &tuned, &x) <= act_err(&a, &naive, &x) {
                wins += 1;
            }
        }
        assert!(wins >= 13, "LPLR won only {wins}/{trials}");
    }

    #[test]
    fn lr_approx_16bit_matches_whitened_svd() {
        let mut rng = Pcg64::new(153, 1);
        let a = Matrix::randn(12, 16, 1.0, &mut rng);
        let h = testing::gen_spd(&mut rng, 16);
        let cfg = LowRankConfig {
            rank: 4,
            lr_bits: 16,
            ..Default::default()
        };
        let mut rng2 = Pcg64::new(153, 1);
        let p = lr_approx(&a, &h, &cfg, &mut rng);
        let q = whitened_svd_lr(&a, &h, 4, &mut rng2);
        assert!(p.product().max_abs_diff(&q.product()) < 1e-5);
    }

    #[test]
    fn rank_zero_factors_are_empty() {
        let p = LrPair::zeros(8, 10, 0);
        assert_eq!(p.rank(), 0);
        assert_eq!(p.product(), Matrix::zeros(8, 10));
    }

    #[test]
    fn higher_rank_lower_error() {
        let mut rng = Pcg64::new(154, 1);
        let a = Matrix::randn(32, 40, 1.0, &mut rng);
        let h = testing::gen_spd(&mut rng, 40);
        let x_eval = Matrix::randn(40, 60, 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for rank in [2usize, 8, 24] {
            let p = whitened_svd_lr(&a, &h, rank, &mut rng);
            let e = act_err(&a, &p, &x_eval);
            assert!(e < last, "rank={rank}: {e} !< {last}");
            last = e;
        }
    }
}
