//! Evaluation: perplexity over the synthetic splits and the five zero-shot
//! proxy tasks, driven through any [`Forward`] implementation — the
//! runtime's `fwd_<family>` artifact (XLA or native engine) or the packed
//! fused model ([`crate::fused::FusedModel`]), which never densifies `Q`.
//!
//! Scoring mirrors lm-eval-harness: PPL = exp(mean NLL of next-token
//! targets); multiple-choice accuracy scores each choice continuation by
//! summed log-prob and takes the argmax.

use anyhow::{bail, Result};

use crate::corpus::{self, Split, Task};
use crate::model::ModelParams;
use crate::runtime::{Runtime, Value};
use crate::tensor::Matrix;

/// Anything that can turn a row-major (batch, seq) token block into logits
/// of shape (batch·seq, vocab).
pub trait Forward {
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;
    fn logits(&self, tokens: Vec<i32>) -> Result<Matrix>;
}

/// The runtime-backed forward: dense params through `fwd_<family>`.
pub struct RuntimeForward<'a> {
    pub rt: &'a Runtime,
    pub params: &'a ModelParams,
}

impl Forward for RuntimeForward<'_> {
    fn batch(&self) -> usize {
        self.rt.manifest.batch
    }

    fn seq(&self) -> usize {
        self.rt.manifest.seq
    }

    fn logits(&self, tokens: Vec<i32>) -> Result<Matrix> {
        let (batch, seq) = (self.batch(), self.seq());
        if tokens.len() != batch * seq {
            bail!("forward expects {}x{} tokens", batch, seq);
        }
        let artifact = format!("fwd_{}", self.params.family.name);
        let mut inputs = self.params.values.clone();
        inputs.push(Value::from_vec_i32(vec![batch, seq], tokens));
        let outs = self.rt.exec(&artifact, &inputs)?;
        outs[0].to_matrix_2d()
    }
}

/// Log-softmax NLL of `target` under a logits row (f64 for stability).
/// Public: the batch server scores requests with the same computation.
pub fn nll_of(logits_row: &[f32], target: usize) -> f64 {
    let mx = logits_row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse: f64 = logits_row
        .iter()
        .map(|&v| ((v as f64) - mx).exp())
        .sum::<f64>()
        .ln()
        + mx;
    lse - logits_row[target] as f64
}

/// Perplexity of a forward path on a split, over `windows` sequential
/// windows of its sequence length.
pub fn perplexity_of(fwd: &dyn Forward, split: Split, windows: usize, seed: u64) -> Result<f64> {
    let (batch, seq) = (fwd.batch(), fwd.seq());
    let data = corpus::generate(split, (windows + 2) * (seq + 1) + 1024, seed);
    let wins = corpus::eval_windows(&data, seq, windows);
    if wins.is_empty() {
        bail!("not enough data for eval windows");
    }
    let mut total_nll = 0f64;
    let mut total_tok = 0usize;
    for group in wins.chunks(batch) {
        // Pack up to `batch` windows; pad the group by repeating the first.
        let mut tokens = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let w = group.get(b).unwrap_or(&group[0]);
            tokens.extend(&w[..seq]);
        }
        let logits = fwd.logits(tokens)?;
        let vocab = logits.cols();
        for (b, w) in group.iter().enumerate() {
            for t in 0..seq - 1 {
                let row = logits.row(b * seq + t);
                debug_assert_eq!(row.len(), vocab);
                total_nll += nll_of(row, w[t + 1] as usize);
                total_tok += 1;
            }
        }
    }
    Ok((total_nll / total_tok as f64).exp())
}

/// Runtime-path convenience wrapper (historical signature).
pub fn perplexity(
    rt: &Runtime,
    params: &ModelParams,
    split: Split,
    windows: usize,
    seed: u64,
) -> Result<f64> {
    perplexity_of(&RuntimeForward { rt, params }, split, windows, seed)
}

/// Result of one task evaluation.
#[derive(Clone, Debug)]
pub struct TaskScore {
    pub task: Task,
    pub accuracy: f64,
    pub items: usize,
}

/// Score a two-choice task: each (prompt ++ choice) is packed into one row
/// of the forward batch, NLL summed over the choice's token positions only.
pub fn task_accuracy_of(
    fwd: &dyn Forward,
    task: Task,
    n_items: usize,
    seed: u64,
) -> Result<TaskScore> {
    let (batch, seq) = (fwd.batch(), fwd.seq());
    let items = corpus::task_items(task, n_items, seed);
    // Two rows per item (choice 0 / choice 1).
    let mut rows: Vec<(usize, usize, Vec<i32>, usize, usize)> = Vec::new();
    for (i, it) in items.iter().enumerate() {
        for (c, choice) in it.choices.iter().enumerate() {
            let full = format!("{}{}", it.prompt, choice);
            let bytes = full.as_bytes();
            if bytes.len() + 1 > seq {
                bail!(
                    "task item too long ({} bytes) for seq {}",
                    bytes.len(),
                    seq
                );
            }
            let mut toks: Vec<i32> = bytes.iter().map(|&b| b as i32).collect();
            let choice_start = it.prompt.len(); // first choice byte index
            let choice_end = toks.len();
            toks.resize(seq, b' ' as i32);
            rows.push((i, c, toks, choice_start, choice_end));
        }
    }
    let mut scores = vec![[0f64; 2]; items.len()];
    for group in rows.chunks(batch) {
        let mut tokens = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let r = group.get(b).unwrap_or(&group[0]);
            tokens.extend(&r.2);
        }
        let logits = fwd.logits(tokens)?;
        for (b, (item, choice, toks, start, end)) in group.iter().enumerate() {
            let mut lp = 0f64;
            // P(choice | prompt): positions start..end predicted from
            // position-1 logits.
            for t in *start..*end {
                let row = logits.row(b * seq + t - 1);
                lp -= nll_of(row, toks[t] as usize);
            }
            // Length-normalize (lm-eval `acc_norm`): choices differ in byte
            // length, and raw summed log-prob systematically favors the
            // shorter one.
            scores[*item][*choice] = lp / (*end - *start).max(1) as f64;
        }
    }
    let correct = items
        .iter()
        .enumerate()
        .filter(|(i, it)| {
            let pick = if scores[*i][0] >= scores[*i][1] { 0 } else { 1 };
            pick == it.correct
        })
        .count();
    Ok(TaskScore {
        task,
        accuracy: correct as f64 / items.len() as f64,
        items: items.len(),
    })
}

/// Runtime-path convenience wrapper (historical signature).
pub fn task_accuracy(
    rt: &Runtime,
    params: &ModelParams,
    task: Task,
    n_items: usize,
    seed: u64,
) -> Result<TaskScore> {
    task_accuracy_of(&RuntimeForward { rt, params }, task, n_items, seed)
}

/// Full evaluation bundle (the paper's metric columns for one model).
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub ppl_wiki: f64,
    pub ppl_c4: f64,
    pub tasks: Vec<TaskScore>,
}

pub fn evaluate_of(
    fwd: &dyn Forward,
    ppl_windows: usize,
    task_items: usize,
    seed: u64,
) -> Result<EvalReport> {
    let ppl_wiki = perplexity_of(fwd, Split::WikiSim, ppl_windows, seed)?;
    let ppl_c4 = perplexity_of(fwd, Split::C4Sim, ppl_windows, seed)?;
    let tasks = corpus::ALL_TASKS
        .iter()
        .map(|&t| task_accuracy_of(fwd, t, task_items, seed))
        .collect::<Result<Vec<_>>>()?;
    Ok(EvalReport {
        ppl_wiki,
        ppl_c4,
        tasks,
    })
}

/// Runtime-path convenience wrapper (historical signature).
pub fn evaluate(
    rt: &Runtime,
    params: &ModelParams,
    ppl_windows: usize,
    task_items: usize,
    seed: u64,
) -> Result<EvalReport> {
    evaluate_of(&RuntimeForward { rt, params }, ppl_windows, task_items, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_matches_hand_computation() {
        // logits [0, ln(3)] → p = [1/4, 3/4].
        let row = [0.0f32, (3f32).ln()];
        let nll0 = nll_of(&row, 0);
        let nll1 = nll_of(&row, 1);
        assert!((nll0 - (4f64).ln()).abs() < 1e-6);
        assert!((nll1 - (4f64 / 3.0).ln()).abs() < 1e-6);
    }

    #[test]
    fn nll_is_stable_for_large_logits() {
        let row = [1000.0f32, 998.0];
        let nll = nll_of(&row, 0);
        assert!(nll > 0.0 && nll < 1.0 && nll.is_finite());
    }

    /// A deterministic toy forward: uniform logits except token 0 is always
    /// twice as likely. Lets the eval loops be exercised hermetically.
    struct ToyForward {
        vocab: usize,
        batch: usize,
        seq: usize,
    }

    impl Forward for ToyForward {
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq(&self) -> usize {
            self.seq
        }
        fn logits(&self, tokens: Vec<i32>) -> Result<Matrix> {
            assert_eq!(tokens.len(), self.batch * self.seq);
            let mut m = Matrix::zeros(self.batch * self.seq, self.vocab);
            for i in 0..m.rows() {
                m.row_mut(i)[0] = (2f32).ln();
            }
            Ok(m)
        }
    }

    #[test]
    fn perplexity_of_uniformish_model_is_near_vocab() {
        let fwd = ToyForward {
            vocab: 256,
            batch: 2,
            seq: 64,
        };
        let ppl = perplexity_of(&fwd, Split::WikiSim, 4, 7).unwrap();
        // Nearly-uniform over 256 tokens (token 0 = NUL never occurs in the
        // corpus, so its extra mass only hurts): ppl slightly above 256.
        assert!(ppl > 200.0 && ppl < 300.0, "ppl={ppl}");
    }

    #[test]
    fn task_accuracy_of_runs_on_toy_forward() {
        let fwd = ToyForward {
            vocab: 256,
            batch: 4,
            seq: 96,
        };
        for task in corpus::ALL_TASKS {
            let score = task_accuracy_of(&fwd, task, 8, 3).unwrap();
            assert_eq!(score.items, 8);
            assert!((0.0..=1.0).contains(&score.accuracy));
        }
    }
}
