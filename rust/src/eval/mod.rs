//! Evaluation: perplexity over the synthetic splits and the five zero-shot
//! proxy tasks, driven through any [`Engine`] — the dense native engine
//! ([`crate::engine::NativeEngine`]) or the packed fused model
//! ([`crate::fused::FusedModel`]), which never densifies `Q`.
//!
//! Scoring mirrors lm-eval-harness: PPL = exp(mean NLL of next-token
//! targets); multiple-choice accuracy scores each choice continuation by
//! summed log-prob and takes the argmax. Sequences are scored at their
//! natural lengths through [`crate::engine::score_many`], which batches
//! equal-length sequences together — no row is ever padded by repeating
//! another request (causal attention makes the trailing-pad scores of the
//! old fixed-shape path identical to these).

use anyhow::{bail, Result};

use crate::corpus::{self, Split, Task};
use crate::engine::{self, Engine};

pub use crate::engine::nll_of;

/// Perplexity of an engine on a split, over `windows` sequential windows
/// of the engine's natural sequence length.
pub fn perplexity(engine: &dyn Engine, split: Split, windows: usize, seed: u64) -> Result<f64> {
    let seq = engine.spec().seq;
    let data = corpus::generate(split, (windows + 2) * (seq + 1) + 1024, seed);
    let wins = corpus::eval_windows(&data, seq, windows);
    if wins.is_empty() {
        bail!("not enough data for eval windows");
    }
    let seqs: Vec<Vec<i32>> = wins.iter().map(|w| w[..seq].to_vec()).collect();
    let nlls = engine::score_many(engine, &seqs)?;
    let mut total_nll = 0f64;
    let mut total_tok = 0usize;
    for n in &nlls {
        total_nll += n.iter().sum::<f64>();
        total_tok += n.len();
    }
    if total_tok == 0 {
        bail!("no scored positions");
    }
    Ok((total_nll / total_tok as f64).exp())
}

/// Result of one task evaluation.
#[derive(Clone, Debug)]
pub struct TaskScore {
    pub task: Task,
    pub accuracy: f64,
    pub items: usize,
}

/// Score a two-choice task: each (prompt ++ choice) is scored at its
/// natural length; the choice's summed log-prob (over the choice's token
/// positions only) picks the answer.
pub fn task_accuracy(
    engine: &dyn Engine,
    task: Task,
    n_items: usize,
    seed: u64,
) -> Result<TaskScore> {
    let spec = engine.spec();
    let items = corpus::task_items(task, n_items, seed);
    // Two sequences per item (choice 0 / choice 1).
    let mut seqs: Vec<Vec<i32>> = Vec::with_capacity(2 * items.len());
    let mut meta: Vec<(usize, usize, usize, usize)> = Vec::with_capacity(2 * items.len());
    for (i, it) in items.iter().enumerate() {
        for (c, choice) in it.choices.iter().enumerate() {
            let full = format!("{}{}", it.prompt, choice);
            let toks: Vec<i32> = full.as_bytes().iter().map(|&b| b as i32).collect();
            if toks.len() > spec.seq {
                bail!("task item too long ({} tokens) for seq {}", toks.len(), spec.seq);
            }
            // P(choice | prompt): first choice byte starts at prompt end.
            let start = it.prompt.len().max(1);
            let end = toks.len();
            meta.push((i, c, start, end));
            seqs.push(toks);
        }
    }
    let nlls = engine::score_many(engine, &seqs)?;
    let mut scores = vec![[0f64; 2]; items.len()];
    for ((item, choice, start, end), n) in meta.iter().zip(&nlls) {
        let mut lp = 0f64;
        // Position t is predicted from position t-1's logits → nlls[t-1].
        for t in *start..*end {
            lp -= n[t - 1];
        }
        // Length-normalize (lm-eval `acc_norm`): choices differ in byte
        // length, and raw summed log-prob systematically favors the
        // shorter one.
        scores[*item][*choice] = lp / (*end - *start).max(1) as f64;
    }
    let correct = items
        .iter()
        .enumerate()
        .filter(|(i, it)| {
            let pick = if scores[*i][0] >= scores[*i][1] { 0 } else { 1 };
            pick == it.correct
        })
        .count();
    Ok(TaskScore {
        task,
        accuracy: correct as f64 / items.len() as f64,
        items: items.len(),
    })
}

/// Full evaluation bundle (the paper's metric columns for one model).
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub ppl_wiki: f64,
    pub ppl_c4: f64,
    pub tasks: Vec<TaskScore>,
}

pub fn evaluate(
    engine: &dyn Engine,
    ppl_windows: usize,
    task_items: usize,
    seed: u64,
) -> Result<EvalReport> {
    let ppl_wiki = perplexity(engine, Split::WikiSim, ppl_windows, seed)?;
    let ppl_c4 = perplexity(engine, Split::C4Sim, ppl_windows, seed)?;
    let tasks = corpus::ALL_TASKS
        .iter()
        .map(|&t| task_accuracy(engine, t, task_items, seed))
        .collect::<Result<Vec<_>>>()?;
    Ok(EvalReport {
        ppl_wiki,
        ppl_c4,
        tasks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineSpec, Session};
    use crate::runtime::native::KvCache;
    use crate::tensor::Matrix;

    #[test]
    fn nll_matches_hand_computation() {
        // logits [0, ln(3)] → p = [1/4, 3/4].
        let row = [0.0f32, (3f32).ln()];
        let nll0 = nll_of(&row, 0);
        let nll1 = nll_of(&row, 1);
        assert!((nll0 - (4f64).ln()).abs() < 1e-6);
        assert!((nll1 - (4f64 / 3.0).ln()).abs() < 1e-6);
    }

    #[test]
    fn nll_is_stable_for_large_logits() {
        let row = [1000.0f32, 998.0];
        let nll = nll_of(&row, 0);
        assert!(nll > 0.0 && nll < 1.0 && nll.is_finite());
    }

    /// A deterministic toy engine: uniform logits except token 0 is always
    /// twice as likely. Lets the eval loops be exercised hermetically.
    struct ToyEngine {
        vocab: usize,
        max_batch: usize,
        seq: usize,
    }

    impl ToyEngine {
        fn logits_rows(&self, rows: usize) -> Matrix {
            let mut m = Matrix::zeros(rows, self.vocab);
            for i in 0..rows {
                m.row_mut(i)[0] = (2f32).ln();
            }
            m
        }
    }

    impl Engine for ToyEngine {
        fn spec(&self) -> EngineSpec {
            EngineSpec {
                vocab: self.vocab,
                max_batch: self.max_batch,
                seq: self.seq,
                max_context: 4 * self.seq,
                kv_budget: 0,
            }
        }

        fn forward_batch(
            &self,
            tokens: &[i32],
            batch: usize,
            seq: usize,
        ) -> anyhow::Result<Matrix> {
            assert_eq!(tokens.len(), batch * seq);
            Ok(self.logits_rows(batch * seq))
        }

        fn prefill(&self, tokens: &[i32]) -> anyhow::Result<(Session, Matrix)> {
            Ok((
                Session::new(tokens.to_vec(), KvCache::new(0, 1)),
                self.logits_rows(tokens.len()),
            ))
        }

        fn decode_step(
            &self,
            sessions: &mut [&mut Session],
            tokens: &[i32],
        ) -> anyhow::Result<Matrix> {
            for (s, &t) in sessions.iter_mut().zip(tokens) {
                s.tokens.push(t);
            }
            Ok(self.logits_rows(tokens.len()))
        }
    }

    #[test]
    fn perplexity_of_uniformish_model_is_near_vocab() {
        let engine = ToyEngine {
            vocab: 256,
            max_batch: 2,
            seq: 64,
        };
        let ppl = perplexity(&engine, Split::WikiSim, 4, 7).unwrap();
        // Nearly-uniform over 256 tokens (token 0 = NUL never occurs in the
        // corpus, so its extra mass only hurts): ppl slightly above 256.
        assert!(ppl > 200.0 && ppl < 300.0, "ppl={ppl}");
    }

    #[test]
    fn task_accuracy_runs_on_toy_engine() {
        let engine = ToyEngine {
            vocab: 256,
            max_batch: 4,
            seq: 96,
        };
        for task in corpus::ALL_TASKS {
            let score = task_accuracy(&engine, task, 8, 3).unwrap();
            assert_eq!(score.items, 8);
            assert!((0.0..=1.0).contains(&score.accuracy));
        }
    }
}
