//! Evaluation: perplexity over the synthetic splits and the five zero-shot
//! proxy tasks, both driven through the `fwd_<family>` HLO artifact.
//!
//! Scoring mirrors lm-eval-harness: PPL = exp(mean NLL of next-token
//! targets); multiple-choice accuracy scores each choice continuation by
//! summed log-prob and takes the argmax.

use anyhow::{bail, Result};

use crate::corpus::{self, Split, Task};
use crate::model::ModelParams;
use crate::runtime::{Value, XlaRuntime};

/// Log-softmax NLL of `target` under a logits row (f64 for stability).
fn nll_of(logits_row: &[f32], target: usize) -> f64 {
    let mx = logits_row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse: f64 = logits_row
        .iter()
        .map(|&v| ((v as f64) - mx).exp())
        .sum::<f64>()
        .ln()
        + mx;
    lse - logits_row[target] as f64
}

/// Run the forward artifact on a full (batch, seq) token block; returns the
/// logits as (batch*seq, vocab).
fn forward(
    rt: &XlaRuntime,
    params: &ModelParams,
    tokens: Vec<i32>,
) -> Result<crate::tensor::Matrix> {
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    if tokens.len() != batch * seq {
        bail!("forward expects {}x{} tokens", batch, seq);
    }
    let artifact = format!("fwd_{}", params.family.name);
    let mut inputs = params.values.clone();
    inputs.push(Value::from_vec_i32(vec![batch, seq], tokens));
    let outs = rt.exec(&artifact, &inputs)?;
    outs[0].to_matrix_2d()
}

/// Perplexity of a model on a split, over `windows` sequential windows of
/// the artifact's sequence length.
pub fn perplexity(
    rt: &XlaRuntime,
    params: &ModelParams,
    split: Split,
    windows: usize,
    seed: u64,
) -> Result<f64> {
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let data = corpus::generate(split, (windows + 2) * (seq + 1) + 1024, seed);
    let wins = corpus::eval_windows(&data, seq, windows);
    if wins.is_empty() {
        bail!("not enough data for eval windows");
    }
    let mut total_nll = 0f64;
    let mut total_tok = 0usize;
    for group in wins.chunks(batch) {
        // Pack up to `batch` windows; pad the group by repeating the first.
        let mut tokens = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let w = group.get(b).unwrap_or(&group[0]);
            tokens.extend(&w[..seq]);
        }
        let logits = forward(rt, params, tokens)?;
        let vocab = logits.cols();
        for (b, w) in group.iter().enumerate() {
            for t in 0..seq - 1 {
                let row = logits.row(b * seq + t);
                debug_assert_eq!(row.len(), vocab);
                total_nll += nll_of(row, w[t + 1] as usize);
                total_tok += 1;
            }
        }
    }
    Ok((total_nll / total_tok as f64).exp())
}

/// Result of one task evaluation.
#[derive(Clone, Debug)]
pub struct TaskScore {
    pub task: Task,
    pub accuracy: f64,
    pub items: usize,
}

/// Score a two-choice task: each (prompt ++ choice) is packed into one row
/// of the forward batch, NLL summed over the choice's token positions only.
pub fn task_accuracy(
    rt: &XlaRuntime,
    params: &ModelParams,
    task: Task,
    n_items: usize,
    seed: u64,
) -> Result<TaskScore> {
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let items = corpus::task_items(task, n_items, seed);
    // Two rows per item (choice 0 / choice 1).
    let mut rows: Vec<(usize, usize, Vec<i32>, usize, usize)> = Vec::new();
    for (i, it) in items.iter().enumerate() {
        for (c, choice) in it.choices.iter().enumerate() {
            let full = format!("{}{}", it.prompt, choice);
            let bytes = full.as_bytes();
            if bytes.len() + 1 > seq {
                bail!(
                    "task item too long ({} bytes) for seq {}",
                    bytes.len(),
                    seq
                );
            }
            let mut toks: Vec<i32> = bytes.iter().map(|&b| b as i32).collect();
            let choice_start = it.prompt.len(); // first choice byte index
            let choice_end = toks.len();
            toks.resize(seq, b' ' as i32);
            rows.push((i, c, toks, choice_start, choice_end));
        }
    }
    let mut scores = vec![[0f64; 2]; items.len()];
    for group in rows.chunks(batch) {
        let mut tokens = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let r = group.get(b).unwrap_or(&group[0]);
            tokens.extend(&r.2);
        }
        let logits = forward(rt, params, tokens)?;
        for (b, (item, choice, toks, start, end)) in group.iter().enumerate() {
            let mut lp = 0f64;
            // P(choice | prompt): positions start..end predicted from
            // position-1 logits.
            for t in *start..*end {
                let row = logits.row(b * seq + t - 1);
                lp -= nll_of(row, toks[t] as usize);
            }
            // Length-normalize (lm-eval `acc_norm`): choices differ in byte
            // length, and raw summed log-prob systematically favors the
            // shorter one.
            scores[*item][*choice] = lp / (*end - *start).max(1) as f64;
        }
    }
    let correct = items
        .iter()
        .enumerate()
        .filter(|(i, it)| {
            let pick = if scores[*i][0] >= scores[*i][1] { 0 } else { 1 };
            pick == it.correct
        })
        .count();
    Ok(TaskScore {
        task,
        accuracy: correct as f64 / items.len() as f64,
        items: items.len(),
    })
}

/// Full evaluation bundle (the paper's metric columns for one model).
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub ppl_wiki: f64,
    pub ppl_c4: f64,
    pub tasks: Vec<TaskScore>,
}

pub fn evaluate(
    rt: &XlaRuntime,
    params: &ModelParams,
    ppl_windows: usize,
    task_items: usize,
    seed: u64,
) -> Result<EvalReport> {
    let ppl_wiki = perplexity(rt, params, Split::WikiSim, ppl_windows, seed)?;
    let ppl_c4 = perplexity(rt, params, Split::C4Sim, ppl_windows, seed)?;
    let tasks = corpus::ALL_TASKS
        .iter()
        .map(|&t| task_accuracy(rt, params, t, task_items, seed))
        .collect::<Result<Vec<_>>>()?;
    Ok(EvalReport {
        ppl_wiki,
        ppl_c4,
        tasks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_matches_hand_computation() {
        // logits [0, ln(3)] → p = [1/4, 3/4].
        let row = [0.0f32, (3f32).ln()];
        let nll0 = nll_of(&row, 0);
        let nll1 = nll_of(&row, 1);
        assert!((nll0 - (4f64).ln()).abs() < 1e-6);
        assert!((nll1 - (4f64 / 3.0).ln()).abs() < 1e-6);
    }

    #[test]
    fn nll_is_stable_for_large_logits() {
        let row = [1000.0f32, 998.0];
        let nll = nll_of(&row, 0);
        assert!(nll > 0.0 && nll < 1.0 && nll.is_finite());
    }
}
