//! # odlri — Outlier-Driven Low-Rank Initialization for joint Q+LR weight decomposition
//!
//! A from-scratch reproduction of *"Assigning Distinct Roles to Quantized and
//! Low-Rank Matrices Toward Optimal Weight Decomposition"* (ACL 2025 Findings)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the compression pipeline: calibration,
//!   per-matrix joint `W ≈ Q + L·R` optimization (CALDERA loop with
//!   pluggable low-rank initializers including ODLRI), a threaded job
//!   coordinator, model evaluation (perplexity + zero-shot proxies), and a
//!   full experiment harness regenerating every table/figure of the paper.
//! * **Layer 2** — a tiny Llama-style transformer authored in JAX and
//!   AOT-lowered to HLO text artifacts, executed through PJRT when the
//!   `xla` feature is enabled ([`runtime`]).
//! * **Layer 1** — fused `(Q+LR)·x`, per-group quantize, and Walsh–Hadamard
//!   kernels. The Pallas lowerings live inside the AOT artifacts; the
//!   native equivalents live in [`fused`] and [`runtime::native`].
//!
//! **Artifact-free by default:** every artifact entry point (`fwd_*`,
//! `fwd_fused_*`, `train_*`, `capture_*`, `kernel_*`) has a native Rust
//! implementation, so training, compression, evaluation, serving, benches,
//! and the full test suite run with no artifacts and no Python. When
//! `artifacts/` exists and the crate is built with `--features xla`, the
//! same calls execute the HLO artifacts instead.
//!
//! **Serving hot path:** [`fused::FusedQlrMatrix`] keeps `Q` bit-packed
//! (dequant-on-the-fly, blocked + multithreaded) and applies the low-rank
//! correction as two skinny matmuls — `CompressedMatrix::reconstruct()` is
//! never called at inference time. All inference flows through the
//! [`engine::Engine`] API: scoring forwards plus KV-cached incremental
//! generation over per-request [`engine::Session`]s; [`serve`] runs a
//! continuous-batching threaded server (FIFO admission, variable batch
//! assembly) over any engine.
//!
//! Entry points: [`decompose::JointOptimizer`] (the algorithm),
//! [`coordinator::CompressionPipeline`] (whole-model compression),
//! [`fused::FusedModel`] (deployment form), [`engine`] (serving API),
//! [`eval`] (metrics), `odlri exp <id>` (paper reproductions),
//! `odlri serve-bench --fused` / `odlri generate --fused` (packed serving
//! and generation).

pub mod benchkit;
pub mod calib;
pub mod cli;
pub mod coordinator;
pub mod corpus;
pub mod decompose;
pub mod engine;
pub mod eval;
pub mod exec;
pub mod exp;
pub mod fused;
pub mod hadamard;
pub mod hessian;
pub mod linalg;
pub mod lowrank;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
