//! # odlri — Outlier-Driven Low-Rank Initialization for joint Q+LR weight decomposition
//!
//! A from-scratch reproduction of *"Assigning Distinct Roles to Quantized and
//! Low-Rank Matrices Toward Optimal Weight Decomposition"* (ACL 2025 Findings)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the compression pipeline: calibration,
//!   per-matrix joint `W ≈ Q + L·R` optimization (CALDERA loop with
//!   pluggable low-rank initializers including ODLRI), a threaded job
//!   coordinator, model evaluation (perplexity + zero-shot proxies), and a
//!   full experiment harness regenerating every table/figure of the paper.
//! * **Layer 2** — a tiny Llama-style transformer authored in JAX and
//!   AOT-lowered to HLO text artifacts, executed here through PJRT
//!   ([`runtime`]).
//! * **Layer 1** — Pallas kernels (fused `(Q+LR)·x`, per-group quantize,
//!   Walsh–Hadamard) lowered inside the same artifacts.
//!
//! Python never runs at pipeline/eval time: after `make artifacts`, the
//! `odlri` binary is self-contained.
//!
//! Entry points: [`decompose::JointOptimizer`] (the algorithm),
//! [`coordinator::CompressionPipeline`] (whole-model compression),
//! [`eval`] (metrics), `odlri exp <id>` (paper reproductions).

pub mod benchkit;
pub mod calib;
pub mod cli;
pub mod coordinator;
pub mod corpus;
pub mod decompose;
pub mod eval;
pub mod exec;
pub mod exp;
pub mod hadamard;
pub mod hessian;
pub mod linalg;
pub mod lowrank;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
