//! Seeded, deterministic fault injection for the serving scheduler.
//!
//! A [`FaultPlan`] names the sites and per-site probabilities (parsed
//! from the CLI's `--chaos SPEC` string); a [`FaultInjector`] turns the
//! plan plus a seed into concrete fault decisions the scheduler consults
//! at each site. Every decision is a **stateless keyed hash draw**
//! ([`crate::util::rng::splitmix64`] over `seed ^ site ^ key`), not a
//! shared RNG stream — so whether a given request faults does not depend
//! on the order sites happen to be consulted in, and the same seed
//! replays the same fault sequence in CI regardless of thread timing.
//!
//! ## Sites and keys
//!
//! | site      | key                  | effect in the scheduler            |
//! |-----------|----------------------|------------------------------------|
//! | `pool`    | request id           | one transient pool-exhaustion      |
//! |           |                      | refusal (retry-with-backoff path)  |
//! | `replica` | scheduler tick       | quarantine one live shard          |
//! | `draft`   | request id × round   | a speculative draft round fails    |
//! |           |                      | (feeds the circuit-breaker)        |
//! | `abort`   | request id           | client goes away after N tokens    |
//! | `slow`    | client id × ordinal  | client stalls before draining      |
//!
//! Request-keyed sites are **topology-independent**: the set of requests
//! that fault is the same under `--replicas 1` and `--replicas 2`, which
//! is what the cross-topology determinism property test pins. Tick-keyed
//! sites (`replica`) are deterministic per run configuration but
//! naturally vary with topology (tick counts differ).
//!
//! The `pool` site is the one stateful site: it fires **at most once per
//! request** (a consumed set), so an injected transient can never be
//! mistaken for real, persistent exhaustion — the scheduler's fatal
//! pool-exhaustion path stays reachable only by genuine pressure.

use anyhow::{bail, Result};
use std::collections::BTreeSet;

use crate::util::rng::splitmix64;

// Per-site salts: arbitrary odd constants so the same key draws
// independently at every site.
const SITE_POOL: u64 = 0x9e37_79b9_7f4a_7c15;
const SITE_REPLICA: u64 = 0xbf58_476d_1ce4_e5b9;
const SITE_DRAFT: u64 = 0x94d0_49bb_1331_11eb;
const SITE_ABORT: u64 = 0xd6e8_feb8_6659_fd93;
const SITE_SLOW: u64 = 0xa076_1d64_78bd_642f;
const SITE_ABORT_AT: u64 = 0xe703_7ed1_a0b4_28db;

/// Per-site fault probabilities, all in `[0, 1]`; `0` disables a site.
/// Parsed from a `--chaos` spec like `"pool=0.2,replica=0.1,draft=0.3"`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Transient pool-exhaustion refusal, once per drawn request.
    pub pool: f64,
    /// Per-tick chance of one live replica shard failing.
    pub replica: f64,
    /// Per-round chance a speculative draft round fails.
    pub draft: f64,
    /// Per-request chance the client aborts mid-stream.
    pub abort: f64,
    /// Per-request chance the client is slow to drain its response.
    pub slow: f64,
}

impl FaultPlan {
    /// Parse a comma-separated `site=probability` list. Unknown sites and
    /// probabilities outside `[0, 1]` are errors; an empty spec is the
    /// empty (fault-free) plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((site, prob)) = part.split_once('=') else {
                bail!("chaos spec entry {part:?} is not site=probability");
            };
            let p: f64 = prob
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("chaos probability {prob:?} is not a number"))?;
            if !(0.0..=1.0).contains(&p) {
                bail!("chaos probability {p} for site {site:?} is outside [0, 1]");
            }
            match site.trim() {
                "pool" => plan.pool = p,
                "replica" => plan.replica = p,
                "draft" => plan.draft = p,
                "abort" => plan.abort = p,
                "slow" => plan.slow = p,
                other => bail!(
                    "unknown chaos site {other:?} (expected pool, replica, draft, abort, slow)"
                ),
            }
        }
        Ok(plan)
    }

    /// True when every site is disabled (no injector needed).
    pub fn is_empty(&self) -> bool {
        self.pool == 0.0
            && self.replica == 0.0
            && self.draft == 0.0
            && self.abort == 0.0
            && self.slow == 0.0
    }
}

/// A seeded fault oracle over a [`FaultPlan`]. All draws are pure keyed
/// hashes except the once-per-request `pool` site (a consumed set).
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    seed: u64,
    pool_consumed: BTreeSet<u64>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan, seed: u64) -> FaultInjector {
        FaultInjector {
            plan,
            seed,
            pool_consumed: BTreeSet::new(),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Uniform draw in `[0, 1)` keyed by `(seed, site, key)`.
    fn unit(&self, site: u64, key: u64) -> f64 {
        let mut state = self.seed ^ site ^ key.wrapping_mul(0xd1b5_4a32_d192_ed03);
        let bits = splitmix64(&mut state);
        (bits >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Raw 64-bit draw keyed by `(seed, site, key)` (for selectors).
    fn bits(&self, site: u64, key: u64) -> u64 {
        let mut state = self.seed ^ site ^ key.wrapping_mul(0xd1b5_4a32_d192_ed03);
        splitmix64(&mut state)
    }

    /// One transient pool-exhaustion refusal for `req_id`, at most once
    /// per request across all consult sites (admission and decode).
    pub fn pool_fault(&mut self, req_id: u64) -> bool {
        if self.plan.pool <= 0.0 || self.pool_consumed.contains(&req_id) {
            return false;
        }
        if self.unit(SITE_POOL, req_id) < self.plan.pool {
            self.pool_consumed.insert(req_id);
            return true;
        }
        false
    }

    /// Shard-failure draw for this tick: `Some(selector)` means one live
    /// shard should be quarantined (the engine picks the victim from the
    /// selector, skipping already-dead shards and the last survivor).
    pub fn replica_fault(&self, tick: u64) -> Option<u64> {
        if self.plan.replica <= 0.0 || self.unit(SITE_REPLICA, tick) >= self.plan.replica {
            return None;
        }
        Some(self.bits(SITE_REPLICA, tick.wrapping_add(1)))
    }

    /// Whether speculative draft round `round` of request `req_id` fails.
    pub fn draft_fault(&self, req_id: u64, round: u64) -> bool {
        self.plan.draft > 0.0
            && self.unit(SITE_DRAFT, req_id ^ round.wrapping_mul(0x9e37_79b9)) < self.plan.draft
    }

    /// Injected client abort for `req_id`: `Some(n)` means the client
    /// goes away after `n` produced tokens (`1 ≤ n < max_new_tokens`, so
    /// the abort always lands mid-stream). `None` when the request does
    /// not abort or is too short to abort mid-stream.
    pub fn abort_after(&self, req_id: u64, max_new_tokens: usize) -> Option<usize> {
        if self.plan.abort <= 0.0
            || max_new_tokens < 2
            || self.unit(SITE_ABORT, req_id) >= self.plan.abort
        {
            return None;
        }
        let span = (max_new_tokens - 1) as u64;
        Some(1 + (self.bits(SITE_ABORT_AT, req_id) % span) as usize)
    }

    /// Whether the client should stall before draining this response
    /// (keyed by client id and per-client request ordinal — the client
    /// side knows those before the scheduler assigns a request id).
    pub fn slow_client(&self, client: u64, ordinal: u64) -> bool {
        self.plan.slow > 0.0
            && self.unit(SITE_SLOW, client ^ ordinal.wrapping_mul(0x85eb_ca6b)) < self.plan.slow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_full_and_partial_specs() {
        let p = FaultPlan::parse("pool=0.2,replica=0.1,draft=0.3").unwrap();
        assert_eq!(p.pool, 0.2);
        assert_eq!(p.replica, 0.1);
        assert_eq!(p.draft, 0.3);
        assert_eq!(p.abort, 0.0);
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert_eq!(
            FaultPlan::parse(" abort=1 , slow=0.5 ").unwrap(),
            FaultPlan {
                abort: 1.0,
                slow: 0.5,
                ..FaultPlan::default()
            }
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("pool").is_err());
        assert!(FaultPlan::parse("pool=x").is_err());
        assert!(FaultPlan::parse("pool=1.5").is_err());
        assert!(FaultPlan::parse("pool=-0.1").is_err());
        assert!(FaultPlan::parse("gamma=0.5").is_err());
    }

    #[test]
    fn draws_are_deterministic_and_seed_sensitive() {
        let plan = FaultPlan::parse("pool=0.5,replica=0.5,draft=0.5,abort=0.5,slow=0.5").unwrap();
        let a = FaultInjector::new(plan.clone(), 7);
        let b = FaultInjector::new(plan.clone(), 7);
        let c = FaultInjector::new(plan, 8);
        let per_seed = |f: &FaultInjector| -> Vec<bool> {
            (0..64)
                .map(|i| f.draft_fault(i, 0) || f.abort_after(i, 16).is_some())
                .collect()
        };
        assert_eq!(per_seed(&a), per_seed(&b), "same seed must replay");
        assert_ne!(per_seed(&a), per_seed(&c), "different seed must differ");
        assert_eq!(a.replica_fault(3), b.replica_fault(3));
    }

    #[test]
    fn pool_fault_fires_at_most_once_per_request() {
        let plan = FaultPlan::parse("pool=1").unwrap();
        let mut f = FaultInjector::new(plan, 9);
        for id in 0..8u64 {
            assert!(f.pool_fault(id), "p=1 must fire for request {id}");
            assert!(!f.pool_fault(id), "second draw for {id} must be consumed");
        }
    }

    #[test]
    fn probability_extremes_are_certain() {
        let all = FaultPlan::parse("replica=1,draft=1,abort=1,slow=1").unwrap();
        let none = FaultPlan::default();
        let on = FaultInjector::new(all, 3);
        let off = FaultInjector::new(none, 3);
        for k in 0..32u64 {
            assert!(on.replica_fault(k).is_some());
            assert!(on.draft_fault(k, k));
            assert!(on.slow_client(k, k));
            let n = on.abort_after(k, 12).unwrap();
            assert!((1..12).contains(&n), "abort point {n} out of range");
            assert!(off.replica_fault(k).is_none());
            assert!(!off.draft_fault(k, k));
            assert!(off.abort_after(k, 12).is_none());
            assert!(!off.slow_client(k, k));
        }
        // Too short to abort mid-stream.
        assert_eq!(on.abort_after(0, 1), None);
    }
}
