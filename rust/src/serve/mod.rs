//! Threaded batch serving over any [`Forward`] path (dense runtime or the
//! packed fused engine).
//!
//! Client threads submit single-sequence scoring requests; the leader
//! batches them up to the forward's batch size (dynamic batching with a
//! deadline, vLLM-router-style), executes one forward per batch, and
//! answers each request with its mean next-token NLL. `examples/serve.rs`
//! is a thin wrapper; the serving smoke test drives this loop directly on
//! the artifact-free native fallback.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::corpus;
use crate::eval::{nll_of, Forward};
use crate::util::rng::Pcg64;

/// Batch-server run configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Total requests across all clients.
    pub requests: usize,
    /// Closed-loop client threads.
    pub clients: usize,
    /// Dynamic-batching deadline once a partial batch is pending.
    pub deadline: Duration,
    /// Corpus seed for request payloads.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            requests: 120,
            clients: 4,
            deadline: Duration::from_millis(10),
            seed: 0,
        }
    }
}

/// Serving outcome: one score + latency per completed request.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Mean NLL of each served sequence (the response payload).
    pub scores: Vec<f32>,
    /// Per-request wall latency in seconds, completion order.
    pub latencies_s: Vec<f64>,
    /// Executed forward batches.
    pub batches: usize,
    pub wall_secs: f64,
}

impl ServeReport {
    fn percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        let mut sorted = self.latencies_s.clone();
        // A stray NaN sample (clock anomaly, poisoned math) must not panic
        // the whole batch-server report. NaNs of either sign sort to the
        // END (total_cmp alone would put -NaN first and shift every
        // percentile), so they only distort the tail slot they land in.
        sorted.sort_by(|a, b| match (a.is_nan(), b.is_nan()) {
            (true, true) => std::cmp::Ordering::Equal,
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
            (false, false) => a.total_cmp(b),
        });
        sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
    }

    pub fn p50_ms(&self) -> f64 {
        self.percentile(0.50) * 1e3
    }

    pub fn p95_ms(&self) -> f64 {
        self.percentile(0.95) * 1e3
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_secs == 0.0 {
            0.0
        } else {
            self.scores.len() as f64 / self.wall_secs
        }
    }
}

struct Request {
    tokens: Vec<i32>, // length = seq
    done: mpsc::Sender<f32>,
    submitted: Instant,
}

/// Run the closed-loop batch server until every client request completes.
pub fn run_batch_server(fwd: &dyn Forward, cfg: &ServeConfig) -> Result<ServeReport> {
    let (batch, seq) = (fwd.batch(), fwd.seq());
    let (tx, rx) = mpsc::channel::<Request>();
    let mut scores = Vec::with_capacity(cfg.requests);
    let mut latencies = Vec::with_capacity(cfg.requests);
    let mut batches = 0usize;
    let t_start = Instant::now();

    std::thread::scope(|s| -> Result<()> {
        // Client threads: each submits a burst of requests with jitter.
        let clients = cfg.clients.max(1);
        let per_client = cfg.requests / clients;
        let remainder = cfg.requests - per_client * clients;
        for c in 0..clients {
            let tx = tx.clone();
            let seed = cfg.seed;
            let n = per_client + usize::from(c < remainder);
            s.spawn(move || {
                let mut rng = Pcg64::new(seed ^ c as u64, 77);
                let data = corpus::generate(corpus::Split::C4Sim, 200_000, seed ^ c as u64);
                for _ in 0..n {
                    let start = rng.below(data.len() - seq - 1);
                    let tokens: Vec<i32> =
                        data[start..start + seq].iter().map(|&b| b as i32).collect();
                    let (dtx, drx) = mpsc::channel();
                    if tx
                        .send(Request {
                            tokens,
                            done: dtx,
                            submitted: Instant::now(),
                        })
                        .is_err()
                    {
                        return;
                    }
                    // Closed loop: wait for the score before the next send.
                    let _score = drx.recv().ok();
                    std::thread::sleep(Duration::from_millis(rng.below(5) as u64));
                }
            });
        }
        drop(tx);

        // Leader: dynamic batcher. Collect up to `batch` requests or until
        // the deadline passes, then execute one forward. On a forward
        // error, drain the queue before propagating — dropping each queued
        // `Request` drops its `done` sender, so blocked clients wake up and
        // wind down instead of deadlocking the scope join.
        let mut serve = || -> Result<()> {
        let mut pending: Vec<Request> = Vec::new();
        loop {
            let req = if pending.is_empty() {
                match rx.recv() {
                    Ok(r) => Some(r),
                    Err(_) => break, // all clients done
                }
            } else {
                match rx.recv_timeout(cfg.deadline) {
                    Ok(r) => Some(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => None,
                }
            };
            if let Some(r) = req {
                pending.push(r);
                if pending.len() < batch {
                    continue;
                }
            }
            if pending.is_empty() {
                break;
            }
            // Build the batch (pad by repeating the first request).
            let mut tokens = Vec::with_capacity(batch * seq);
            for b in 0..batch {
                let r = pending.get(b).unwrap_or(&pending[0]);
                tokens.extend(&r.tokens);
            }
            let logits = fwd.logits(tokens)?;
            batches += 1;
            for (b, r) in pending.drain(..).enumerate() {
                // Mean NLL over the sequence = the response payload.
                let mut nll = 0f64;
                for t in 0..seq - 1 {
                    nll += nll_of(logits.row(b * seq + t), r.tokens[t + 1] as usize);
                }
                let score = (nll / (seq - 1) as f64) as f32;
                latencies.push(r.submitted.elapsed().as_secs_f64());
                scores.push(score);
                r.done.send(score).ok();
            }
        }
        Ok(())
        };
        let result = serve();
        if result.is_err() {
            // Unblock every client still waiting on a response, then keep
            // draining until all senders hang up.
            while rx.recv().is_ok() {}
        }
        result
    })?;

    Ok(ServeReport {
        scores,
        latencies_s: latencies,
        batches,
        wall_secs: t_start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    /// Uniform-logits stand-in model: instant forward, exact expected score
    /// (ln vocab), exercises the batching loop hermetically.
    struct UniformForward {
        vocab: usize,
        batch: usize,
        seq: usize,
    }

    impl Forward for UniformForward {
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq(&self) -> usize {
            self.seq
        }
        fn logits(&self, tokens: Vec<i32>) -> Result<Matrix> {
            assert_eq!(tokens.len(), self.batch * self.seq);
            Ok(Matrix::zeros(self.batch * self.seq, self.vocab))
        }
    }

    #[test]
    fn serves_every_request_with_exact_uniform_score() {
        let fwd = UniformForward {
            vocab: 256,
            batch: 4,
            seq: 32,
        };
        let cfg = ServeConfig {
            requests: 13,
            clients: 3,
            deadline: Duration::from_millis(2),
            seed: 9,
        };
        let report = run_batch_server(&fwd, &cfg).unwrap();
        assert_eq!(report.scores.len(), 13);
        assert_eq!(report.latencies_s.len(), 13);
        assert!(report.batches >= (13usize).div_ceil(4));
        let want = (256f32).ln();
        for s in &report.scores {
            assert!((s - want).abs() < 1e-4, "score {s} != ln(256)");
        }
        assert!(report.p50_ms() >= 0.0 && report.p95_ms() >= report.p50_ms());
        assert!(report.requests_per_sec() > 0.0);
    }

    #[test]
    fn percentiles_survive_nan_latency_samples() {
        // One poisoned sample must not crash the report; finite percentiles
        // still come from the sorted finite prefix. The negative NaN (what
        // 0.0/0.0 actually produces on x86-64) is the regression case: it
        // must sort last, not first.
        let report = ServeReport {
            scores: vec![0.0; 5],
            latencies_s: vec![0.004, -f64::NAN, 0.001, 0.003, 0.002],
            batches: 2,
            wall_secs: 0.1,
        };
        let p50 = report.p50_ms();
        assert!((p50 - 3.0).abs() < 1e-9, "p50 = {p50}");
        // p95 indexes the NaN slot — it must simply report it, not panic.
        assert!(report.p95_ms().is_nan());
    }

    #[test]
    fn zero_clients_clamps_to_one() {
        // vocab must cover the byte-level corpus (tokens up to 255).
        let fwd = UniformForward {
            vocab: 256,
            batch: 2,
            seq: 8,
        };
        let cfg = ServeConfig {
            requests: 3,
            clients: 0,
            deadline: Duration::from_millis(1),
            seed: 1,
        };
        let report = run_batch_server(&fwd, &cfg).unwrap();
        assert_eq!(report.scores.len(), 3);
    }
}
