//! Continuous-batching serving over any [`Engine`] — the serving API spec.
//!
//! ## Request lifecycle
//!
//! Client threads submit typed [`Request`]s over a channel; a single
//! leader thread runs the [`Scheduler`]. Every arrival is stamped with a
//! monotonically increasing id and appended to the FIFO queue of its
//! [`Priority`] class (`Score` requests, which have no priority field,
//! ride the `Interactive` queue — so an all-default workload degenerates
//! to the single strict-FIFO queue of earlier revisions, bit-for-bit).
//! On each scheduler iteration:
//!
//! 1. **Admission (priority classes, FIFO within each).** Classes are
//!    scanned in urgency order (`Interactive` before `Batch`); within a
//!    class, requests are admitted from the queue *front only*. The first
//!    blocked head stops admission entirely: nothing overtakes it — not a
//!    later arrival in its own class, and not a lower class either. That
//!    is the fairness guarantee: admission order = (class, arrival)
//!    order, so equal-work generate requests in one class also *complete*
//!    in arrival order, and `Batch` work can never delay an admissible
//!    `Interactive` request.
//! 2. **Scoring (variable batch assembly).** Admitted score requests are
//!    grouped by exact sequence length and each group runs as one
//!    variable-size forward — no wasted rows, no fixed shape.
//! 3. **Decode (continuous batching, vLLM-style).** All in-flight
//!    sessions — whatever their lengths — advance by one token in a single
//!    [`Engine::decode_step`] against their KV caches. Finished sessions
//!    retire immediately and their slots are refilled by admission on the
//!    *next* iteration. **Decode runs before prefill work** each tick.
//! 4. **Chunked prefill (decode never stalls behind a long prompt).**
//!    When the engine implements [`Engine::prefill_chunk`] and the
//!    scheduler was given a chunk budget, admitted generate requests do
//!    not prefill monolithically: they park in a *prefilling* set (each
//!    occupying a decode slot) and advance by at most `prefill_chunk`
//!    prompt tokens per tick — after the decode step, in (class, arrival)
//!    order, chunk boundaries page-aligned when possible. A 10k-token
//!    prompt therefore costs every running session at most one
//!    chunk-sized bubble per tick instead of a full-prompt stall; the
//!    report counts these overlapped ticks in
//!    [`ServeReport::interleaved_decode_steps`]. Chunking is invisible in
//!    the output: [`Engine::prefill_chunk`] is bit-identical to one-shot
//!    [`Engine::prefill`] by contract (property-tested in the engine
//!    modules and end-to-end below).
//! 5. **Speculative decoding (optional, [`Scheduler::with_speculation`]).**
//!    Given a cheap draft [`Engine`] (a lower-bit ODLRI pack of the same
//!    family) and a depth `k`, the decode tick becomes a draft/verify
//!    round per session: the draft greedily proposes up to `k` tokens,
//!    the target checks the pending token *plus all drafts* in one
//!    batched [`Engine::verify_step`], the longest matching prefix is
//!    accepted, and both KV caches roll back to the committed length via
//!    [`Session::truncate`] on first mismatch. Greedy streams therefore
//!    commit 1..=k+1 tokens per target forward while staying **bit-
//!    identical** to plain target-only serving (the headline invariant,
//!    tested below through preemption and chunked prefill); sampled
//!    streams take the plain single-token path through the same verify
//!    call, with the bonus token drawn from the session's [`Sampler`].
//!    The draft is strictly advisory: each session's draft KV lives in
//!    the *draft engine's* pool, is dropped on preemption and rebuilt by
//!    a draft prefill on resume, and any draft-side failure (pool
//!    exhaustion, a smaller draft context) silently degrades that round
//!    to plain decode — only target errors drive the preemption policy.
//!
//! ## Batching policy
//!
//! The only time the leader waits is when it is fully idle (no in-flight
//! decode, prefill, or preempted sessions): it then holds a partial
//! scoring batch up to [`ServeConfig::deadline`] hoping to fill it
//! (dynamic batching). With work in flight the loop never sleeps.
//!
//! Per-session decode results are independent of batch composition (the
//! engine contract), so a request's output does not depend on who it
//! shared a batch with — property-tested below via solo-vs-concurrent
//! equality, including through a multi-replica engine.
//!
//! ## KV paging, preemption, and resume
//!
//! Generation sessions store their KV in fixed-size pages drawn from the
//! engine's [`KvPool`](crate::runtime::kvpool::KvPool) under a hard byte
//! budget (`--kv-budget`). Admission validates a generate request up
//! front: an empty prompt, a prompt at/over `max_context`, or one that
//! can *never* fit answers **that request** with a typed
//! [`Response::Rejected`] and the scheduler keeps serving everyone else;
//! a prompt that merely cannot fit *right now* is put back at its class
//! queue front until running sessions retire.
//!
//! When a decode step runs out of pages, the scheduler **preempts** the
//! lowest-class, youngest in-flight session (`Batch` before
//! `Interactive`, LIFO within a class): its KV cache is dropped, its
//! token history and sampler state are parked, and the smaller batch
//! retries. Preempted sessions **resume** highest-class-oldest first as
//! soon as capacity frees, by re-prefilling their full token history —
//! bit-exact, because KV rows are pure functions of the token prefix and
//! the sampler state survived intact. A lone session that outgrows the
//! whole pool is a typed fatal error: it cannot free its own pages.
//! Partially prefilled sessions relieve pressure the cheap way: their
//! chunk cache is dropped and the request returns to its queue slot (no
//! history to park — nothing was sampled yet).
//!
//! Identical prompt prefixes across sessions share pages copy-on-write
//! ([`ServeConfig::shared_prompt`] benches exactly this), so N sessions
//! behind one system prompt hold far fewer resident pages than N × the
//! prompt's page count.
//!
//! ## Degradation ladder
//!
//! Overload and faults degrade through typed outcomes, never panics, and
//! never a wedged scheduler:
//!
//! * **Deadlines.** [`Request::Generate`] carries `deadline_ticks`; a
//!   request that has not completed within that many scheduler ticks of
//!   its arrival is answered with [`Response::TimedOut`] and every page
//!   it held is released. The deadline is *absolute*: requeues and
//!   preemptions never extend the budget.
//! * **Load shedding.** With [`ServeConfig::queue_cap`] set, arrivals
//!   past the cap are shed with [`Response::Shed`] — `Batch` work first.
//!   An `Interactive` arrival evicts the youngest queued `Batch` request
//!   to make room and is only shed itself when the backlog is
//!   all-`Interactive`; `Interactive` is never shed while `Batch` work
//!   is queued.
//! * **Transient pool exhaustion.** A refused decode step backs off and
//!   retries the same batch for up to [`POOL_RETRY_LIMIT`] consecutive
//!   ticks (pages may free as other work retires) before the preemption
//!   ladder engages. Injected transients (the `pool` chaos site) ride
//!   the same path and fire at most once per request, so they can never
//!   be mistaken for persistent exhaustion.
//! * **Replica failover.** A quarantined shard's decode sessions migrate
//!   by re-prefilling their token history on a surviving shard — the
//!   standard resume path, so the streams stay bit-exact; mid-prefill
//!   sessions return to their queue slot. The tick auditor additionally
//!   asserts that no quarantined shard still holds referenced pages
//!   after migration.
//! * **Speculation circuit breaker.**
//!   [`BREAKER_THRESHOLD`](crate::engine::speculative::BREAKER_THRESHOLD)
//!   consecutive draft failures (real or injected) disable drafting for
//!   [`BREAKER_COOLDOWN_ROUNDS`](crate::engine::speculative::BREAKER_COOLDOWN_ROUNDS)
//!   ticks, then the first round after the cooldown probes the draft
//!   again. Rounds meanwhile degrade to plain verify-path decode — the
//!   draft is advisory, so streams stay bit-exact throughout.
//! * **Client aborts.** A session whose client went away — its liveness
//!   token dropped, or the chaos plan's abort point was reached — is
//!   retired with [`Response::Aborted`] and its pages are released,
//!   instead of burning decode slots on a stream nobody reads.
//!
//! Fault injection itself lives in [`faults`]: seeded keyed-hash draws
//! at named sites (the CLI's `--chaos`), so the same seed replays the
//! same fault sequence and the chaos property tests can pin exact
//! report counters. Under any plan, every submitted request terminates
//! with exactly one typed [`Response`].
//!
//! ## Telemetry
//!
//! [`ServeReport`] aggregates fleet-wide counters plus a per-priority
//! breakdown ([`ServeReport::classes`]): completed generate streams,
//! time-to-first-token, per-decode-step latency percentiles (NaN-last
//! nearest-rank, shared with the global percentiles), and preemptions —
//! the numbers that show `Interactive` latency surviving `Batch` load.
//! Speculative runs additionally report drafted/accepted/rejected token
//! counters and [`ServeReport::acceptance_rate`], the fraction of draft
//! proposals the target confirmed.
//!
//! ## Machine-checked invariants
//!
//! The rules this module relies on are enforced by tooling, not
//! convention: `tools/odlri-lint` statically refuses panics on the
//! scheduler hot path and pool locks held across a forward, and keeps the
//! `KvError` tags in sync with their classifiers. In debug builds (and
//! therefore the whole test suite) the one-shot serving loop additionally
//! runs [`KvPool::audit_tables`](crate::runtime::kvpool::KvPool::audit_tables)
//! against the complete set of live block tables at every tick boundary,
//! and checks every touched pool for page leaks once the scheduler drains.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Weak};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::corpus;
use crate::engine::speculative::{BREAKER_COOLDOWN_ROUNDS, BREAKER_THRESHOLD};
use crate::engine::{Engine, Priority, Request, Response, Sampler, Sampling, Session};
use crate::runtime::kvpool::KvError;
use crate::runtime::native::KvCache;
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

pub mod faults;

use self::faults::{FaultInjector, FaultPlan};

/// Decode ticks a pool-refused batch backs off and retries (pages may
/// free as other work retires) before the preemption ladder engages.
pub const POOL_RETRY_LIMIT: usize = 3;

/// What the closed-loop bench clients submit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Full-sequence NLL scoring (the PR-1 workload).
    Score,
    /// KV-cached greedy generation of `max_new_tokens` per request.
    Generate { max_new_tokens: usize },
}

/// Batch-server run configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Total requests across all clients.
    pub requests: usize,
    /// Closed-loop client threads.
    pub clients: usize,
    /// Idle-only dynamic-batching deadline for partial scoring batches.
    pub deadline: Duration,
    /// Corpus seed for request payloads.
    pub seed: u64,
    pub workload: Workload,
    /// Sequence length (score) / prompt length (generate); 0 = engine seq.
    /// Validated against the workload and engine up front — a length the
    /// engine can never serve is an error, not a silent near-no-op.
    pub prompt_len: usize,
    /// Every request uses the *same* corpus window as its prompt (a shared
    /// system prompt) — the cross-session KV prefix-sharing benchmark knob.
    pub shared_prompt: bool,
    /// Prompt tokens prefilled per scheduler tick (0 = monolithic one-shot
    /// prefill). Only engines that implement [`Engine::prefill_chunk`]
    /// chunk; others fall back to one-shot regardless.
    pub prefill_chunk: usize,
    /// The last `batch_clients` client threads submit at
    /// [`Priority::Batch`]; the rest are `Interactive`.
    pub batch_clients: usize,
    /// When nonzero (generate workload), client 0's *first* request uses a
    /// prompt of this length — the long-prompt-vs-decode interference
    /// probe that chunked prefill exists to fix.
    pub long_prompt_len: usize,
    /// Bounded admission queue: an arrival that would push the queued
    /// total past this cap is shed (`Batch` before `Interactive`);
    /// 0 = unbounded.
    pub queue_cap: usize,
    /// Per-request deadline, in scheduler ticks, stamped on every
    /// generate request (0 = no deadline).
    pub deadline_ticks: usize,
    /// Seeded fault-injection plan (empty = no chaos). Seeded from
    /// [`ServeConfig::seed`].
    pub chaos: FaultPlan,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            requests: 120,
            clients: 4,
            deadline: Duration::from_millis(10),
            seed: 0,
            workload: Workload::Score,
            prompt_len: 0,
            shared_prompt: false,
            prefill_chunk: 0,
            batch_clients: 0,
            long_prompt_len: 0,
            queue_cap: 0,
            deadline_ticks: 0,
            chaos: FaultPlan::default(),
        }
    }
}

/// Per-priority-class serving outcome (completed generate streams only:
/// scores carry no priority and rejected requests produced no tokens).
#[derive(Clone, Debug)]
pub struct ClassReport {
    pub class: Priority,
    /// Completed generate requests in this class.
    pub requests: usize,
    /// Median time-to-first-token (submit → first sampled token), ms.
    pub ttft_p50_ms: f64,
    /// Per-decode-step latency percentiles for this class's sessions, ms.
    pub ms_per_tok_p50: f64,
    pub ms_per_tok_p99: f64,
    /// Sessions of this class preempted under KV pool pressure.
    pub preemptions: usize,
}

/// Serving outcome: per-request scores/latencies plus decode telemetry.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Mean next-token NLL per scored request, completion order.
    pub scores: Vec<f32>,
    /// Per-request wall latency (submit → response), completion order.
    pub latencies_s: Vec<f64>,
    /// Arrival ids (0-based intake order) in completion order — the
    /// fairness audit trail.
    pub completed: Vec<u64>,
    /// Executed scoring/prefill forwards (each prefill chunk counts one).
    pub batches: usize,
    /// Executed incremental decode steps.
    pub decode_steps: usize,
    /// Decode steps taken while at least one session was mid-chunked-
    /// prefill — the "long prompt did not stall decode" evidence.
    pub interleaved_decode_steps: usize,
    /// Tokens produced by generate requests (the first token of each
    /// request comes from its prefill; the rest from decode steps).
    pub generated_tokens: usize,
    /// Tokens produced by incremental decode steps specifically.
    pub decoded_tokens: usize,
    /// Wall time of each decode step (per-token latency samples).
    pub decode_step_latencies_s: Vec<f64>,
    /// Sessions preempted under KV pool pressure (pages reclaimed, state
    /// parked for a later bit-exact resume).
    pub preemptions: usize,
    /// Preempted sessions resumed by re-prefilling their token history.
    pub resumes: usize,
    /// Requests answered with [`Response::Rejected`] — per-request
    /// validation refusals. They appear in `completed`/`latencies_s`
    /// (each got an answer) but contribute no scores or tokens.
    pub rejected: usize,
    /// Tokens the draft engine proposed (speculative runs only).
    pub drafted_tokens: usize,
    /// Draft proposals the target confirmed and committed.
    pub accepted_tokens: usize,
    /// Draft proposals the target overruled (rolled back via truncate).
    pub rejected_tokens: usize,
    /// Single-token forwards the draft engine ran (catch-up + proposals).
    pub draft_steps: usize,
    /// Batched target verify forwards (one per session per decode tick
    /// when speculating).
    pub verify_steps: usize,
    /// Requests answered [`Response::TimedOut`]: their deadline passed
    /// before completion (pages released, stream discarded).
    pub timed_out: usize,
    /// Requests answered [`Response::Shed`] by the bounded admission
    /// queue (`Batch` work first, never `Interactive` before `Batch`).
    pub shed: usize,
    /// Sessions retired with [`Response::Aborted`]: the client went away
    /// mid-stream (dead liveness token or injected abort point).
    pub aborted: usize,
    /// Responses whose client stalled before draining them (the `slow`
    /// chaos site) — the scheduler kept serving regardless.
    pub slow_clients: usize,
    /// Decode ticks spent backing off on a transient pool refusal before
    /// the preemption ladder engaged.
    pub pool_retries: usize,
    /// Transient pool faults the chaos plan injected (at most one per
    /// request, so they never masquerade as persistent exhaustion).
    pub injected_pool_faults: usize,
    /// Replica shards quarantined by the chaos plan mid-run.
    pub shard_failures: usize,
    /// Sessions migrated off a quarantined shard (re-prefilled onto a
    /// survivor bit-exactly, or returned to their queue slot mid-prefill).
    pub failovers: usize,
    /// Speculative draft rounds that failed (real draft errors plus
    /// injected `draft` chaos faults).
    pub draft_failures: usize,
    /// Times the speculation circuit breaker tripped open
    /// ([`crate::engine::speculative::BREAKER_THRESHOLD`] consecutive
    /// draft failures).
    pub breaker_trips: usize,
    /// Draft rounds suppressed while the breaker was open (the sessions
    /// took plain verify-path decode instead).
    pub breaker_skipped: usize,
    /// Per-priority breakdown, indexed by [`Priority::index`].
    pub classes: Vec<ClassReport>,
    pub wall_secs: f64,
    /// `latencies_s` sorted once at construction (NaN-last), so percentile
    /// queries are O(1) instead of clone+sort per call.
    sorted_latencies_s: Vec<f64>,
}

/// Sort latency samples with NaNs of either sign at the END: a stray NaN
/// (clock anomaly, poisoned math) must not panic the report or shift every
/// percentile down (`total_cmp` alone would order -NaN first). Public: the
/// CLI's per-token latency report uses the same ordering.
pub fn sort_nan_last(xs: &[f64]) -> Vec<f64> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| match (a.is_nan(), b.is_nan()) {
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        (false, false) => a.total_cmp(b),
    });
    sorted
}

/// Nearest-rank percentile over a pre-sorted slice: the smallest element
/// whose rank fraction is ≥ p, i.e. index ⌈p·n⌉ − 1 (clamped).
pub fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return 0.0;
    }
    let rank = (p * n as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(n - 1)]
}

impl ServeReport {
    fn percentile(&self, p: f64) -> f64 {
        nearest_rank(&self.sorted_latencies_s, p)
    }

    pub fn p50_ms(&self) -> f64 {
        self.percentile(0.50) * 1e3
    }

    pub fn p95_ms(&self) -> f64 {
        self.percentile(0.95) * 1e3
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_secs == 0.0 {
            0.0
        } else {
            self.completed.len() as f64 / self.wall_secs
        }
    }

    /// Median per-step decode latency (≈ per-token latency at the served
    /// batch size).
    pub fn decode_p50_ms(&self) -> f64 {
        nearest_rank(&sort_nan_last(&self.decode_step_latencies_s), 0.50) * 1e3
    }

    /// Fraction of drafted tokens the target accepted; 0.0 when nothing
    /// was drafted (plain runs), so the field is always finite.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            0.0
        } else {
            self.accepted_tokens as f64 / self.drafted_tokens as f64
        }
    }

    /// Decode-step throughput: tokens produced by decode steps over decode
    /// wall time (each request's first token comes from prefill and is
    /// deliberately excluded from both numerator and denominator).
    pub fn decode_tokens_per_sec(&self) -> f64 {
        let total: f64 = self.decode_step_latencies_s.iter().sum();
        if total <= 0.0 {
            0.0
        } else {
            self.decoded_tokens as f64 / total
        }
    }
}

/// Per-class raw samples accumulated while serving.
#[derive(Default)]
struct ClassAccum {
    requests: usize,
    ttft_s: Vec<f64>,
    step_latencies_s: Vec<f64>,
    preemptions: usize,
}

/// Accumulating counters the scheduler fills; sealed into a [`ServeReport`]
/// (sorting the latency samples exactly once) when serving ends.
#[derive(Default)]
struct Stats {
    scores: Vec<f32>,
    latencies_s: Vec<f64>,
    completed: Vec<u64>,
    batches: usize,
    decode_steps: usize,
    interleaved_decode_steps: usize,
    generated_tokens: usize,
    decoded_tokens: usize,
    decode_step_latencies_s: Vec<f64>,
    preemptions: usize,
    resumes: usize,
    rejected: usize,
    drafted_tokens: usize,
    accepted_tokens: usize,
    rejected_tokens: usize,
    draft_steps: usize,
    verify_steps: usize,
    timed_out: usize,
    shed: usize,
    aborted: usize,
    slow_clients: usize,
    pool_retries: usize,
    injected_pool_faults: usize,
    shard_failures: usize,
    failovers: usize,
    draft_failures: usize,
    breaker_trips: usize,
    breaker_skipped: usize,
    classes: [ClassAccum; Priority::COUNT],
}

impl Stats {
    fn into_report(self, wall_secs: f64) -> ServeReport {
        let sorted_latencies_s = sort_nan_last(&self.latencies_s);
        let classes = self
            .classes
            .iter()
            .enumerate()
            .map(|(ci, acc)| {
                let ttft = sort_nan_last(&acc.ttft_s);
                let steps = sort_nan_last(&acc.step_latencies_s);
                ClassReport {
                    class: Priority::from_index(ci),
                    requests: acc.requests,
                    ttft_p50_ms: nearest_rank(&ttft, 0.50) * 1e3,
                    ms_per_tok_p50: nearest_rank(&steps, 0.50) * 1e3,
                    ms_per_tok_p99: nearest_rank(&steps, 0.99) * 1e3,
                    preemptions: acc.preemptions,
                }
            })
            .collect();
        ServeReport {
            scores: self.scores,
            latencies_s: self.latencies_s,
            completed: self.completed,
            batches: self.batches,
            decode_steps: self.decode_steps,
            interleaved_decode_steps: self.interleaved_decode_steps,
            generated_tokens: self.generated_tokens,
            decoded_tokens: self.decoded_tokens,
            decode_step_latencies_s: self.decode_step_latencies_s,
            preemptions: self.preemptions,
            resumes: self.resumes,
            rejected: self.rejected,
            drafted_tokens: self.drafted_tokens,
            accepted_tokens: self.accepted_tokens,
            rejected_tokens: self.rejected_tokens,
            draft_steps: self.draft_steps,
            verify_steps: self.verify_steps,
            timed_out: self.timed_out,
            shed: self.shed,
            aborted: self.aborted,
            slow_clients: self.slow_clients,
            pool_retries: self.pool_retries,
            injected_pool_faults: self.injected_pool_faults,
            shard_failures: self.shard_failures,
            failovers: self.failovers,
            draft_failures: self.draft_failures,
            breaker_trips: self.breaker_trips,
            breaker_skipped: self.breaker_skipped,
            classes,
            wall_secs,
            sorted_latencies_s,
        }
    }
}

/// One submitted request awaiting service.
struct Incoming {
    req: Request,
    done: mpsc::Sender<Response>,
    submitted: Instant,
    /// Client liveness token: upgradable while the client still holds
    /// its end of the stream. `None` = liveness not tracked (the
    /// pre-queued one-shot paths).
    alive: Option<Weak<()>>,
}

/// The robustness envelope riding alongside a request through every
/// holding area (queue, prefilling, active, preempted): its absolute
/// deadline, the client liveness token, and the chaos plan's injected
/// abort point. Fixed at arrival — requeues and preemptions carry it
/// unchanged, so nothing a request does extends its deadline.
#[derive(Clone)]
struct Envelope {
    /// Absolute scheduler tick past which the request times out
    /// (`u64::MAX` = no deadline).
    deadline_tick: u64,
    alive: Option<Weak<()>>,
    /// Chaos: the client goes away once this many tokens were produced.
    abort_after: Option<usize>,
}

impl Envelope {
    fn expired(&self, tick: u64) -> bool {
        tick > self.deadline_tick
    }

    fn client_gone(&self) -> bool {
        self.alive.as_ref().is_some_and(|w| w.upgrade().is_none())
    }
}

struct Arrived {
    id: u64,
    inc: Incoming,
    env: Envelope,
}

/// The scheduling class of a request. `Score` carries no priority field
/// and rides the `Interactive` queue, which keeps an all-default workload
/// identical to the historical single-queue FIFO.
fn req_class(req: &Request) -> Priority {
    match req {
        Request::Score { .. } => Priority::Interactive,
        Request::Generate { priority, .. } => *priority,
    }
}

/// An in-flight generation session in the decode pool.
struct ActiveGen {
    id: u64,
    class: Priority,
    session: Session,
    sampler: Sampler,
    /// Last sampled token, not yet fed back.
    next: i32,
    /// Greedy streams are the only ones the speculative tick drafts for:
    /// accepted draft tokens are argmaxes, which only equal the plain
    /// stream under greedy sampling.
    greedy: bool,
    /// This session's mirror on the draft engine (speculative runs).
    /// Lazily built by a draft prefill of the token history; dropped on
    /// preemption (releasing its draft-pool pages) and on any draft
    /// failure, then rebuilt the same way.
    draft_session: Option<Session>,
    produced: Vec<i32>,
    /// Wall time of each decode step this session took part in.
    step_latencies_s: Vec<f64>,
    budget: usize,
    prompt_len: usize,
    /// Submit → first sampled token (survives preemption: the token was
    /// already delivered to the stream state).
    ttft_s: f64,
    /// Speculative rounds this session has started (the `draft` chaos
    /// site's round key; resets with the session on resume).
    spec_rounds: u64,
    env: Envelope,
    done: mpsc::Sender<Response>,
    submitted: Instant,
}

/// A generate request mid-chunked-prefill: it owns a decode slot and a
/// growing KV cache but has not sampled its first token yet.
struct PrefillingGen {
    id: u64,
    class: Priority,
    prompt: Vec<i32>,
    /// The building cache, threaded through [`Engine::prefill_chunk`].
    state: Option<KvCache>,
    /// Prompt tokens fed so far (scheduler-side mirror of the cache len).
    fed: usize,
    budget: usize,
    max_new_tokens: usize,
    sampling: Sampling,
    env: Envelope,
    done: mpsc::Sender<Response>,
    submitted: Instant,
}

/// A generation session parked under KV pool pressure. Its cache (and
/// thereby every page it held) is gone; everything needed to continue the
/// stream bit-exactly — full token history, sampler state, the sampled but
/// not-yet-fed token — is kept.
struct Preempted {
    id: u64,
    class: Priority,
    /// Prompt plus every token fed back so far (`Session::tokens` at the
    /// moment of preemption) — re-prefilling exactly this recreates the
    /// dropped KV rows bit-identically.
    history: Vec<i32>,
    sampler: Sampler,
    next: i32,
    greedy: bool,
    produced: Vec<i32>,
    step_latencies_s: Vec<f64>,
    budget: usize,
    prompt_len: usize,
    ttft_s: f64,
    env: Envelope,
    done: mpsc::Sender<Response>,
    submitted: Instant,
}

/// How one speculative round went, for the circuit breaker's books.
/// Exactly one of these comes back from every [`Scheduler::spec_advance_one`]
/// call, so the breaker counts rounds — not tokens or errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DraftRound {
    /// The round never wanted to draft (sampled stream, budget clamp,
    /// draft context too small) — neutral for the breaker.
    Idle,
    /// The round wanted to draft but the open breaker suppressed it.
    Skipped,
    /// A drafting round that completed; resets the failure streak.
    Clean,
    /// The draft engine failed (or a `draft` chaos fault fired) and the
    /// round degraded to plain decode; counts toward tripping the breaker.
    Failed,
}

/// Continuous-batching scheduler state (single leader thread).
struct Scheduler<'a> {
    engine: &'a dyn Engine,
    max_batch: usize,
    /// Prompt tokens advanced per tick across all prefilling sessions
    /// (0 = one-shot prefill).
    prefill_chunk: usize,
    /// Draft engine for speculative decoding (same vocab as `engine`).
    draft: Option<&'a dyn Engine>,
    /// Speculation depth: draft tokens proposed per session per tick.
    speculate: usize,
    /// One FIFO queue per priority class, indexed by [`Priority::index`].
    queues: [VecDeque<Arrived>; Priority::COUNT],
    active: Vec<ActiveGen>,
    /// Sessions mid-chunked-prefill (each holds a decode slot).
    prefilling: Vec<PrefillingGen>,
    /// Sessions evicted from the pool, waiting to resume.
    preempted: Vec<Preempted>,
    stats: Stats,
    next_id: u64,
    /// Scheduler iterations so far — the deadline clock and the key of
    /// the tick-keyed chaos sites.
    tick: u64,
    /// Bounded admission queue cap (0 = unbounded).
    queue_cap: usize,
    /// Seeded fault oracle (None = no chaos configured).
    faults: Option<FaultInjector>,
    /// Consecutive decode ticks spent backing off on a transient pool
    /// refusal; resets on any successful decode step.
    pool_retry_streak: usize,
    /// Consecutive failed draft rounds (the breaker's trip counter).
    consec_draft_failures: usize,
    /// Speculation circuit breaker: drafting is suppressed until this
    /// tick (the first round at/after it is the probe).
    breaker_open_until: u64,
}

impl<'a> Scheduler<'a> {
    fn new(engine: &'a dyn Engine, prefill_chunk: usize) -> Scheduler<'a> {
        Scheduler {
            engine,
            max_batch: engine.spec().max_batch.max(1),
            prefill_chunk,
            draft: None,
            speculate: 0,
            queues: std::array::from_fn(|_| VecDeque::new()),
            active: Vec::new(),
            prefilling: Vec::new(),
            preempted: Vec::new(),
            stats: Stats::default(),
            next_id: 0,
            tick: 0,
            queue_cap: 0,
            faults: None,
            pool_retry_streak: 0,
            consec_draft_failures: 0,
            breaker_open_until: 0,
        }
    }

    /// Switch decode ticks to speculative draft/verify rounds against
    /// `draft`. Callers validate the pair first ([`validate_speculation`]).
    fn with_speculation(mut self, draft: &'a dyn Engine, k: usize) -> Scheduler<'a> {
        self.draft = Some(draft);
        self.speculate = k;
        self
    }

    /// Bound the admission queue at `cap` requests (0 = unbounded).
    fn with_queue_cap(mut self, cap: usize) -> Scheduler<'a> {
        self.queue_cap = cap;
        self
    }

    /// Attach a seeded fault oracle (chaos runs).
    fn with_faults(mut self, faults: FaultInjector) -> Scheduler<'a> {
        self.faults = Some(faults);
        self
    }

    fn enqueue(&mut self, inc: Incoming) {
        let id = self.next_id;
        self.next_id += 1;
        let class = req_class(&inc.req);
        let (deadline_ticks, max_new) = match &inc.req {
            Request::Generate {
                deadline_ticks,
                max_new_tokens,
                ..
            } => (*deadline_ticks, *max_new_tokens),
            Request::Score { .. } => (0, 0),
        };
        let env = Envelope {
            deadline_tick: if deadline_ticks == 0 {
                u64::MAX
            } else {
                self.tick.saturating_add(deadline_ticks as u64)
            },
            alive: inc.alive.clone(),
            abort_after: match self.faults.as_ref() {
                Some(f) if max_new > 0 => f.abort_after(id, max_new),
                _ => None,
            },
        };
        let arrived = Arrived { id, inc, env };
        if self.queue_cap > 0 && self.queues.iter().map(|q| q.len()).sum::<usize>() >= self.queue_cap
        {
            // Bounded admission queue: shed Batch work first. Interactive
            // is never shed while Batch work is queued — an Interactive
            // arrival evicts the youngest queued Batch request instead.
            if class == Priority::Batch {
                self.shed(arrived);
                return;
            }
            if let Some(victim) = self.queues[Priority::Batch.index()].pop_back() {
                self.shed(victim);
            } else {
                self.shed(arrived);
                return;
            }
        }
        self.queues[class.index()].push_back(arrived);
    }

    /// Answer one request with the typed overload refusal.
    fn shed(&mut self, a: Arrived) {
        self.stats.shed += 1;
        self.finish(a.id, a.inc.submitted, &a.inc.done, Response::Shed);
    }

    fn has_work(&self) -> bool {
        self.queues.iter().any(|q| !q.is_empty())
            || !self.active.is_empty()
            || !self.prefilling.is_empty()
            || !self.preempted.is_empty()
    }

    /// Decode slots held: decoding sessions plus mid-prefill sessions.
    fn slots_used(&self) -> usize {
        self.active.len() + self.prefilling.len()
    }

    /// One scheduler iteration: advance the tick clock, sweep expired and
    /// abandoned requests, inject tick-keyed chaos faults, resume
    /// preempted sessions, priority-class FIFO admission, one scoring
    /// pass, one decode step, then up to `prefill_chunk` tokens of
    /// chunked prefill. Decode runs *before* prefill so a long prompt can
    /// never stall running streams. Always makes progress when
    /// `has_work()`.
    fn step(&mut self) -> Result<()> {
        self.tick += 1;
        self.sweep_expired();
        self.inject_tick_faults()?;
        // Preempted sessions were admitted before anything still queued:
        // they get first claim on freed pool capacity.
        self.try_resume()?;
        // Admission: classes in urgency order, front-only within a class.
        // The first blocked head stops admission entirely — nothing
        // overtakes it (the fairness guarantee).
        let chunked = self.prefill_chunk > 0 && self.engine.supports_chunked_prefill();
        let mut score_batch: Vec<Arrived> = Vec::new();
        'admission: for ci in 0..Priority::COUNT {
            loop {
                let admissible = match self.queues[ci].front().map(|a| &a.inc.req) {
                    Some(Request::Score { .. }) => score_batch.len() < self.max_batch,
                    Some(Request::Generate { .. }) => {
                        // New sessions wait while any preempted one still
                        // needs its pages back — it was admitted first.
                        self.preempted.is_empty() && self.slots_used() < self.max_batch
                    }
                    None => break, // class drained; a lower class may admit
                };
                if !admissible {
                    break 'admission;
                }
                let Some(arrived) = self.queues[ci].pop_front() else {
                    break; // peeked Some above; defensive for the linter
                };
                let is_score = matches!(arrived.inc.req, Request::Score { .. });
                if is_score {
                    score_batch.push(arrived);
                } else if chunked {
                    self.admit_generate_chunked(arrived)?;
                } else if !self.admit_generate(arrived)? {
                    break 'admission; // pool momentarily full: requeued at the front
                }
            }
        }
        if !score_batch.is_empty() {
            self.run_scores(score_batch)?;
        }
        if !self.active.is_empty() {
            self.decode_once()?;
        }
        self.prefill_tick()?;
        Ok(())
    }

    /// Degradation sweep, first thing every tick: time out requests whose
    /// deadline passed and retire sessions whose client went away (dead
    /// liveness token, or the chaos plan's abort point reached). Every
    /// removal sends exactly one terminal [`Response`] and drops the
    /// session's caches, so its pages return to the pool immediately.
    fn sweep_expired(&mut self) {
        let tick = self.tick;
        // Queued arrivals (nothing produced yet): deadline + liveness.
        for ci in 0..Priority::COUNT {
            let mut i = 0;
            while i < self.queues[ci].len() {
                let timed = self.queues[ci][i].env.expired(tick);
                if !timed && !self.queues[ci][i].env.client_gone() {
                    i += 1;
                    continue;
                }
                let Some(a) = self.queues[ci].remove(i) else {
                    break; // index checked above; defensive for the linter
                };
                if timed {
                    self.stats.timed_out += 1;
                    self.finish(a.id, a.inc.submitted, &a.inc.done, Response::TimedOut);
                } else {
                    self.stats.aborted += 1;
                    self.finish(a.id, a.inc.submitted, &a.inc.done, Response::Aborted);
                }
            }
        }
        // Decode sessions: deadline, dead client, injected abort point.
        let mut i = 0;
        while i < self.active.len() {
            let timed = self.active[i].env.expired(tick);
            let gone = self.active[i].env.client_gone()
                || self.active[i]
                    .env
                    .abort_after
                    .is_some_and(|n| self.active[i].produced.len() >= n);
            if !timed && !gone {
                i += 1;
                continue;
            }
            // Cache (and draft mirror) drop here: pages released.
            let ag = self.active.swap_remove(i);
            if timed {
                self.stats.timed_out += 1;
                self.finish(ag.id, ag.submitted, &ag.done, Response::TimedOut);
            } else {
                self.stats.aborted += 1;
                self.finish(ag.id, ag.submitted, &ag.done, Response::Aborted);
            }
        }
        // Mid-prefill sessions: deadline + liveness (chunk cache drops).
        let mut i = 0;
        while i < self.prefilling.len() {
            let timed = self.prefilling[i].env.expired(tick);
            if !timed && !self.prefilling[i].env.client_gone() {
                i += 1;
                continue;
            }
            let p = self.prefilling.swap_remove(i);
            if timed {
                self.stats.timed_out += 1;
                self.finish(p.id, p.submitted, &p.done, Response::TimedOut);
            } else {
                self.stats.aborted += 1;
                self.finish(p.id, p.submitted, &p.done, Response::Aborted);
            }
        }
        // Parked sessions hold no pages, but their clients still deserve
        // a terminal answer — and an expired one must never resume.
        let mut i = 0;
        while i < self.preempted.len() {
            let timed = self.preempted[i].env.expired(tick);
            let gone = self.preempted[i].env.client_gone()
                || self.preempted[i]
                    .env
                    .abort_after
                    .is_some_and(|n| self.preempted[i].produced.len() >= n);
            if !timed && !gone {
                i += 1;
                continue;
            }
            let p = self.preempted.swap_remove(i);
            if timed {
                self.stats.timed_out += 1;
                self.finish(p.id, p.submitted, &p.done, Response::TimedOut);
            } else {
                self.stats.aborted += 1;
                self.finish(p.id, p.submitted, &p.done, Response::Aborted);
            }
        }
    }

    /// Tick-keyed chaos faults: a drawn replica failure quarantines one
    /// live shard through [`Engine::quarantine_one_shard`] and migrates
    /// every session stranded on it. Only drawn while sessions are in
    /// flight, so a quarantine always exercises migration (and the CI
    /// failover grep is deterministic instead of racing admission).
    fn inject_tick_faults(&mut self) -> Result<()> {
        let Some(f) = self.faults.as_ref() else {
            return Ok(());
        };
        if self.active.is_empty() && self.prefilling.is_empty() {
            return Ok(());
        }
        if let Some(selector) = f.replica_fault(self.tick) {
            if self.engine.quarantine_one_shard(selector).is_some() {
                self.stats.shard_failures += 1;
                self.migrate_orphans();
            }
        }
        Ok(())
    }

    /// Move every session whose KV lives on a quarantined shard off it:
    /// decode sessions park as preempted (their token history re-prefills
    /// onto a live shard bit-exactly — the standard resume path), and
    /// mid-prefill sessions return to their queue slot. Each migration
    /// counts one failover.
    fn migrate_orphans(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.engine.cache_orphaned(&self.active[i].session.cache) {
                self.park_active_at(i);
                self.stats.failovers += 1;
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.prefilling.len() {
            let orphaned = self.prefilling[i]
                .state
                .as_ref()
                .is_some_and(|c| self.engine.cache_orphaned(c));
            if orphaned {
                self.requeue_prefilling_at(i);
                self.stats.failovers += 1;
            } else {
                i += 1;
            }
        }
    }

    /// Debug-mode tick auditor: collect every live paged cache (active
    /// target sessions, their draft mirrors, mid-prefill chunk states),
    /// group them by underlying pool identity, and run
    /// [`crate::runtime::kvpool::KvPool::audit_tables`] on each pool
    /// against its complete table set. A pool seen on an earlier tick but
    /// holding no table this tick is audited against the empty set — every
    /// refcount must be back at zero (registered pages may stay cached).
    /// Newly seen pools are appended to `seen` so the caller can run the
    /// final no-leak check after the scheduler drains.
    #[cfg(debug_assertions)]
    fn audit_tick(&self, seen: &mut Vec<crate::runtime::kvpool::KvPool>) -> Result<()> {
        use crate::runtime::kvpool::{BlockTable, KvPool};
        let mut caches: Vec<&KvCache> = Vec::new();
        for a in &self.active {
            caches.push(&a.session.cache);
            if let Some(ds) = &a.draft_session {
                caches.push(&ds.cache);
            }
        }
        for p in &self.prefilling {
            if let Some(c) = &p.state {
                caches.push(c);
            }
        }
        let mut groups: Vec<(&KvPool, Vec<&BlockTable>)> = Vec::new();
        for c in caches {
            let Some((pool, table)) = c.pool_and_table() else {
                continue;
            };
            match groups.iter_mut().find(|(p, _)| p.ptr_eq(pool)) {
                Some((_, tables)) => tables.push(table),
                None => groups.push((pool, vec![table])),
            }
        }
        for (pool, tables) in &groups {
            pool.audit_tables(tables)
                .map_err(|e| anyhow!("kv pool audit failed at tick boundary: {e}"))?;
            if !seen.iter().any(|p| p.ptr_eq(pool)) {
                seen.push((*pool).clone());
            }
        }
        for pool in seen.iter() {
            if !groups.iter().any(|(p, _)| p.ptr_eq(pool)) {
                pool.audit_tables(&[])
                    .map_err(|e| anyhow!("kv pool audit failed at idle tick: {e}"))?;
            }
        }
        // Degradation ladder: after migration, a quarantined shard must
        // not hold a single referenced page — every stranded session was
        // parked (cache dropped) or requeued, so its pool audits clean
        // against the empty table set.
        for pool in self.engine.quarantined_pools() {
            pool.audit_tables(&[])
                .map_err(|e| anyhow!("quarantined shard still holds pages: {e}"))?;
        }
        Ok(())
    }

    /// Resume preempted sessions highest-class-oldest first while slots
    /// and pool pages allow: re-prefill the parked token history
    /// (recreating the dropped KV rows bit-identically), discard the
    /// logits — the pending token was already sampled — and rejoin the
    /// decode pool.
    fn try_resume(&mut self) -> Result<()> {
        while !self.preempted.is_empty() && self.slots_used() < self.max_batch {
            let Some(idx) = self
                .preempted
                .iter()
                .enumerate()
                .min_by_key(|(_, p)| (p.class, p.id))
                .map(|(i, _)| i)
            else {
                break; // loop condition guarantees non-empty
            };
            let history = self.preempted[idx].history.clone();
            match self.engine.prefill(&history) {
                Ok((session, _logits)) => {
                    debug_assert_eq!(session.tokens, history, "resume history drifted");
                    let p = self.preempted.swap_remove(idx);
                    self.stats.batches += 1;
                    self.stats.resumes += 1;
                    self.active.push(ActiveGen {
                        id: p.id,
                        class: p.class,
                        session,
                        sampler: p.sampler,
                        next: p.next,
                        greedy: p.greedy,
                        // The draft mirror was dropped with its pages at
                        // preemption; the next speculative tick rebuilds it.
                        draft_session: None,
                        produced: p.produced,
                        step_latencies_s: p.step_latencies_s,
                        budget: p.budget,
                        prompt_len: p.prompt_len,
                        ttft_s: p.ttft_s,
                        spec_rounds: 0,
                        env: p.env,
                        done: p.done,
                        submitted: p.submitted,
                    });
                }
                // Still no room: retry on a later iteration, after more
                // active sessions retired.
                Err(e) if KvError::is_pool_exhausted(&e) => break,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Request-level validation shared by both admission paths: `Some` is
    /// the typed per-request refusal message (empty prompt, context
    /// overflow, a prompt no amount of preemption can ever fit).
    fn validate_generate(&self, arrived: &Arrived) -> Option<String> {
        let spec = self.engine.spec();
        let Request::Generate { prompt, .. } = &arrived.inc.req else {
            unreachable!("generate admission on a non-generate request");
        };
        if prompt.is_empty() {
            Some("generate request with an empty prompt".to_string())
        } else if prompt.len() >= spec.max_context {
            Some(
                KvError::ContextOverflow {
                    have: prompt.len(),
                    extra: 1,
                    max: spec.max_context,
                }
                .to_string(),
            )
        } else {
            self.engine.pool_stats().and_then(|ps| {
                let p = ps.page_tokens.max(1);
                let need = prompt.len().div_ceil(p);
                // Never satisfiable: even an empty pool cannot hold the
                // prompt, so requeueing would spin forever.
                (need > ps.max_pages).then(|| {
                    KvError::PromptTooLarge {
                        prompt_pages: need,
                        max_pages: ps.max_pages,
                    }
                    .to_string()
                })
            })
        }
    }

    /// Prefill a generate request into the decode pool and sample its
    /// first token (the monolithic one-shot path). Returns `false` when
    /// the KV pool is momentarily exhausted and the request went back to
    /// the queue front.
    ///
    /// Validation failures of the request *itself* answer that one request
    /// with [`Response::Rejected`] and keep the loop running: one bad
    /// request must not abort every other client's queued and in-flight
    /// work. Fatal errors are reserved for engine/internal failures.
    fn admit_generate(&mut self, arrived: Arrived) -> Result<bool> {
        let spec = self.engine.spec();
        if let Some(error) = self.validate_generate(&arrived) {
            self.reject(arrived, error);
            return Ok(true);
        }
        if let Some(f) = self.faults.as_mut() {
            if f.pool_fault(arrived.id) {
                // Injected transient exhaustion (at most once per
                // request): the head keeps its turn at the queue front
                // and admission stops this tick, exactly like the real
                // momentary-pressure path below — never the fatal one.
                self.stats.injected_pool_faults += 1;
                let class = req_class(&arrived.inc.req);
                self.queues[class.index()].push_front(arrived);
                return Ok(false);
            }
        }
        let prefilled = {
            let Request::Generate { prompt, .. } = &arrived.inc.req else {
                unreachable!("admit_generate on a non-generate request");
            };
            self.engine.prefill(prompt)
        };
        let (session, logits) = match prefilled {
            Err(e)
                if KvError::is_pool_exhausted(&e)
                    && (!self.active.is_empty()
                        || !self.prefilling.is_empty()
                        || !self.preempted.is_empty()) =>
            {
                // Transient pressure: pages free up as running sessions
                // retire. The head of its class queue keeps its turn.
                let class = req_class(&arrived.inc.req);
                self.queues[class.index()].push_front(arrived);
                return Ok(false);
            }
            // The engine re-checks request-level bounds; its typed
            // refusals are per-request too, not server failures.
            Err(e) if KvError::is_context_overflow(&e) || KvError::is_prompt_too_large(&e) => {
                self.reject(arrived, format!("{e:#}"));
                return Ok(true);
            }
            Err(e) => return Err(e),
            Ok(ok) => ok,
        };
        let Arrived { id, inc, env } = arrived;
        let Request::Generate {
            prompt,
            max_new_tokens,
            sampling,
            priority,
            deadline_ticks: _, // the envelope carries the absolute tick
        } = inc.req
        else {
            unreachable!("admit_generate on a non-generate request");
        };
        let prompt_len = prompt.len();
        let budget = max_new_tokens.min(spec.max_context.saturating_sub(prompt_len));
        self.stats.batches += 1;
        let greedy = matches!(sampling, Sampling::Greedy);
        let mut sampler = Sampler::new(sampling);
        if budget == 0 {
            self.finish(
                id,
                inc.submitted,
                &inc.done,
                Response::Generated {
                    prompt_len,
                    tokens: Vec::new(),
                    step_latencies_s: Vec::new(),
                },
            );
            return Ok(true);
        }
        let next = sampler.sample(logits.row(logits.rows() - 1));
        let ag = ActiveGen {
            id,
            class: priority,
            session,
            sampler,
            next,
            greedy,
            draft_session: None,
            produced: vec![next],
            step_latencies_s: Vec::new(),
            budget,
            prompt_len,
            ttft_s: inc.submitted.elapsed().as_secs_f64(),
            spec_rounds: 0,
            env,
            done: inc.done,
            submitted: inc.submitted,
        };
        if ag.produced.len() >= ag.budget {
            self.retire(ag);
        } else {
            self.active.push(ag);
        }
        Ok(true)
    }

    /// Admit a generate request onto the chunked-prefill path: validate,
    /// then park it in the prefilling set (claiming a decode slot) without
    /// touching the engine — [`Scheduler::prefill_tick`] feeds the prompt
    /// incrementally after each decode step.
    fn admit_generate_chunked(&mut self, arrived: Arrived) -> Result<()> {
        let spec = self.engine.spec();
        if let Some(error) = self.validate_generate(&arrived) {
            self.reject(arrived, error);
            return Ok(());
        }
        let Arrived { id, inc, env } = arrived;
        let Request::Generate {
            prompt,
            max_new_tokens,
            sampling,
            priority,
            deadline_ticks: _, // the envelope carries the absolute tick
        } = inc.req
        else {
            unreachable!("admit_generate_chunked on a non-generate request");
        };
        let prompt_len = prompt.len();
        let budget = max_new_tokens.min(spec.max_context.saturating_sub(prompt_len));
        if budget == 0 {
            self.finish(
                id,
                inc.submitted,
                &inc.done,
                Response::Generated {
                    prompt_len,
                    tokens: Vec::new(),
                    step_latencies_s: Vec::new(),
                },
            );
            return Ok(());
        }
        self.prefilling.push(PrefillingGen {
            id,
            class: priority,
            prompt,
            state: None,
            fed: 0,
            budget,
            max_new_tokens,
            sampling,
            env,
            done: inc.done,
            submitted: inc.submitted,
        });
        Ok(())
    }

    /// Advance chunked prefills by up to `prefill_chunk` prompt tokens
    /// total this tick, highest-class-oldest session first, chunk
    /// boundaries page-aligned when that still makes progress. A session
    /// whose final chunk lands samples its first token and joins the
    /// decode pool immediately.
    fn prefill_tick(&mut self) -> Result<()> {
        let mut tokens_left = self.prefill_chunk;
        while tokens_left > 0 && !self.prefilling.is_empty() {
            let Some(idx) = self
                .prefilling
                .iter()
                .enumerate()
                .min_by_key(|(_, p)| (p.class, p.id))
                .map(|(i, _)| i)
            else {
                break; // loop condition guarantees non-empty
            };
            let (target, is_final) = {
                let p = &self.prefilling[idx];
                let total = p.prompt.len();
                let want = (p.fed + tokens_left).min(total);
                let target = if want < total {
                    // Stop at a page boundary so mid-prompt chunks fill
                    // whole pages — unless that would stall the session.
                    let pt = self.engine.pool_stats().map_or(0, |s| s.page_tokens);
                    if pt > 1 {
                        let aligned = (want / pt) * pt;
                        if aligned > p.fed {
                            aligned
                        } else {
                            want
                        }
                    } else {
                        want
                    }
                } else {
                    total
                };
                (target, target == total)
            };
            let chunk = {
                let p = &mut self.prefilling[idx];
                self.engine.prefill_chunk(&p.prompt, &mut p.state, target)
            };
            match chunk {
                Ok(logits) => {
                    self.stats.batches += 1;
                    let fed_before = self.prefilling[idx].fed;
                    self.prefilling[idx].fed = target;
                    tokens_left = tokens_left.saturating_sub(target - fed_before);
                    if is_final {
                        let p = self.prefilling.remove(idx);
                        self.finish_prefill(p, &logits);
                    }
                }
                Err(e) if KvError::is_pool_exhausted(&e) => {
                    if !self.active.is_empty() {
                        // Pages free as decode sessions retire; retry the
                        // chunk next tick.
                        break;
                    }
                    // Nothing decoding: relieve pressure now by returning
                    // the youngest lowest-class OTHER prefill to its queue.
                    if self.requeue_one_prefilling(Some(idx)) {
                        continue;
                    }
                    // Last prefill standing with preempted sessions parked:
                    // give up our own pages too — the preempted session was
                    // admitted first and holds none, so waiting would stall
                    // forever. (The request keeps its queue slot; admission
                    // re-admits it once the preempted have resumed.)
                    if !self.preempted.is_empty() && self.requeue_one_prefilling(None) {
                        continue;
                    }
                    // A lone prefill the pool cannot hold was pre-checked
                    // at admission — this is a genuine pool failure.
                    return Err(e);
                }
                Err(e)
                    if KvError::is_context_overflow(&e) || KvError::is_prompt_too_large(&e) =>
                {
                    let p = self.prefilling.remove(idx);
                    self.stats.rejected += 1;
                    self.finish(
                        p.id,
                        p.submitted,
                        &p.done,
                        Response::Rejected {
                            error: format!("{e:#}"),
                        },
                    );
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Seal a completed chunked prefill: sample the first token from the
    /// final chunk's logits (its last row is the last prompt position,
    /// bit-identical to one-shot prefill) and join the decode pool.
    fn finish_prefill(&mut self, p: PrefillingGen, logits: &Matrix) {
        let greedy = matches!(p.sampling, Sampling::Greedy);
        let mut sampler = Sampler::new(p.sampling);
        let next = sampler.sample(logits.row(logits.rows() - 1));
        let prompt_len = p.prompt.len();
        let Some(cache) = p.state else {
            // Every prefill_chunk call stores a cache into `state` before
            // returning Ok, and finish_prefill only runs on the final Ok
            // chunk — a missing cache is an engine contract bug. Refuse
            // the one request instead of killing the server.
            debug_assert!(false, "completed prefill lost its cache");
            self.stats.rejected += 1;
            self.finish(
                p.id,
                p.submitted,
                &p.done,
                Response::Rejected {
                    error: "internal: completed prefill lost its cache".to_string(),
                },
            );
            return;
        };
        let ag = ActiveGen {
            id: p.id,
            class: p.class,
            session: Session::new(p.prompt, cache),
            sampler,
            next,
            greedy,
            draft_session: None,
            produced: vec![next],
            step_latencies_s: Vec::new(),
            budget: p.budget,
            prompt_len,
            ttft_s: p.submitted.elapsed().as_secs_f64(),
            spec_rounds: 0,
            env: p.env,
            done: p.done,
            submitted: p.submitted,
        };
        if ag.produced.len() >= ag.budget {
            self.retire(ag);
        } else {
            self.active.push(ag);
        }
    }

    /// Drop one mid-prefill session (youngest of the lowest class,
    /// skipping `except`) back to its class queue — its chunk cache frees
    /// here. Cheaper than preempting a decoding session: nothing was
    /// sampled yet, so there is no stream state to park. Insertion keeps
    /// the queue id-ordered, preserving within-class FIFO.
    fn requeue_one_prefilling(&mut self, except: Option<usize>) -> bool {
        let Some(vi) = self
            .prefilling
            .iter()
            .enumerate()
            .filter(|(i, _)| Some(*i) != except)
            .max_by_key(|(_, p)| (p.class, p.id))
            .map(|(i, _)| i)
        else {
            return false;
        };
        self.requeue_prefilling_at(vi);
        true
    }

    /// Return the mid-prefill session at `vi` to its queue slot (its
    /// chunk cache frees here). The rebuilt request keeps its id and its
    /// envelope — the absolute deadline is NOT extended by the round
    /// trip — and insertion keeps the queue id-ordered (within-class
    /// FIFO).
    fn requeue_prefilling_at(&mut self, vi: usize) {
        let v = self.prefilling.remove(vi);
        let req = Request::Generate {
            prompt: v.prompt,
            max_new_tokens: v.max_new_tokens,
            sampling: v.sampling,
            priority: v.class,
            // The envelope's absolute tick stays authoritative; the
            // relative field is never re-read on this path.
            deadline_ticks: 0,
        };
        let alive = v.env.alive.clone();
        let q = &mut self.queues[v.class.index()];
        let pos = q.iter().position(|a| a.id > v.id).unwrap_or(q.len());
        q.insert(
            pos,
            Arrived {
                id: v.id,
                inc: Incoming {
                    req,
                    done: v.done,
                    submitted: v.submitted,
                    alive,
                },
                env: v.env,
            },
        );
    }

    /// Consult the chaos plan's `pool` site for every in-flight session
    /// and, when any draw fires, burn this decode tick as one backoff
    /// retry. Each request's fault is consumed exactly once, so the
    /// injected transient clears by itself — it can never escalate into
    /// the preemption ladder or the fatal lone-session path.
    fn inject_pool_backoff(&mut self) -> bool {
        let Some(f) = self.faults.as_mut() else {
            return false;
        };
        let mut hit = false;
        for a in &self.active {
            if f.pool_fault(a.id) {
                self.stats.injected_pool_faults += 1;
                hit = true;
            }
        }
        if hit {
            self.stats.pool_retries += 1;
        }
        hit
    }

    /// Advance every in-flight session by one token in a single engine
    /// call, then retire the ones that hit their budget. When the KV pool
    /// cannot back the step (page reservation runs *before* any compute,
    /// so a refusal leaves every session untouched), preempt the youngest
    /// session of the lowest class and retry the smaller batch; with one
    /// session left the exhaustion is fatal — a lone session cannot free
    /// its own pages (a mid-prefill session is requeued first if present).
    fn decode_once(&mut self) -> Result<()> {
        // Chaos: a drawn transient pool refusal (at most once per
        // request) backs this tick off through the retry path, before
        // any engine work — shared by the plain and speculative paths.
        if self.inject_pool_backoff() {
            return Ok(());
        }
        if let (Some(draft), true) = (self.draft, self.speculate > 0) {
            return self.speculative_tick(draft);
        }
        let engine = self.engine;
        loop {
            let tokens: Vec<i32> = self.active.iter().map(|a| a.next).collect();
            let t0 = Instant::now();
            let step = {
                let mut sessions: Vec<&mut Session> =
                    self.active.iter_mut().map(|a| &mut a.session).collect();
                engine.decode_step(&mut sessions, &tokens)
            };
            let logits = match step {
                Ok(l) => l,
                Err(e) if KvError::is_replica_failed(&e) => {
                    // An orphaned session reached the engine (the typed
                    // refusal ran before any compute, so nothing moved):
                    // migrate it and retry the survivors. No progress
                    // means the failure is not migration-shaped — fatal.
                    let before = self.stats.failovers;
                    self.migrate_orphans();
                    if self.stats.failovers == before {
                        return Err(e);
                    }
                    continue;
                }
                Err(e)
                    if KvError::is_pool_exhausted(&e)
                        && self.pool_retry_streak < POOL_RETRY_LIMIT =>
                {
                    // Transient exhaustion: back off and retry the same
                    // batch next tick — pages may free as scores answer
                    // and other work retires — before preempting anyone.
                    self.pool_retry_streak += 1;
                    self.stats.pool_retries += 1;
                    return Ok(());
                }
                Err(e) if KvError::is_pool_exhausted(&e) && self.active.len() > 1 => {
                    self.preempt_one();
                    continue;
                }
                Err(e)
                    if KvError::is_pool_exhausted(&e)
                        && self.requeue_one_prefilling(None) =>
                {
                    continue;
                }
                Err(e) => return Err(e),
            };
            self.pool_retry_streak = 0;
            let step_s = t0.elapsed().as_secs_f64();
            self.stats.decode_steps += 1;
            if !self.prefilling.is_empty() {
                self.stats.interleaved_decode_steps += 1;
            }
            self.stats.decode_step_latencies_s.push(step_s);
            self.stats.decoded_tokens += self.active.len();
            for (row, ag) in self.active.iter_mut().enumerate() {
                let next = ag.sampler.sample(logits.row(row));
                ag.next = next;
                ag.produced.push(next);
                ag.step_latencies_s.push(step_s);
            }
            let drained: Vec<ActiveGen> = self.active.drain(..).collect();
            for ag in drained {
                if ag.produced.len() >= ag.budget {
                    self.retire(ag);
                } else {
                    self.active.push(ag);
                }
            }
            return Ok(());
        }
    }

    /// One speculative decode tick: every in-flight session advances by
    /// one draft/verify round. Greedy sessions may commit up to
    /// `speculate + 1` tokens per round; sampled sessions (and greedy
    /// ones on their final budgeted token) take the plain single-token
    /// path through the same verify call. Sessions advance one at a time
    /// so a KV-pool refusal preempts under the exact policy of the plain
    /// path — lowest class, youngest first — and retries the survivors;
    /// a session preempted by an earlier retry in the same tick is simply
    /// skipped. Counts as ONE decode step in the report (one latency
    /// sample per tick keeps `decode_steps == decode_step_latencies_s`).
    fn speculative_tick(&mut self, draft: &'a dyn Engine) -> Result<()> {
        let t0 = Instant::now();
        let mut emitted_total = 0usize;
        // Circuit breaker: while open, every round this tick degrades to
        // plain verify-path decode; the first tick at/after
        // `breaker_open_until` probes the draft again.
        let allow_draft = self.tick >= self.breaker_open_until;
        let ids: Vec<u64> = self.active.iter().map(|a| a.id).collect();
        for id in ids {
            loop {
                let Some(i) = self.active.iter().position(|a| a.id == id) else {
                    break; // preempted by an earlier retry this tick
                };
                match self.spec_advance_one(draft, i, allow_draft) {
                    Ok((emitted, round)) => {
                        self.pool_retry_streak = 0;
                        match round {
                            DraftRound::Failed => {
                                self.stats.draft_failures += 1;
                                self.consec_draft_failures += 1;
                                if self.consec_draft_failures >= BREAKER_THRESHOLD {
                                    // Trip: suppress drafting for the
                                    // cooldown window starting next tick.
                                    self.stats.breaker_trips += 1;
                                    self.consec_draft_failures = 0;
                                    self.breaker_open_until =
                                        self.tick + 1 + BREAKER_COOLDOWN_ROUNDS as u64;
                                }
                            }
                            DraftRound::Clean => self.consec_draft_failures = 0,
                            DraftRound::Skipped => self.stats.breaker_skipped += 1,
                            DraftRound::Idle => {}
                        }
                        emitted_total += emitted;
                        // Retire at-budget sessions NOW, not at tick end:
                        // a later session's pool-exhaustion retry must
                        // never park an already-finished stream (it would
                        // resume and overshoot its budget).
                        if self.active[i].produced.len() >= self.active[i].budget {
                            let ag = self.active.remove(i);
                            self.retire(ag);
                        }
                        break;
                    }
                    Err(e) if KvError::is_replica_failed(&e) => {
                        // Orphaned by a quarantine this tick: migrate and
                        // re-run the position lookup (the session parked).
                        let before = self.stats.failovers;
                        self.migrate_orphans();
                        if self.stats.failovers == before {
                            return Err(e);
                        }
                    }
                    Err(e)
                        if KvError::is_pool_exhausted(&e)
                            && self.pool_retry_streak < POOL_RETRY_LIMIT =>
                    {
                        // Transient: this session's round retries next
                        // tick, before the preemption ladder engages.
                        self.pool_retry_streak += 1;
                        self.stats.pool_retries += 1;
                        break;
                    }
                    Err(e) if KvError::is_pool_exhausted(&e) && self.active.len() > 1 => {
                        self.preempt_one();
                    }
                    Err(e)
                        if KvError::is_pool_exhausted(&e)
                            && self.requeue_one_prefilling(None) => {}
                    Err(e) => return Err(e),
                }
            }
        }
        let step_s = t0.elapsed().as_secs_f64();
        self.stats.decode_steps += 1;
        if !self.prefilling.is_empty() {
            self.stats.interleaved_decode_steps += 1;
        }
        self.stats.decode_step_latencies_s.push(step_s);
        self.stats.decoded_tokens += emitted_total;
        Ok(())
    }

    /// Advance one session by a speculative round: draft up to
    /// `speculate` tokens greedily on the draft engine, verify the
    /// pending token plus all drafts in a single batched target
    /// [`Engine::verify_step`], commit the longest accepted prefix plus
    /// the target's own next token, and roll both KV caches back to the
    /// committed length. Returns the number of tokens emitted
    /// (`accepted + 1`, never past the session's budget because the
    /// draft count is clamped to `remaining - 1`).
    ///
    /// The draft is advisory: any draft-side failure (its pool
    /// exhausted, a smaller draft context, an engine refusal, an
    /// injected `draft` chaos fault) degrades this round toward plain
    /// single-token decode, drops the draft mirror for a later rebuild,
    /// and reports [`DraftRound::Failed`] so the caller's circuit
    /// breaker can count it. Only *target* errors escape, so the
    /// caller's retry loop reasons about exactly one KV pool;
    /// [`Engine::verify_step`] is atomic, leaving the session untouched
    /// for the post-preemption retry.
    fn spec_advance_one(
        &mut self,
        draft: &'a dyn Engine,
        i: usize,
        allow_draft: bool,
    ) -> Result<(usize, DraftRound)> {
        let t0 = Instant::now();
        let round_no = self.active[i].spec_rounds;
        self.active[i].spec_rounds += 1;
        let (greedy, remaining, history_len) = {
            let a = &self.active[i];
            (a.greedy, a.budget - a.produced.len(), a.session.tokens.len())
        };
        let mut m = if greedy {
            // Clamped so a fully accepted round (m drafts + bonus) lands
            // exactly on the budget, never past it.
            self.speculate.min(remaining.saturating_sub(1))
        } else {
            0
        };
        // The draft must hold history + pending + drafts; skip the round's
        // speculation rather than overflow a smaller draft context.
        if m > 0 && history_len + 1 + m > draft.spec().max_context {
            m = 0;
        }
        // Whether this round *would* draft, before the breaker and the
        // chaos plan have their say — the breaker-skip accounting key.
        let wanted = m > 0;
        if !allow_draft {
            m = 0;
        }
        let mut draft_failed = false;
        if m > 0 {
            if let Some(f) = self.faults.as_ref() {
                if f.draft_fault(self.active[i].id, round_no) {
                    // Injected draft failure: the mirror is presumed lost
                    // and this round degrades to plain decode.
                    draft_failed = true;
                    self.active[i].draft_session = None;
                    m = 0;
                }
            }
        }
        if m > 0 && self.active[i].draft_session.is_none() {
            // Fresh session or post-preemption resume: rebuild the draft
            // KV from the token history (bit-exact by the prefill
            // contract — KV rows are pure functions of the prefix).
            match draft.prefill(&self.active[i].session.tokens) {
                Ok((ds, _logits)) => self.active[i].draft_session = Some(ds),
                Err(_) => {
                    // No draft pages → no speculation this round; the
                    // breaker counts the starvation as a draft failure.
                    draft_failed = true;
                    m = 0;
                }
            }
        }
        let mut drafts: Vec<i32> = Vec::with_capacity(m);
        if m > 0 {
            // The rebuild above either stored a draft session or zeroed
            // `m`; if it is somehow absent, drafting nothing degrades this
            // round to plain single-token decode via the same verify call.
            let a = &mut self.active[i];
            if let Some(ds) = a.draft_session.as_mut() {
                let mut draft_ok = true;
                // Catch-up: after a fully accepted round the draft trails
                // the target by exactly the bonus token it never consumed.
                while draft_ok && ds.tokens.len() < a.session.tokens.len() {
                    let t = a.session.tokens[ds.tokens.len()];
                    match draft.decode_step(&mut [&mut *ds], &[t]) {
                        Ok(_) => self.stats.draft_steps += 1,
                        Err(_) => draft_ok = false,
                    }
                }
                let mut cur = a.next;
                while draft_ok && drafts.len() < m {
                    match draft.decode_step(&mut [&mut *ds], &[cur]) {
                        Ok(lg) => {
                            self.stats.draft_steps += 1;
                            cur = crate::engine::argmax(lg.row(0)) as i32;
                            drafts.push(cur);
                        }
                        Err(_) => draft_ok = false,
                    }
                }
                if !draft_ok {
                    // Unknown draft-side state: drop the mirror (pages
                    // free); tokens drafted before the failure are still
                    // verifiable.
                    a.draft_session = None;
                    draft_failed = true;
                }
            }
        }
        // One batched target step verifies the pending token + all drafts.
        let engine = self.engine;
        let a = &mut self.active[i];
        let start = a.session.tokens.len();
        let mut chunk = Vec::with_capacity(1 + drafts.len());
        chunk.push(a.next);
        chunk.extend_from_slice(&drafts);
        let logits = engine.verify_step(&mut a.session, &chunk)?;
        self.stats.verify_steps += 1;
        let (acc, _) = crate::engine::speculative::verify_accept(&drafts, &logits);
        // The bonus token goes through the session's sampler: argmax for
        // greedy (identical to the accept rule), a real draw for sampled
        // streams — whose RNG stream advances exactly once per emitted
        // token, same as plain serving.
        let bonus = a.sampler.sample(logits.row(acc));
        let committed = start + 1 + acc;
        a.session.truncate(committed);
        if let Some(ds) = a.draft_session.as_mut() {
            ds.truncate(committed);
        }
        a.produced.extend_from_slice(&drafts[..acc]);
        a.produced.push(bonus);
        a.next = bonus;
        a.step_latencies_s.push(t0.elapsed().as_secs_f64());
        self.stats.drafted_tokens += drafts.len();
        self.stats.accepted_tokens += acc;
        self.stats.rejected_tokens += drafts.len() - acc;
        let round = if draft_failed {
            DraftRound::Failed
        } else if wanted && !allow_draft {
            DraftRound::Skipped
        } else if wanted {
            DraftRound::Clean
        } else {
            DraftRound::Idle
        };
        Ok((acc + 1, round))
    }

    /// Park the youngest session of the lowest priority class (`Batch`
    /// before `Interactive`, LIFO within a class): its cache drops here
    /// (every page back to the pool) while token history, sampler state,
    /// and the pending token survive for a bit-exact resume.
    fn preempt_one(&mut self) {
        let idx = self
            .active
            .iter()
            .enumerate()
            .max_by_key(|(_, a)| (a.class, a.id))
            .map(|(i, _)| i)
            // lint:allow(hot-path-panic) callers check active.len() > 1; a silent no-op would spin the exhaustion retry loop forever
            .expect("preempt with no active session");
        let class = self.active[idx].class;
        self.stats.preemptions += 1;
        self.stats.classes[class.index()].preemptions += 1;
        self.park_active_at(idx);
    }

    /// Move `active[idx]` to the preempted list, dropping its caches
    /// (every page back to its pool) while keeping token history, sampler
    /// state, and the pending token for a bit-exact resume. Shared by the
    /// pressure preemption ladder (which books it as a preemption) and
    /// replica failover (which books it as a failover).
    fn park_active_at(&mut self, idx: usize) {
        let ag = self.active.remove(idx);
        // `ag.draft_session` drops here too: the draft-pool pages a parked
        // session held go back with the target pages.
        self.preempted.push(Preempted {
            id: ag.id,
            class: ag.class,
            history: ag.session.tokens,
            sampler: ag.sampler,
            next: ag.next,
            greedy: ag.greedy,
            produced: ag.produced,
            step_latencies_s: ag.step_latencies_s,
            budget: ag.budget,
            prompt_len: ag.prompt_len,
            ttft_s: ag.ttft_s,
            env: ag.env,
            done: ag.done,
            submitted: ag.submitted,
        });
    }

    /// Score the admitted requests through [`crate::engine::score_many`]
    /// (the single variable-batch-assembly implementation: equal-length
    /// grouping, no padding rows), then answer each request in arrival
    /// order.
    fn run_scores(&mut self, batch: Vec<Arrived>) -> Result<()> {
        let seqs: Vec<Vec<i32>> = batch
            .iter()
            .map(|a| match &a.inc.req {
                Request::Score { tokens } => tokens.clone(),
                Request::Generate { .. } => unreachable!("non-score request in score batch"),
            })
            .collect();
        let all_nlls = crate::engine::score_many(self.engine, &seqs)?;
        // Forward-count telemetry mirrors score_many's grouping: one
        // forward per (length, max_batch chunk); len < 2 runs none.
        let mut group_sizes: BTreeMap<usize, usize> = BTreeMap::new();
        for s in &seqs {
            if s.len() > 1 {
                *group_sizes.entry(s.len()).or_insert(0) += 1;
            }
        }
        self.stats.batches += group_sizes
            .values()
            .map(|&c| c.div_ceil(self.max_batch))
            .sum::<usize>();
        for (a, nlls) in batch.iter().zip(all_nlls) {
            let mean = if nlls.is_empty() {
                0.0
            } else {
                (nlls.iter().sum::<f64>() / nlls.len() as f64) as f32
            };
            self.stats.scores.push(mean);
            self.finish(a.id, a.inc.submitted, &a.inc.done, Response::Score { nlls });
        }
        Ok(())
    }

    fn retire(&mut self, ag: ActiveGen) {
        self.stats.generated_tokens += ag.produced.len();
        let acc = &mut self.stats.classes[ag.class.index()];
        acc.requests += 1;
        acc.ttft_s.push(ag.ttft_s);
        acc.step_latencies_s.extend_from_slice(&ag.step_latencies_s);
        self.finish(
            ag.id,
            ag.submitted,
            &ag.done,
            Response::Generated {
                prompt_len: ag.prompt_len,
                tokens: ag.produced,
                step_latencies_s: ag.step_latencies_s,
            },
        );
    }

    /// Answer one request with a typed per-request refusal and keep
    /// serving (counted separately from completions in the report).
    fn reject(&mut self, arrived: Arrived, error: String) {
        let Arrived { id, inc, env: _ } = arrived;
        self.stats.rejected += 1;
        self.finish(id, inc.submitted, &inc.done, Response::Rejected { error });
    }

    fn finish(&mut self, id: u64, submitted: Instant, done: &mpsc::Sender<Response>, resp: Response) {
        self.stats.latencies_s.push(submitted.elapsed().as_secs_f64());
        self.stats.completed.push(id);
        done.send(resp).ok();
    }
}

/// Run the scheduler over a pre-queued request list without client
/// threads: everything is enqueued up front (FIFO by list order) and the
/// scheduler steps until drained. Deterministic — the continuous-batching
/// and fairness tests (and benches) drive this directly. Returns the
/// responses in request order plus the report.
pub fn serve_oneshot(
    engine: &dyn Engine,
    reqs: Vec<Request>,
) -> Result<(Vec<Response>, ServeReport)> {
    serve_oneshot_chunked(engine, reqs, 0)
}

/// [`serve_oneshot`] with a per-tick chunked-prefill token budget
/// (0 = monolithic prefill; engines without chunk support fall back to
/// one-shot regardless).
pub fn serve_oneshot_chunked(
    engine: &dyn Engine,
    reqs: Vec<Request>,
    prefill_chunk: usize,
) -> Result<(Vec<Response>, ServeReport)> {
    serve_oneshot_inner(
        engine,
        None,
        reqs,
        &ServeOptions {
            prefill_chunk,
            ..ServeOptions::default()
        },
    )
}

/// [`serve_oneshot`] with speculative decoding: greedy generate streams
/// draft up to `k` tokens per tick on `draft` and commit them through
/// single batched target verify steps — bit-identical outputs, fewer
/// target forwards. `prefill_chunk` composes as in
/// [`serve_oneshot_chunked`]. The pair is validated up front: `k >= 1`
/// and matching vocabularies.
pub fn serve_oneshot_speculative(
    engine: &dyn Engine,
    draft: &dyn Engine,
    k: usize,
    reqs: Vec<Request>,
    prefill_chunk: usize,
) -> Result<(Vec<Response>, ServeReport)> {
    serve_oneshot_inner(
        engine,
        Some((draft, k)),
        reqs,
        &ServeOptions {
            prefill_chunk,
            ..ServeOptions::default()
        },
    )
}

/// Scheduler knobs for the pre-queued one-shot entry points (the chaos
/// property tests drive these; the plain wrappers use the defaults).
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    /// Per-tick chunked-prefill token budget (0 = monolithic).
    pub prefill_chunk: usize,
    /// Bounded admission queue cap (0 = unbounded).
    pub queue_cap: usize,
    /// Fault-injection plan (empty = no chaos).
    pub chaos: FaultPlan,
    /// Seed for the fault oracle (only read when `chaos` is non-empty).
    pub chaos_seed: u64,
}

/// [`serve_oneshot`] with the full scheduler option set — bounded queue,
/// seeded chaos plan — for robustness tests and the chaos smoke bench.
pub fn serve_oneshot_with(
    engine: &dyn Engine,
    reqs: Vec<Request>,
    opts: &ServeOptions,
) -> Result<(Vec<Response>, ServeReport)> {
    serve_oneshot_inner(engine, None, reqs, opts)
}

/// [`serve_oneshot_speculative`] with the full scheduler option set.
pub fn serve_oneshot_speculative_with(
    engine: &dyn Engine,
    draft: &dyn Engine,
    k: usize,
    reqs: Vec<Request>,
    opts: &ServeOptions,
) -> Result<(Vec<Response>, ServeReport)> {
    serve_oneshot_inner(engine, Some((draft, k)), reqs, opts)
}

/// Shared up-front validation for the speculative entry points.
fn validate_speculation(engine: &dyn Engine, spec: Option<(&dyn Engine, usize)>) -> Result<()> {
    if let Some((draft, k)) = spec {
        if k == 0 {
            bail!("speculation depth k must be at least 1");
        }
        crate::engine::speculative::check_pair(&draft.spec(), &engine.spec())?;
    }
    Ok(())
}

fn serve_oneshot_inner(
    engine: &dyn Engine,
    speculation: Option<(&dyn Engine, usize)>,
    reqs: Vec<Request>,
    opts: &ServeOptions,
) -> Result<(Vec<Response>, ServeReport)> {
    validate_speculation(engine, speculation)?;
    let t0 = Instant::now();
    let mut sched = Scheduler::new(engine, opts.prefill_chunk).with_queue_cap(opts.queue_cap);
    if let Some((draft, k)) = speculation {
        sched = sched.with_speculation(draft, k);
    }
    if !opts.chaos.is_empty() {
        sched = sched.with_faults(FaultInjector::new(opts.chaos.clone(), opts.chaos_seed));
    }
    let mut rxs = Vec::with_capacity(reqs.len());
    for req in reqs {
        let (dtx, drx) = mpsc::channel();
        sched.enqueue(Incoming {
            req,
            done: dtx,
            submitted: Instant::now(),
            alive: None,
        });
        rxs.push(drx);
    }
    // Debug builds (and therefore the whole test suite — the test profile
    // inherits dev) audit every KV pool against the complete set of live
    // block tables at each tick boundary, and check for page leaks once the
    // scheduler drains.
    #[cfg(debug_assertions)]
    let mut audited_pools: Vec<crate::runtime::kvpool::KvPool> = Vec::new();
    while sched.has_work() {
        sched.step()?;
        #[cfg(debug_assertions)]
        sched.audit_tick(&mut audited_pools)?;
    }
    #[cfg(debug_assertions)]
    for pool in &audited_pools {
        pool.audit_tables(&[])
            .map_err(|e| anyhow!("kv pool leak after drain: {e}"))?;
    }
    let mut out = Vec::with_capacity(rxs.len());
    for rx in rxs {
        out.push(
            rx.recv()
                .map_err(|_| anyhow!("request dropped without a response"))?,
        );
    }
    let report = sched.stats.into_report(t0.elapsed().as_secs_f64());
    Ok((out, report))
}

/// Run the closed-loop threaded server until every client request
/// completes: `cfg.clients` threads submit `cfg.requests` total requests of
/// `cfg.workload` (the last [`ServeConfig::batch_clients`] threads at
/// [`Priority::Batch`]), the leader thread runs the continuous-batching
/// scheduler.
pub fn run_server(engine: &dyn Engine, cfg: &ServeConfig) -> Result<ServeReport> {
    run_server_inner(engine, None, cfg)
}

/// [`run_server`] with speculative decoding against `draft` at depth `k`
/// (see [`serve_oneshot_speculative`] for the contract).
pub fn run_server_speculative(
    engine: &dyn Engine,
    draft: &dyn Engine,
    k: usize,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    run_server_inner(engine, Some((draft, k)), cfg)
}

fn run_server_inner(
    engine: &dyn Engine,
    speculation: Option<(&dyn Engine, usize)>,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    validate_speculation(engine, speculation)?;
    let spec = engine.spec();
    let prompt_len = if cfg.prompt_len == 0 {
        spec.seq
    } else {
        cfg.prompt_len
    };
    // Reject configs the engine can never serve before spawning a single
    // client, instead of the old silent behavior (scoring one token
    // produced empty "scores"; an over-long generate prompt burned a full
    // prefill to emit zero tokens).
    match cfg.workload {
        Workload::Score => {
            if prompt_len < 2 {
                bail!(
                    "score workload needs prompt_len >= 2 (got {prompt_len}): \
                     scoring predicts each token from its prefix"
                );
            }
            if prompt_len > spec.max_context {
                bail!(
                    "prompt_len {prompt_len} exceeds the engine's max_context {}",
                    spec.max_context
                );
            }
        }
        Workload::Generate { .. } => {
            if prompt_len >= spec.max_context {
                bail!(
                    "prompt_len {prompt_len} leaves no room to generate within \
                     the engine's max_context {}",
                    spec.max_context
                );
            }
            if cfg.long_prompt_len > 0 && cfg.long_prompt_len >= spec.max_context {
                bail!(
                    "long_prompt_len {} leaves no room to generate within \
                     the engine's max_context {}",
                    cfg.long_prompt_len,
                    spec.max_context
                );
            }
        }
    }
    let (tx, rx) = mpsc::channel::<Incoming>();
    let t_start = Instant::now();
    let mut sched = Scheduler::new(engine, cfg.prefill_chunk).with_queue_cap(cfg.queue_cap);
    if let Some((draft, k)) = speculation {
        sched = sched.with_speculation(draft, k);
    }
    if !cfg.chaos.is_empty() {
        sched = sched.with_faults(FaultInjector::new(cfg.chaos.clone(), cfg.seed));
    }
    // Client-side chaos (the `slow` site) runs in the client threads; the
    // shared counter folds into the report after the scope joins.
    let client_faults = FaultInjector::new(cfg.chaos.clone(), cfg.seed);
    let slow_count = AtomicU64::new(0);

    std::thread::scope(|s| -> Result<()> {
        // Client threads: each submits a burst of requests with jitter.
        let clients = cfg.clients.max(1);
        let per_client = cfg.requests / clients;
        let remainder = cfg.requests - per_client * clients;
        for c in 0..clients {
            let tx = tx.clone();
            let seed = cfg.seed;
            let workload = cfg.workload;
            let shared = cfg.shared_prompt;
            let deadline_ticks = cfg.deadline_ticks;
            let faults = &client_faults;
            let slow_count = &slow_count;
            let n = per_client + usize::from(c < remainder);
            // The last `batch_clients` threads submit throughput traffic.
            let class = if clients - c <= cfg.batch_clients.min(clients) {
                Priority::Batch
            } else {
                Priority::Interactive
            };
            let long_first = if c == 0 { cfg.long_prompt_len } else { 0 };
            s.spawn(move || {
                let mut rng = Pcg64::new(seed ^ c as u64, 77);
                // Shared-prompt mode: every client reads the same corpus
                // window, so sessions carry one system prompt and the KV
                // pool can share its prefix pages across all of them.
                let corpus_seed = if shared { seed } else { seed ^ c as u64 };
                let data = corpus::generate(corpus::Split::C4Sim, 200_000, corpus_seed);
                for i in 0..n {
                    let plen = if i == 0 && long_first > 0 && matches!(workload, Workload::Generate { .. }) {
                        long_first.min(data.len() - 2)
                    } else {
                        prompt_len
                    };
                    let start = if shared {
                        0
                    } else {
                        rng.below(data.len() - plen - 1)
                    };
                    let tokens: Vec<i32> = data[start..start + plen]
                        .iter()
                        .map(|&b| b as i32)
                        .collect();
                    let req = match workload {
                        Workload::Score => Request::Score { tokens },
                        Workload::Generate { max_new_tokens } => Request::Generate {
                            prompt: tokens,
                            max_new_tokens,
                            sampling: Sampling::Greedy,
                            priority: class,
                            deadline_ticks,
                        },
                    };
                    let (dtx, drx) = mpsc::channel();
                    // Liveness token: alive while this client still waits
                    // on the stream (it drops with `token` at loop exit).
                    let token = Arc::new(());
                    if tx
                        .send(Incoming {
                            req,
                            done: dtx,
                            submitted: Instant::now(),
                            alive: Some(Arc::downgrade(&token)),
                        })
                        .is_err()
                    {
                        return;
                    }
                    // Chaos `slow` site: stall before draining, so the
                    // scheduler proves it serves everyone else meanwhile.
                    if faults.slow_client(c as u64, i as u64) {
                        slow_count.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    // Closed loop: wait for the response before the next send.
                    let _resp = drx.recv().ok();
                    std::thread::sleep(Duration::from_millis(rng.below(5) as u64));
                }
            });
        }
        drop(tx);

        // Leader: continuous-batching loop. On an engine error, drain the
        // queue before propagating — dropping each queued `Incoming` drops
        // its `done` sender, so blocked clients wake up and wind down
        // instead of deadlocking the scope join.
        let mut serve = || -> Result<()> {
            loop {
                if !sched.has_work() {
                    match rx.recv() {
                        Ok(inc) => sched.enqueue(inc),
                        Err(_) => break, // all clients done
                    }
                }
                while let Ok(inc) = rx.try_recv() {
                    sched.enqueue(inc);
                }
                // Idle-only dynamic batching: nothing in flight (no decode,
                // no mid-prefill, no preempted session waiting on pages) →
                // hold a partial scoring batch briefly to let it fill.
                if sched.active.is_empty()
                    && sched.prefilling.is_empty()
                    && sched.preempted.is_empty()
                    && sched.queues.iter().map(|q| q.len()).sum::<usize>() < sched.max_batch
                {
                    let t0 = Instant::now();
                    while sched.queues.iter().map(|q| q.len()).sum::<usize>() < sched.max_batch
                    {
                        let left = cfg.deadline.saturating_sub(t0.elapsed());
                        if left.is_zero() {
                            break;
                        }
                        match rx.recv_timeout(left) {
                            Ok(inc) => sched.enqueue(inc),
                            Err(_) => break,
                        }
                    }
                }
                sched.step()?;
            }
            Ok(())
        };
        let result = serve();
        if result.is_err() {
            // Queued and in-flight requests still hold their responders:
            // drop them so every client blocked on a response wakes up,
            // then drain until all submitters hang up.
            for q in sched.queues.iter_mut() {
                q.clear();
            }
            sched.active.clear();
            sched.prefilling.clear();
            sched.preempted.clear();
            while rx.recv().is_ok() {}
        }
        result
    })?;

    let mut stats = std::mem::take(&mut sched.stats);
    stats.slow_clients = slow_count.load(Ordering::Relaxed) as usize;
    Ok(stats.into_report(t_start.elapsed().as_secs_f64()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineSpec, NativeEngine};
    use crate::model::ModelParams;
    use crate::runtime::FamilySpec;
    use std::sync::Mutex;

    /// Uniform-logits stand-in engine: instant forwards, exact expected
    /// score (ln vocab), records decode batch sizes so the tests can audit
    /// continuous batching.
    struct ToyEngine {
        vocab: usize,
        max_batch: usize,
        seq: usize,
        decode_sizes: Mutex<Vec<usize>>,
    }

    impl ToyEngine {
        fn new(vocab: usize, max_batch: usize, seq: usize) -> ToyEngine {
            ToyEngine {
                vocab,
                max_batch,
                seq,
                decode_sizes: Mutex::new(Vec::new()),
            }
        }
    }

    impl Engine for ToyEngine {
        fn spec(&self) -> EngineSpec {
            EngineSpec {
                vocab: self.vocab,
                max_batch: self.max_batch,
                seq: self.seq,
                max_context: 1024,
                kv_budget: 0,
            }
        }

        fn forward_batch(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<Matrix> {
            assert_eq!(tokens.len(), batch * seq);
            Ok(Matrix::zeros(batch * seq, self.vocab))
        }

        fn prefill(&self, tokens: &[i32]) -> Result<(Session, Matrix)> {
            Ok((
                Session::new(tokens.to_vec(), KvCache::new(0, 1)),
                Matrix::zeros(tokens.len(), self.vocab),
            ))
        }

        fn decode_step(&self, sessions: &mut [&mut Session], tokens: &[i32]) -> Result<Matrix> {
            self.decode_sizes.lock().unwrap().push(sessions.len());
            for (s, &t) in sessions.iter_mut().zip(tokens) {
                s.tokens.push(t);
            }
            Ok(Matrix::zeros(sessions.len(), self.vocab))
        }
    }

    fn gen_req(prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request::Generate {
            prompt,
            max_new_tokens,
            sampling: Sampling::Greedy,
            priority: Priority::default(),
            deadline_ticks: 0,
        }
    }

    fn gen_req_class(prompt: Vec<i32>, max_new_tokens: usize, priority: Priority) -> Request {
        Request::Generate {
            prompt,
            max_new_tokens,
            sampling: Sampling::Greedy,
            priority,
            deadline_ticks: 0,
        }
    }

    #[test]
    fn serves_every_score_request_with_exact_uniform_score() {
        let engine = ToyEngine::new(256, 4, 32);
        let cfg = ServeConfig {
            requests: 13,
            clients: 3,
            deadline: Duration::from_millis(2),
            seed: 9,
            workload: Workload::Score,
            ..ServeConfig::default()
        };
        let report = run_server(&engine, &cfg).unwrap();
        assert_eq!(report.scores.len(), 13);
        assert_eq!(report.latencies_s.len(), 13);
        assert_eq!(report.completed.len(), 13);
        assert!(report.batches >= (13usize).div_ceil(4));
        let want = (256f32).ln();
        for s in &report.scores {
            assert!((s - want).abs() < 1e-4, "score {s} != ln(256)");
        }
        assert!(report.p50_ms() >= 0.0 && report.p95_ms() >= report.p50_ms());
        assert!(report.requests_per_sec() > 0.0);
    }

    #[test]
    fn generation_workload_completes_every_request() {
        let engine = ToyEngine::new(64, 4, 16);
        let cfg = ServeConfig {
            requests: 9,
            clients: 3,
            deadline: Duration::from_millis(1),
            seed: 4,
            workload: Workload::Generate { max_new_tokens: 5 },
            prompt_len: 8,
            ..ServeConfig::default()
        };
        let report = run_server(&engine, &cfg).unwrap();
        assert_eq!(report.completed.len(), 9);
        assert_eq!(report.generated_tokens, 9 * 5);
        // One token per request comes from prefill; the rest from decode.
        assert_eq!(report.decoded_tokens, 9 * 4);
        assert!(report.decode_steps >= 4, "decode never engaged");
        assert_eq!(
            report.decode_steps,
            report.decode_step_latencies_s.len()
        );
        assert!(report.decode_tokens_per_sec() > 0.0);
        assert!(report.decode_p50_ms() >= 0.0);
        // All-default traffic lands in the Interactive class breakdown.
        assert_eq!(report.classes.len(), Priority::COUNT);
        assert_eq!(report.classes[0].class, Priority::Interactive);
        assert_eq!(report.classes[0].requests, 9);
        assert_eq!(report.classes[1].requests, 0);
    }

    #[test]
    fn fifo_admission_completes_equal_work_in_arrival_order() {
        // 6 equal-budget generates through a 2-slot engine: strict FIFO
        // admission ⇒ completion order is exactly arrival order.
        let engine = ToyEngine::new(16, 2, 8);
        let reqs: Vec<Request> = (0..6)
            .map(|i| gen_req(vec![1 + (i % 8), 2, 3], 3))
            .collect();
        let (resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert_eq!(report.completed, vec![0, 1, 2, 3, 4, 5]);
        for r in &resps {
            match r {
                Response::Generated { tokens, .. } => assert_eq!(tokens.len(), 3),
                other => panic!("wrong response {other:?}"),
            }
        }
    }

    #[test]
    fn new_sessions_join_in_flight_decode_batches() {
        // One long session plus short ones through a 2-slot engine: each
        // short session retires and the next is admitted while the long
        // one is still decoding — the decode batch stays at width 2
        // (continuous batching), and the long request finishes last.
        let engine = ToyEngine::new(16, 2, 8);
        let mut reqs = vec![gen_req(vec![1, 2], 7)];
        for _ in 0..3 {
            reqs.push(gen_req(vec![3, 4], 2));
        }
        let (_resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert_eq!(report.completed, vec![1, 2, 3, 0], "short ones first, FIFO");
        let sizes = engine.decode_sizes.lock().unwrap().clone();
        // Short sessions keep slotting in beside the long one: at least
        // the first few steps run at full width 2 even though no two
        // short sessions overlap in admission.
        assert!(
            sizes.iter().filter(|&&n| n == 2).count() >= 3,
            "decode batches never stayed full: {sizes:?}"
        );
        assert_eq!(report.generated_tokens, 7 + 3 * 2);
        assert_eq!(report.decoded_tokens, 6 + 3);
    }

    #[test]
    fn mixed_workload_head_of_queue_blocks_later_arrivals() {
        // Queue: [gen, gen, gen (blocked: 2 slots), score]. The score
        // arrives last and must NOT overtake the blocked generate.
        let engine = ToyEngine::new(16, 2, 8);
        let reqs = vec![
            gen_req(vec![1, 2], 4),
            gen_req(vec![1, 2], 4),
            gen_req(vec![1, 2], 2),
            Request::Score {
                tokens: vec![1, 2, 3, 4],
            },
        ];
        let (resps, report) = serve_oneshot(&engine, reqs).unwrap();
        // While the head generate (id 2) is blocked on a slot, the score
        // queued behind it is NOT admitted: it completes only after both
        // running generates retired and id 2 was admitted ahead of it —
        // an unfair scheduler would answer the instant score first.
        assert_eq!(
            report.completed,
            vec![0, 1, 3, 2],
            "FIFO admission order violated"
        );
        assert_eq!(resps.len(), 4);
    }

    #[test]
    fn interactive_requests_overtake_queued_batch_work_but_not_their_own_class() {
        // Arrival order: two Batch generates, then two Interactive ones,
        // through a 1-slot engine. Priority admission serves Interactive
        // first; *within* each class, completion stays in arrival order.
        let engine = ToyEngine::new(16, 1, 8);
        let reqs = vec![
            gen_req_class(vec![1, 2], 3, Priority::Batch),
            gen_req_class(vec![3, 4], 3, Priority::Batch),
            gen_req_class(vec![5, 6], 3, Priority::Interactive),
            gen_req_class(vec![7, 8], 3, Priority::Interactive),
        ];
        let (resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert_eq!(
            report.completed,
            vec![2, 3, 0, 1],
            "priority classes with within-class FIFO violated"
        );
        for r in &resps {
            match r {
                Response::Generated { tokens, .. } => assert_eq!(tokens.len(), 3),
                other => panic!("wrong response {other:?}"),
            }
        }
        assert_eq!(report.classes[Priority::Interactive.index()].requests, 2);
        assert_eq!(report.classes[Priority::Batch.index()].requests, 2);
    }

    #[test]
    fn per_class_report_breaks_out_generate_streams() {
        let engine = ToyEngine::new(16, 2, 8);
        let reqs = vec![
            gen_req_class(vec![1, 2], 3, Priority::Interactive),
            gen_req_class(vec![3, 4], 3, Priority::Batch),
            gen_req_class(vec![5, 6], 3, Priority::Interactive),
        ];
        let (_resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert_eq!(report.classes.len(), Priority::COUNT);
        let inter = &report.classes[Priority::Interactive.index()];
        let batch = &report.classes[Priority::Batch.index()];
        assert_eq!(inter.class, Priority::Interactive);
        assert_eq!(batch.class, Priority::Batch);
        assert_eq!(inter.requests, 2);
        assert_eq!(batch.requests, 1);
        assert_eq!(inter.preemptions + batch.preemptions, report.preemptions);
        assert!(inter.ttft_p50_ms >= 0.0 && batch.ttft_p50_ms >= 0.0);
        assert!(inter.ms_per_tok_p99 >= inter.ms_per_tok_p50);
        assert_eq!(report.interleaved_decode_steps, 0, "no chunking configured");
    }

    #[test]
    fn generation_output_is_independent_of_batch_composition() {
        // Real model: a request served concurrently produces exactly the
        // tokens it produces served alone (the engine's row-local decode
        // contract) — solo vs continuous-batched greedy streams are equal.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 17);
        let engine = NativeEngine::new(&params, 3, 8).unwrap();
        let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9]];
        let reqs: Vec<Request> = prompts.iter().map(|p| gen_req(p.clone(), 6)).collect();
        let (resps, _report) = serve_oneshot(&engine, reqs).unwrap();
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&engine, p, 6, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "batched stream diverged from solo");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
    }

    #[test]
    fn fused_generation_is_independent_of_batch_composition() {
        // Same invariant on the PACKED engine: every decode step routes
        // through the specialized fused dequant-dot kernel (row-local by
        // construction), so a session served inside a continuous batch
        // produces exactly the tokens it produces alone.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 19);
        let engine = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(3, 8);
        let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9]];
        let reqs: Vec<Request> = prompts.iter().map(|p| gen_req(p.clone(), 6)).collect();
        let (resps, _report) = serve_oneshot(&engine, reqs).unwrap();
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&engine, p, 6, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "fused batched stream diverged from solo");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        // And the specialized decode path was actually exercised.
        assert!(crate::fused::decode_kernel_calls() > 0, "decode kernel never ran");
    }

    #[test]
    fn percentile_is_nearest_rank_over_a_single_sort() {
        let stats = Stats {
            latencies_s: vec![0.04, 0.01, 0.03, 0.02],
            ..Default::default()
        };
        let report = stats.into_report(0.1);
        // n=4: p50 → ⌈2⌉−1 = idx 1 → 20 ms (the truncating formula said
        // 30 ms); p95 → ⌈3.8⌉−1 = idx 3 → 40 ms; p100 stays in range.
        assert!((report.p50_ms() - 20.0).abs() < 1e-9, "p50={}", report.p50_ms());
        assert!((report.p95_ms() - 40.0).abs() < 1e-9);
        assert!((report.percentile(1.0) * 1e3 - 40.0).abs() < 1e-9);
        // Single sample: every percentile is that sample.
        let one = Stats {
            latencies_s: vec![0.005],
            ..Default::default()
        }
        .into_report(0.1);
        assert!((one.p50_ms() - 5.0).abs() < 1e-9);
        assert!((one.p95_ms() - 5.0).abs() < 1e-9);
        // Empty: zeros, no panic.
        let empty = Stats::default().into_report(0.0);
        assert_eq!(empty.p50_ms(), 0.0);
        assert_eq!(empty.classes.len(), Priority::COUNT);
        assert_eq!(empty.classes[0].ttft_p50_ms, 0.0);
    }

    #[test]
    fn percentiles_survive_nan_latency_samples() {
        // One poisoned sample must not crash the report; finite percentiles
        // still come from the sorted finite prefix. The negative NaN (what
        // 0.0/0.0 actually produces on x86-64) is the regression case: it
        // must sort last, not first.
        let stats = Stats {
            scores: vec![0.0; 5],
            latencies_s: vec![0.004, -f64::NAN, 0.001, 0.003, 0.002],
            ..Default::default()
        };
        let report = stats.into_report(0.1);
        let p50 = report.p50_ms();
        assert!((p50 - 3.0).abs() < 1e-9, "p50 = {p50}");
        // p95 indexes the NaN slot — it must simply report it, not panic.
        assert!(report.p95_ms().is_nan());
    }

    #[test]
    fn zero_clients_clamps_to_one() {
        // vocab must cover the byte-level corpus (tokens up to 255).
        let engine = ToyEngine::new(256, 2, 8);
        let cfg = ServeConfig {
            requests: 3,
            clients: 0,
            deadline: Duration::from_millis(1),
            seed: 1,
            workload: Workload::Score,
            ..ServeConfig::default()
        };
        let report = run_server(&engine, &cfg).unwrap();
        assert_eq!(report.scores.len(), 3);
    }

    /// Distinct micro-vocab prompts (tokens 1..=10) of `len` tokens each.
    fn distinct_prompts(n: usize, len: usize) -> Vec<Vec<i32>> {
        (0..n)
            .map(|i| (0..len).map(|j| (1 + (i * 3 + j) % 10) as i32).collect())
            .collect()
    }

    #[test]
    fn preempted_sessions_resume_bit_exact_under_a_tiny_pool() {
        // Four sessions of one prompt-page each through a 3-page pool
        // (micro family: one 16-position page = 512 B). The fourth can't
        // even prefill until a slotholder retires (admission requeue), and
        // every decode past position 16 needs a second page that only
        // exists if another session is preempted. All streams must still
        // finish byte-identical to an unconstrained solo run.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 23);
        let engine = NativeEngine::new(&params, 4, 8)
            .unwrap()
            .with_kv_budget(3 * 512)
            .unwrap();
        let reference = NativeEngine::new(&params, 4, 8).unwrap();
        let prompts = distinct_prompts(4, 12);
        let reqs: Vec<Request> = prompts.iter().map(|p| gen_req(p.clone(), 10)).collect();
        let (resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert!(report.preemptions >= 1, "pool never forced a preemption");
        assert!(report.resumes >= 1, "no preempted session resumed");
        assert_eq!(
            report.preemptions, report.resumes,
            "every preemption must be matched by a resume"
        );
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&reference, p, 10, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens.len(), 10);
                    assert_eq!(tokens, &solo.tokens, "preempted stream diverged from solo");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        let ps = engine.pool_stats().unwrap();
        assert_eq!(ps.max_pages, 3);
        assert!(ps.peak_resident_pages <= ps.max_pages, "pool over-allocated");
    }

    #[test]
    fn fused_preempted_sessions_resume_bit_exact_under_a_tiny_pool() {
        // Same eviction-forcing budget on the PACKED engine: preemption +
        // re-prefill must preserve the fused greedy streams bit-exactly.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 19);
        let engine = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(3, 8)
            .with_kv_budget(3 * 512)
            .unwrap();
        let reference = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(3, 8);
        let prompts = distinct_prompts(3, 12);
        let reqs: Vec<Request> = prompts.iter().map(|p| gen_req(p.clone(), 10)).collect();
        let (resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert!(report.preemptions >= 1, "pool never forced a preemption");
        assert_eq!(report.preemptions, report.resumes);
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&reference, p, 10, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "fused preempted stream diverged");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        let ps = engine.pool_stats().unwrap();
        assert!(ps.peak_resident_pages <= ps.max_pages, "pool over-allocated");
    }

    #[test]
    fn preemption_parks_batch_class_before_interactive() {
        // One Batch arrival, then one Interactive, both decoding past the
        // page boundary under a 3-page pool: the pool can only back one of
        // them, and it must be the *Batch* session that gets parked — the
        // old youngest-first policy would have preempted the Interactive
        // one (it has the higher id). Both streams still finish bit-exact.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 23);
        let engine = NativeEngine::new(&params, 4, 8)
            .unwrap()
            .with_kv_budget(3 * 512)
            .unwrap();
        let reference = NativeEngine::new(&params, 4, 8).unwrap();
        let prompts = distinct_prompts(2, 12);
        let reqs = vec![
            gen_req_class(prompts[0].clone(), 10, Priority::Batch),
            gen_req_class(prompts[1].clone(), 10, Priority::Interactive),
        ];
        let (resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert!(report.preemptions >= 1, "pool never forced a preemption");
        let inter = &report.classes[Priority::Interactive.index()];
        let batch = &report.classes[Priority::Batch.index()];
        assert_eq!(
            inter.preemptions, 0,
            "an Interactive session was preempted while Batch work ran"
        );
        assert_eq!(batch.preemptions, report.preemptions);
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&reference, p, 10, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "priority-preempted stream diverged");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
    }

    #[test]
    fn chunked_prefill_interleaves_decode_under_a_long_prompt() {
        // A short request starts decoding; a long-prompt request then
        // prefills in small chunks. Decode steps must land *between* the
        // chunks (interleaved_decode_steps > 0) and both streams must be
        // byte-identical to unchunked solo runs — chunking is a scheduling
        // artifact, never an output artifact.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 37);
        let engine = NativeEngine::new(&params, 4, 8).unwrap();
        let reference = NativeEngine::new(&params, 4, 8).unwrap();
        let short: Vec<i32> = vec![1, 2, 3];
        let long: Vec<i32> = (0..20).map(|j| (1 + (j * 7) % 10) as i32).collect();
        let reqs = vec![gen_req(short.clone(), 8), gen_req(long.clone(), 4)];
        let (resps, report) = serve_oneshot_chunked(&engine, reqs, 4).unwrap();
        assert!(
            report.interleaved_decode_steps >= 3,
            "decode stalled behind the long prompt: {} interleaved steps",
            report.interleaved_decode_steps
        );
        assert!(report.decode_steps >= report.interleaved_decode_steps);
        // The long prompt took several chunk forwards, not one.
        assert!(report.batches > 2, "prompt was not actually chunked");
        assert_eq!(report.completed, vec![0, 1], "short request must finish first");
        for (p, (r, n)) in [(&short, (&resps[0], 8)), (&long, (&resps[1], 4))] {
            let solo = crate::engine::generate(&reference, p, n, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "chunk-prefilled stream diverged");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
    }

    #[test]
    fn chunked_serving_matches_one_shot_serving_exactly() {
        // The same request list served with and without chunking must
        // produce identical token streams — on both engine families.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 41);
        let prompts = distinct_prompts(3, 7);
        let reqs = |_: ()| -> Vec<Request> {
            prompts.iter().map(|p| gen_req(p.clone(), 6)).collect()
        };
        let native_a = NativeEngine::new(&params, 3, 8).unwrap();
        let native_b = NativeEngine::new(&params, 3, 8).unwrap();
        let (one_shot, _) = serve_oneshot(&native_a, reqs(())).unwrap();
        for chunk in [1usize, 3, 16] {
            let (chunked, _) = serve_oneshot_chunked(&native_b, reqs(()), chunk).unwrap();
            for (a, b) in one_shot.iter().zip(&chunked) {
                match (a, b) {
                    (
                        Response::Generated { tokens: ta, .. },
                        Response::Generated { tokens: tb, .. },
                    ) => assert_eq!(ta, tb, "chunk={chunk} diverged on native"),
                    other => panic!("wrong response pair {other:?}"),
                }
            }
        }
        let fused_a = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(3, 8);
        let fused_b = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(3, 8);
        let (one_shot_f, _) = serve_oneshot(&fused_a, reqs(())).unwrap();
        let (chunked_f, _) = serve_oneshot_chunked(&fused_b, reqs(()), 3).unwrap();
        for (a, b) in one_shot_f.iter().zip(&chunked_f) {
            match (a, b) {
                (
                    Response::Generated { tokens: ta, .. },
                    Response::Generated { tokens: tb, .. },
                ) => assert_eq!(ta, tb, "chunked serving diverged on fused"),
                other => panic!("wrong response pair {other:?}"),
            }
        }
    }

    #[test]
    fn replica_serving_matches_solo_streams() {
        // Serving through a 2-shard replica fleet (with chunked prefill)
        // must answer every request with exactly the solo engine's greedy
        // stream: shard routing and sub-batch stitching are invisible.
        use crate::engine::replicas::Replicas;
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 43);
        let base = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(2, 8);
        let reference = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(2, 8);
        let reps = Replicas::new(base, 2);
        let prompts = distinct_prompts(4, 6);
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                gen_req_class(
                    p.clone(),
                    5,
                    if i % 2 == 0 {
                        Priority::Interactive
                    } else {
                        Priority::Batch
                    },
                )
            })
            .collect();
        let (resps, report) = serve_oneshot_chunked(&reps, reqs, 4).unwrap();
        assert_eq!(report.completed.len(), 4);
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&reference, p, 5, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "replica-served stream diverged");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        // Both shards actually hosted sessions.
        let per = reps.shard_stats();
        assert!(per.iter().all(|s| s.allocated_pages > 0), "a shard sat idle");
    }

    #[test]
    fn identical_prompts_share_prefix_pages_across_sessions() {
        // Three sessions behind one 20-token "system prompt" (2 pages
        // each if private): adoption keeps the prompt resident once, and
        // the first divergent decode takes a COW copy instead of
        // corrupting the shared rows — so outputs still match solo.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 29);
        let engine = NativeEngine::new(&params, 3, 8).unwrap();
        let reference = NativeEngine::new(&params, 3, 8).unwrap();
        let prompt: Vec<i32> = (0..20).map(|j| (1 + j % 10) as i32).collect();
        let reqs: Vec<Request> = (0..3).map(|_| gen_req(prompt.clone(), 4)).collect();
        let (resps, _report) = serve_oneshot(&engine, reqs).unwrap();
        let solo = crate::engine::generate(&reference, &prompt, 4, Sampling::Greedy).unwrap();
        for r in &resps {
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "shared-prefix stream diverged");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        let ps = engine.pool_stats().unwrap();
        assert!(ps.shared_adoptions >= 2, "no prefix pages were adopted");
        assert!(ps.cow_copies >= 1, "divergence never took a COW copy");
        assert!(
            ps.peak_resident_pages < 3 * 2,
            "sharing saved nothing: peak {} pages for 3 sessions x 2 prompt pages",
            ps.peak_resident_pages
        );
    }

    #[test]
    fn pool_exhaustion_is_typed_and_never_over_allocates() {
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 31);
        let engine = NativeEngine::new(&params, 3, 8)
            .unwrap()
            .with_kv_budget(512) // exactly one 16-position page
            .unwrap();
        // A prompt needing 2 pages can never be admitted: a typed
        // Rejected response at admission, before any prefill work — and
        // the valid request queued behind it is still served.
        let big = gen_req(distinct_prompts(1, 20).pop().unwrap(), 2);
        let ok = gen_req(distinct_prompts(1, 8).pop().unwrap(), 2);
        let (resps, report) = serve_oneshot(&engine, vec![big, ok]).unwrap();
        assert_eq!(report.rejected, 1);
        match &resps[0] {
            Response::Rejected { error } => {
                assert!(error.contains(KvError::PROMPT_TOO_LARGE_TAG), "error: {error}");
                assert!(!error.contains(KvError::POOL_EXHAUSTED_TAG), "error: {error}");
            }
            other => panic!("never-fitting prompt not rejected: {other:?}"),
        }
        match &resps[1] {
            Response::Generated { tokens, .. } => assert_eq!(tokens.len(), 2),
            other => panic!("valid request behind a reject not served: {other:?}"),
        }
        // A lone session that outgrows the whole pool mid-decode is a
        // typed pool-exhaustion error (nobody left to preempt) — never a
        // panic, never an allocation past the budget.
        let long = gen_req(distinct_prompts(1, 14).pop().unwrap(), 10);
        let err = serve_oneshot(&engine, vec![long]).unwrap_err();
        assert!(KvError::is_pool_exhausted(&err), "err: {err:#}");
        let ps = engine.pool_stats().unwrap();
        assert_eq!(ps.max_pages, 1);
        assert!(ps.resident_pages <= ps.max_pages, "budget exceeded");
        assert!(ps.peak_resident_pages <= ps.max_pages, "budget exceeded at peak");
    }

    #[test]
    fn invalid_prompt_len_is_rejected_up_front() {
        let engine = ToyEngine::new(256, 2, 8);
        // Scoring a single token predicts nothing: the old code silently
        // returned empty scores per request.
        let cfg = ServeConfig {
            requests: 2,
            clients: 1,
            deadline: Duration::from_millis(1),
            seed: 3,
            workload: Workload::Score,
            prompt_len: 1,
            ..ServeConfig::default()
        };
        let err = run_server(&engine, &cfg).unwrap_err();
        assert!(
            format!("{err:#}").contains("prompt_len"),
            "unexpected error: {err:#}"
        );
        // A generate prompt at max_context leaves no room to decode: the
        // old code prefilled it and answered with zero tokens.
        let cfg = ServeConfig {
            workload: Workload::Generate { max_new_tokens: 4 },
            prompt_len: 1024, // == ToyEngine max_context
            ..cfg
        };
        let err = run_server(&engine, &cfg).unwrap_err();
        assert!(
            format!("{err:#}").contains("prompt_len"),
            "unexpected error: {err:#}"
        );
        // Same guard for the long-prompt probe knob.
        let cfg = ServeConfig {
            prompt_len: 8,
            long_prompt_len: 1024,
            ..cfg
        };
        let err = run_server(&engine, &cfg).unwrap_err();
        assert!(
            format!("{err:#}").contains("long_prompt_len"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn invalid_generate_requests_are_rejected_without_aborting_the_run() {
        // One empty prompt and one at max_context, with a valid score
        // request queued behind them: each invalid request gets its own
        // typed Rejected answer and the run keeps serving — a per-request
        // validation failure must never take down every other client.
        let engine = ToyEngine::new(256, 4, 16);
        let reqs = vec![
            gen_req(Vec::new(), 3),
            gen_req(vec![1; 1024], 3), // == ToyEngine max_context
            Request::Score {
                tokens: vec![1, 2, 3, 4],
            },
        ];
        let (resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert_eq!(report.rejected, 2);
        match &resps[0] {
            Response::Rejected { error } => {
                assert!(error.contains("empty prompt"), "error: {error}")
            }
            other => panic!("empty prompt not rejected: {other:?}"),
        }
        match &resps[1] {
            Response::Rejected { error } => {
                assert!(error.contains(KvError::CONTEXT_OVERFLOW_TAG), "error: {error}")
            }
            other => panic!("over-long prompt not rejected: {other:?}"),
        }
        match &resps[2] {
            Response::Score { nlls } => assert_eq!(nlls.len(), 3),
            other => panic!("score behind rejects not served: {other:?}"),
        }
    }

    #[test]
    fn throughput_is_finite_even_with_no_samples_or_zero_wall() {
        // Empty run, zero wall clock: both rates must be exactly 0.0 —
        // the 0/0 (NaN) and n/0 (inf) paths both lurked here.
        let empty = Stats::default().into_report(0.0);
        assert_eq!(empty.requests_per_sec(), 0.0);
        assert_eq!(empty.decode_tokens_per_sec(), 0.0);
        assert!(empty.requests_per_sec().is_finite());
        assert!(empty.decode_tokens_per_sec().is_finite());
        // Completed work under a zero-duration clock (coarse timers do
        // this): still finite, still zero.
        let report = Stats {
            completed: vec![0, 1],
            decoded_tokens: 5,
            decode_step_latencies_s: vec![0.0, 0.0],
            ..Default::default()
        }
        .into_report(0.0);
        assert_eq!(report.requests_per_sec(), 0.0);
        assert_eq!(report.decode_tokens_per_sec(), 0.0);
        assert!(report.decode_tokens_per_sec().is_finite());
    }

    #[test]
    fn shared_prompt_serving_completes() {
        // The shared-prompt knob routes every client to the same corpus
        // window; the run must complete normally on a pool-less engine.
        let engine = ToyEngine::new(256, 4, 16);
        let cfg = ServeConfig {
            requests: 6,
            clients: 3,
            deadline: Duration::from_millis(1),
            seed: 5,
            workload: Workload::Generate { max_new_tokens: 3 },
            prompt_len: 8,
            shared_prompt: true,
            ..ServeConfig::default()
        };
        let report = run_server(&engine, &cfg).unwrap();
        assert_eq!(report.completed.len(), 6);
        assert_eq!(report.generated_tokens, 6 * 3);
    }

    #[test]
    fn mixed_priority_threaded_serving_completes_with_class_stats() {
        // Closed-loop run with one Batch client, a long first prompt, and
        // chunked prefill on the toy engine (which does not support
        // chunking — the one-shot fallback must serve it all the same).
        let engine = ToyEngine::new(256, 4, 16);
        let cfg = ServeConfig {
            requests: 8,
            clients: 4,
            deadline: Duration::from_millis(1),
            seed: 11,
            workload: Workload::Generate { max_new_tokens: 3 },
            prompt_len: 8,
            prefill_chunk: 16,
            batch_clients: 1,
            long_prompt_len: 64,
            ..ServeConfig::default()
        };
        let report = run_server(&engine, &cfg).unwrap();
        assert_eq!(report.completed.len(), 8);
        assert_eq!(report.generated_tokens, 8 * 3);
        let inter = &report.classes[Priority::Interactive.index()];
        let batch = &report.classes[Priority::Batch.index()];
        assert_eq!(inter.requests + batch.requests, 8);
        assert!(batch.requests >= 1, "the batch client produced nothing");
    }

    #[test]
    fn speculative_serving_is_bit_identical_to_plain_serving_native() {
        // A *different-seed* draft (real rejections) at every depth: the
        // speculatively served streams must equal both plain serving and
        // the solo greedy reference, token for token — speculation is a
        // latency optimization, never an output artifact.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let target = NativeEngine::new(&ModelParams::init(&fam, 17), 4, 8).unwrap();
        let draft = NativeEngine::new(&ModelParams::init(&fam, 18), 4, 8).unwrap();
        let reference = NativeEngine::new(&ModelParams::init(&fam, 17), 4, 8).unwrap();
        let prompts = distinct_prompts(3, 7);
        let reqs = || -> Vec<Request> { prompts.iter().map(|p| gen_req(p.clone(), 8)).collect() };
        let (plain, _) = serve_oneshot(&target, reqs()).unwrap();
        for k in [1usize, 2, 4, 8] {
            let (spec, report) = serve_oneshot_speculative(&target, &draft, k, reqs(), 0).unwrap();
            for ((p, a), b) in prompts.iter().zip(&plain).zip(&spec) {
                let solo = crate::engine::generate(&reference, p, 8, Sampling::Greedy).unwrap();
                match (a, b) {
                    (
                        Response::Generated { tokens: ta, .. },
                        Response::Generated { tokens: tb, .. },
                    ) => {
                        assert_eq!(tb, &solo.tokens, "k={k}: speculative diverged from solo");
                        assert_eq!(ta, tb, "k={k}: speculative diverged from plain serving");
                    }
                    other => panic!("wrong response pair {other:?}"),
                }
            }
            assert!(report.drafted_tokens > 0, "k={k}: nothing was drafted");
            assert_eq!(
                report.accepted_tokens + report.rejected_tokens,
                report.drafted_tokens
            );
            assert!(report.verify_steps > 0);
            assert!((0.0..=1.0).contains(&report.acceptance_rate()));
            // Every request's first token comes from prefill, the rest
            // from draft/verify rounds — same ledger as plain decode.
            assert_eq!(report.decoded_tokens, report.generated_tokens - prompts.len());
        }
    }

    #[test]
    fn speculative_serving_is_bit_identical_on_the_fused_pair() {
        // The ODLRI pairing from the paper's serving story: a 2-bit pack
        // drafts for a 4-bit pack of the same checkpoint — high agreement,
        // verified batched through the decode-regime fused kernel. Also
        // composes with chunked prefill (the tick loop interleaves draft,
        // verify, and prompt chunks).
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 23);
        let target = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(3, 8);
        let draft = crate::fused::FusedModel::pack_dense(&params, "uniform", 2, 16)
            .unwrap()
            .with_shape(3, 8);
        let reference = crate::fused::FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(3, 8);
        let prompts = distinct_prompts(3, 7);
        let reqs = || -> Vec<Request> { prompts.iter().map(|p| gen_req(p.clone(), 8)).collect() };
        for chunk in [0usize, 3] {
            let (spec, report) =
                serve_oneshot_speculative(&target, &draft, 4, reqs(), chunk).unwrap();
            for (p, r) in prompts.iter().zip(&spec) {
                let solo = crate::engine::generate(&reference, p, 8, Sampling::Greedy).unwrap();
                match r {
                    Response::Generated { tokens, .. } => {
                        assert_eq!(tokens, &solo.tokens, "chunk={chunk}: fused spec diverged");
                    }
                    other => panic!("wrong response {other:?}"),
                }
            }
            assert!(report.drafted_tokens > 0);
            assert!(report.accepted_tokens > 0, "2-bit draft never agreed with 4-bit target");
        }
    }

    #[test]
    fn identical_draft_accepts_every_token_and_cuts_target_ticks() {
        // Draft == target: every proposal verifies, so acceptance is 1.0
        // and the run needs far fewer target decode ticks than plain
        // serving — the whole point of the draft/verify split.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 29);
        let target = NativeEngine::new(&params, 4, 8).unwrap();
        let draft = NativeEngine::new(&params, 4, 8).unwrap();
        let prompts = distinct_prompts(3, 7);
        let reqs = || -> Vec<Request> { prompts.iter().map(|p| gen_req(p.clone(), 8)).collect() };
        let (_, plain_report) = serve_oneshot(&target, reqs()).unwrap();
        let (_, spec_report) = serve_oneshot_speculative(&target, &draft, 4, reqs(), 0).unwrap();
        assert_eq!(spec_report.rejected_tokens, 0, "identical draft was rejected");
        assert!((spec_report.acceptance_rate() - 1.0).abs() < 1e-12);
        assert!(
            spec_report.decode_steps < plain_report.decode_steps,
            "speculation saved no ticks: {} vs {}",
            spec_report.decode_steps,
            plain_report.decode_steps
        );
        assert_eq!(spec_report.generated_tokens, plain_report.generated_tokens);
    }

    #[test]
    fn speculation_survives_preemption_under_a_five_page_pool() {
        // Four sessions through a 5-page target pool: every session needs
        // a second page mid-stream, so the scheduler preempts mid-
        // speculation (dropping target pages AND the parked session's
        // draft mirror), resumes by re-prefilling both, and still delivers
        // streams bit-identical to an unconstrained solo run.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 23);
        let target = NativeEngine::new(&params, 4, 8)
            .unwrap()
            .with_kv_budget(5 * 512)
            .unwrap();
        let draft = NativeEngine::new(&ModelParams::init(&fam, 31), 4, 8).unwrap();
        let reference = NativeEngine::new(&params, 4, 8).unwrap();
        let prompts = distinct_prompts(4, 12);
        let reqs: Vec<Request> = prompts.iter().map(|p| gen_req(p.clone(), 10)).collect();
        let (resps, report) = serve_oneshot_speculative(&target, &draft, 4, reqs, 0).unwrap();
        assert!(report.preemptions >= 1, "5-page pool never forced a preemption");
        assert_eq!(
            report.preemptions, report.resumes,
            "every preemption must be matched by a resume"
        );
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&reference, p, 10, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens.len(), 10);
                    assert_eq!(tokens, &solo.tokens, "preempted speculative stream diverged");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        let ps = target.pool_stats().unwrap();
        assert_eq!(ps.max_pages, 5);
        assert!(ps.peak_resident_pages <= ps.max_pages, "target pool over-allocated");
    }

    #[test]
    fn draft_pool_pressure_degrades_to_plain_decode_without_corruption() {
        // A draft engine with a single KV page cannot mirror two sessions
        // (and loses even the one it has when it crosses the page
        // boundary). Every draft-side refusal must silently fall back to
        // plain decode for that round — the streams stay bit-exact and
        // the run never errors.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 37);
        let target = NativeEngine::new(&params, 4, 8).unwrap();
        let draft = NativeEngine::new(&ModelParams::init(&fam, 38), 4, 8)
            .unwrap()
            .with_kv_budget(512)
            .unwrap();
        let reference = NativeEngine::new(&params, 4, 8).unwrap();
        let prompts = distinct_prompts(2, 12);
        let reqs: Vec<Request> = prompts.iter().map(|p| gen_req(p.clone(), 10)).collect();
        let (resps, report) = serve_oneshot_speculative(&target, &draft, 2, reqs, 0).unwrap();
        assert_eq!(report.preemptions, 0, "target pool is unbounded here");
        assert!(report.verify_steps > 0);
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&reference, p, 10, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "draft-starved stream diverged");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        let ps = draft.pool_stats().unwrap();
        assert!(ps.peak_resident_pages <= ps.max_pages, "draft pool over-allocated");
    }

    #[test]
    fn sampled_streams_under_speculation_match_plain_serving() {
        // Non-greedy sessions must NOT be drafted for (accepted drafts are
        // argmaxes); they take the single-token path through verify with
        // the bonus drawn from their own sampler — one RNG draw per
        // emitted token, exactly like plain serving. Mixed with a greedy
        // session that DOES speculate.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 41);
        let target = NativeEngine::new(&params, 4, 8).unwrap();
        let draft = NativeEngine::new(&ModelParams::init(&fam, 42), 4, 8).unwrap();
        let sampled = Sampling::TopK {
            k: 3,
            temperature: 1.0,
            seed: 5,
        };
        let reqs = || -> Vec<Request> {
            vec![
                Request::Generate {
                    prompt: vec![1, 2, 3, 4],
                    max_new_tokens: 7,
                    sampling: sampled.clone(),
                    priority: Priority::Interactive,
                    deadline_ticks: 0,
                },
                gen_req(vec![5, 6, 7], 7),
            ]
        };
        let (plain, _) = serve_oneshot(&target, reqs()).unwrap();
        let (spec, report) = serve_oneshot_speculative(&target, &draft, 4, reqs(), 0).unwrap();
        for (i, (a, b)) in plain.iter().zip(&spec).enumerate() {
            match (a, b) {
                (
                    Response::Generated { tokens: ta, .. },
                    Response::Generated { tokens: tb, .. },
                ) => assert_eq!(ta, tb, "request {i} diverged under speculation"),
                other => panic!("wrong response pair {other:?}"),
            }
        }
        // Only the greedy session drafted: 7 tokens, first from prefill,
        // so at most 6 proposals ever needed.
        assert!(report.drafted_tokens > 0 && report.drafted_tokens <= 6 + report.rejected_tokens);
    }

    #[test]
    fn speculative_pair_is_validated_up_front() {
        let target = ToyEngine::new(32, 2, 8);
        let draft_ok = ToyEngine::new(32, 2, 8);
        let draft_bad = ToyEngine::new(16, 2, 8);
        let reqs = vec![gen_req(vec![1, 2, 3], 4)];
        let err = serve_oneshot_speculative(&target, &draft_ok, 0, reqs.clone(), 0).unwrap_err();
        assert!(format!("{err:#}").contains("at least 1"), "err: {err:#}");
        let err = serve_oneshot_speculative(&target, &draft_bad, 2, reqs, 0).unwrap_err();
        assert!(format!("{err:#}").contains("vocab"), "err: {err:#}");
        // Same guards on the threaded server.
        let cfg = ServeConfig {
            requests: 2,
            clients: 1,
            deadline: Duration::from_millis(1),
            workload: Workload::Generate { max_new_tokens: 3 },
            prompt_len: 4,
            ..ServeConfig::default()
        };
        let err = run_server_speculative(&target, &draft_bad, 2, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("vocab"), "err: {err:#}");
    }

    #[test]
    fn threaded_speculative_serving_completes_with_full_acceptance_on_the_toy_pair() {
        // ToyEngine logits are all zeros → every argmax is token 0, so an
        // identical toy draft is always right: the threaded speculative
        // server must complete every request with acceptance 1.0. This
        // also exercises the *default* `Engine::verify_step` (sequential
        // decode fallback) inside the scheduler.
        let target = ToyEngine::new(256, 4, 16);
        let draft = ToyEngine::new(256, 4, 16);
        let cfg = ServeConfig {
            requests: 6,
            clients: 2,
            deadline: Duration::from_millis(1),
            seed: 7,
            workload: Workload::Generate { max_new_tokens: 5 },
            prompt_len: 8,
            ..ServeConfig::default()
        };
        let report = run_server_speculative(&target, &draft, 3, &cfg).unwrap();
        assert_eq!(report.completed.len(), 6);
        assert_eq!(report.generated_tokens, 6 * 5);
        assert!(report.drafted_tokens > 0);
        assert_eq!(report.rejected_tokens, 0);
        assert!((report.acceptance_rate() - 1.0).abs() < 1e-12);
        assert_eq!(report.decoded_tokens, report.generated_tokens - 6);
    }

    #[test]
    fn client_abort_mid_stream_releases_the_session_without_wedging() {
        // Three clients decode concurrently; one drops its responder AND
        // its liveness token mid-stream. The scheduler must retire that
        // session with a typed Aborted (page release audited below) and
        // the two survivors must finish byte-identical to solo runs.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 47);
        let engine = NativeEngine::new(&params, 4, 8).unwrap();
        let reference = NativeEngine::new(&params, 4, 8).unwrap();
        let prompts = distinct_prompts(3, 8);
        let mut sched = Scheduler::new(&engine, 0);
        let mut rxs = Vec::new();
        let mut tokens = Vec::new();
        for p in &prompts {
            let (dtx, drx) = mpsc::channel();
            let token = Arc::new(());
            sched.enqueue(Incoming {
                req: gen_req(p.clone(), 8),
                done: dtx,
                submitted: Instant::now(),
                alive: Some(Arc::downgrade(&token)),
            });
            rxs.push(drx);
            tokens.push(token);
        }
        for _ in 0..3 {
            sched.step().unwrap();
        }
        assert_eq!(sched.active.len(), 3, "all three should be mid-decode");
        // Client 1 goes away mid-stream.
        drop(rxs.remove(1));
        drop(tokens.remove(1));
        #[cfg(debug_assertions)]
        let mut seen: Vec<crate::runtime::kvpool::KvPool> = Vec::new();
        while sched.has_work() {
            sched.step().unwrap();
            #[cfg(debug_assertions)]
            sched.audit_tick(&mut seen).unwrap();
        }
        assert_eq!(sched.stats.aborted, 1, "the dead client was not detected");
        assert_eq!(sched.stats.timed_out, 0);
        for (p, rx) in [(&prompts[0], &rxs[0]), (&prompts[2], &rxs[1])] {
            let solo = crate::engine::generate(&reference, p, 8, Sampling::Greedy).unwrap();
            match rx.try_recv().unwrap() {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, solo.tokens, "survivor diverged after neighbor abort");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        // Every page the aborted session held went back to the pool.
        #[cfg(debug_assertions)]
        for pool in &seen {
            pool.audit_tables(&[]).unwrap();
        }
    }

    #[test]
    fn expired_deadlines_answer_timed_out_and_release_the_slot() {
        let engine = ToyEngine::new(64, 4, 16);
        let reqs = vec![
            // Can never finish 50 tokens in 3 ticks: must time out, typed.
            Request::Generate {
                prompt: vec![1, 2, 3, 4],
                max_new_tokens: 50,
                sampling: Sampling::Greedy,
                priority: Priority::Interactive,
                deadline_ticks: 3,
            },
            // Finishes well inside its own (unset) deadline.
            gen_req(vec![5, 6, 7], 3),
        ];
        let (resps, report) = serve_oneshot(&engine, reqs).unwrap();
        assert!(matches!(resps[0], Response::TimedOut), "got {:?}", resps[0]);
        match &resps[1] {
            Response::Generated { tokens, .. } => assert_eq!(tokens.len(), 3),
            other => panic!("wrong response {other:?}"),
        }
        assert_eq!(report.timed_out, 1);
        assert_eq!(report.completed.len(), 2, "every request got exactly one answer");
    }

    #[test]
    fn bounded_queue_sheds_batch_before_interactive() {
        // cap = 1 and three arrivals before any tick: the second Batch
        // arrival sheds immediately, then the Interactive arrival evicts
        // the queued Batch request instead of being shed itself.
        let engine = ToyEngine::new(64, 4, 16);
        let reqs = vec![
            gen_req_class(vec![1, 2, 3], 4, Priority::Batch),
            gen_req_class(vec![4, 5, 6], 4, Priority::Batch),
            gen_req_class(vec![7, 8, 9], 4, Priority::Interactive),
        ];
        let opts = ServeOptions {
            queue_cap: 1,
            ..ServeOptions::default()
        };
        let (resps, report) = serve_oneshot_with(&engine, reqs, &opts).unwrap();
        assert!(matches!(resps[0], Response::Shed), "got {:?}", resps[0]);
        assert!(matches!(resps[1], Response::Shed), "got {:?}", resps[1]);
        match &resps[2] {
            Response::Generated { tokens, .. } => assert_eq!(tokens.len(), 4),
            other => panic!("Interactive was shed while Batch was queued: {other:?}"),
        }
        assert_eq!(report.shed, 2);
    }

    #[test]
    fn injected_pool_faults_retry_and_stay_bit_exact() {
        // chaos pool=1: every request draws exactly one transient pool
        // refusal (consumed at admission or decode). The retry-with-
        // backoff path must absorb all of them — no rejections, no stream
        // divergence from a fault-free solo run.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 53);
        let engine = NativeEngine::new(&params, 4, 8).unwrap();
        let reference = NativeEngine::new(&params, 4, 8).unwrap();
        let prompts = distinct_prompts(3, 8);
        let reqs: Vec<Request> = prompts.iter().map(|p| gen_req(p.clone(), 8)).collect();
        let opts = ServeOptions {
            chaos: FaultPlan::parse("pool=1").unwrap(),
            chaos_seed: 5,
            ..ServeOptions::default()
        };
        let (resps, report) = serve_oneshot_with(&engine, reqs, &opts).unwrap();
        assert_eq!(report.injected_pool_faults, 3, "one fault per request");
        assert_eq!(report.rejected, 0);
        assert_eq!(report.timed_out, 0);
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&reference, p, 8, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "injected fault changed a stream");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
    }

    #[test]
    fn breaker_trips_under_injected_draft_faults_and_streams_stay_exact() {
        // chaos draft=1: every wanted round fails before drafting. The
        // breaker must trip after BREAKER_THRESHOLD consecutive failures,
        // suppress drafting through its cooldown (counted), and the
        // streams — speculation being strictly advisory — must still be
        // byte-identical to a fault-free solo run.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 59);
        let target = NativeEngine::new(&params, 4, 8).unwrap();
        let draft = NativeEngine::new(&ModelParams::init(&fam, 60), 4, 8).unwrap();
        let reference = NativeEngine::new(&params, 4, 8).unwrap();
        let prompts = distinct_prompts(3, 8);
        let reqs: Vec<Request> = prompts.iter().map(|p| gen_req(p.clone(), 8)).collect();
        let opts = ServeOptions {
            chaos: FaultPlan::parse("draft=1").unwrap(),
            chaos_seed: 11,
            ..ServeOptions::default()
        };
        let (resps, report) =
            serve_oneshot_speculative_with(&target, &draft, 2, reqs, &opts).unwrap();
        assert!(
            report.draft_failures >= BREAKER_THRESHOLD,
            "only {} draft failures",
            report.draft_failures
        );
        assert!(report.breaker_trips >= 1, "breaker never tripped");
        assert!(report.breaker_skipped > 0, "cooldown suppressed no rounds");
        assert_eq!(report.drafted_tokens, 0, "a faulted round still drafted");
        for (p, r) in prompts.iter().zip(&resps) {
            let solo = crate::engine::generate(&reference, p, 8, Sampling::Greedy).unwrap();
            match r {
                Response::Generated { tokens, .. } => {
                    assert_eq!(tokens, &solo.tokens, "draft chaos changed a stream");
                }
                other => panic!("wrong response {other:?}"),
            }
        }
    }
}
