//! `odlri` — leader binary: train / calibrate / compress / eval / generate
//! / serve-bench / exp.
//!
//! All inference commands run through the [`odlri::engine::Engine`] API
//! (dense native engine or the packed fused `(Q+LR)·x` engine); `generate`
//! and `serve-bench --max-new-tokens` exercise KV-cached incremental
//! decoding, plain or speculative (`--draft PATH --speculate K`: a low-bit
//! packed draft proposes, the target verifies in one batched step and the
//! greedy stream stays bit-identical). Runs artifact-free on the native
//! engine by default; with
//! `--features xla` and an `artifacts/` directory the training/calibration
//! commands execute the AOT HLO artifacts through PJRT.

use std::path::PathBuf;

use anyhow::{bail, Result};

use odlri::cli::{Args, HELP};
use odlri::coordinator::{
    BudgetPlanner, CompressionPipeline, CompressionPlan, InitKind, PipelineConfig, Planner,
};
use odlri::engine::replicas::Replicas;
use odlri::engine::speculative::SpeculativeEngine;
use odlri::engine::{self, Engine, NativeEngine, Sampling};
use odlri::eval;
use odlri::exp;
use odlri::fused::FusedModel;
use odlri::model::{inject_outliers, ModelParams};
use odlri::runtime::Runtime;
use odlri::serve::{
    nearest_rank, run_server, run_server_speculative, sort_nan_last, ServeConfig, ServeReport,
    Workload,
};
use odlri::train::{train, TrainConfig};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    let dir = args.str("artifacts", "");
    if dir.is_empty() {
        odlri::runtime::default_artifact_dir()
    } else {
        PathBuf::from(dir)
    }
}

fn open_runtime(args: &Args) -> Result<Runtime> {
    let rt = Runtime::open(&artifacts_dir(args))?;
    if rt.is_native() {
        eprintln!("[runtime] native engine (no XLA artifacts)");
    }
    Ok(rt)
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "artifacts" => {
            let rt = open_runtime(args)?;
            for name in rt.artifact_names() {
                let spec = rt.manifest.artifact(&name).unwrap();
                println!(
                    "{name:<24} {:>3} inputs {:>3} outputs  ({})",
                    spec.inputs.len(),
                    spec.outputs.len(),
                    spec.file
                );
            }
            Ok(())
        }
        "train" => cmd_train(args),
        "calibrate" => cmd_calibrate(args),
        "compress" => cmd_compress(args),
        "eval" => cmd_eval(args),
        "pipeline" => cmd_pipeline(args),
        "exp" => {
            let id = args.positional_at(0, "experiment id")?.to_string();
            exp::run(&id, args)
        }
        "serve-bench" => cmd_serve_bench(args),
        "generate" => cmd_generate(args),
        other => bail!("unknown command '{other}'; try `odlri help`"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let family = args.str("family", "tl-7s");
    let cfg = TrainConfig {
        family: family.clone(),
        steps: args.usize("steps", 300)?,
        corpus_tokens: args.usize("corpus-tokens", 400_000)?,
        seed: args.u64("seed", 0)?,
        log_every: args.usize("log-every", 25)?,
    };
    let out_dir = PathBuf::from(args.str("out", "runs"));
    std::fs::create_dir_all(&out_dir)?;
    let result = train(&rt, &cfg)?;
    let mut params = result.params;
    let boosts = args.usize("outliers", 4)?;
    if boosts > 0 {
        let planted = inject_outliers(&mut params, boosts, 16.0, cfg.seed)?;
        eprintln!(
            "  injected {} outlier channels per norm (function-preserving)",
            planted.first().map(|(_, c)| c.len()).unwrap_or(0)
        );
    }
    let path = out_dir.join(format!("{family}.odw"));
    params.save(&path)?;
    println!(
        "trained {family}: {} params, final loss {:.4} → {}",
        params.param_count(),
        result.losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN),
        path.display()
    );
    Ok(())
}

/// Parse a CLI byte size with an optional binary k/m/g suffix: "512k",
/// "64m", "2g", or a plain byte count.
fn parse_bytes(s: &str) -> Result<usize> {
    let t = s.trim().to_ascii_lowercase();
    if t.is_empty() {
        bail!("empty byte size");
    }
    let (digits, mult) = match t.as_bytes()[t.len() - 1] {
        b'k' => (&t[..t.len() - 1], 1usize << 10),
        b'm' => (&t[..t.len() - 1], 1usize << 20),
        b'g' => (&t[..t.len() - 1], 1usize << 30),
        _ => (t.as_str(), 1usize),
    };
    let n: usize = digits
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad byte size '{s}' (use e.g. 512k, 64m, 2g)"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte size '{s}' overflows"))
}

fn load_model(rt: &Runtime, args: &Args, family: &str) -> Result<ModelParams> {
    let fam = rt.manifest.family(family)?;
    let weights = args.str("weights", &format!("runs/{family}.odw"));
    ModelParams::load(fam, &PathBuf::from(weights))
}

/// Like [`load_model`], but falls back to random-init weights when no
/// weight file exists (smoke paths: `--pack-dense` serving/generation needs
/// no prior training run).
fn load_model_or_init(rt: &Runtime, args: &Args, family: &str) -> Result<ModelParams> {
    let fam = rt.manifest.family(family)?;
    let weights = args.str("weights", &format!("runs/{family}.odw"));
    let path = PathBuf::from(&weights);
    if path.exists() {
        ModelParams::load(fam, &path)
    } else {
        eprintln!("[engine] no weights at {weights}; using random-init params");
        Ok(ModelParams::init(fam, args.u64("seed", 0)?))
    }
}

/// Load (or pack on the fly) the fused deployment model for `--fused`
/// commands.
fn build_fused(rt: &Runtime, args: &Args, family: &str) -> Result<FusedModel> {
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let fam = rt.manifest.family(family)?;
    let fm = if args.switch("pack-dense") {
        let params = load_model_or_init(rt, args, family)?;
        FusedModel::pack_dense(&params, "uniform", 8, 64)?.with_shape(batch, seq)
    } else {
        let weights = args.str("weights", &format!("runs/{family}.odf"));
        // Normalize the container's stored shape to the runtime
        // manifest's so fused and dense runs score identical windows
        // under the same scheduler batch cap.
        FusedModel::load(fam, &PathBuf::from(weights))?.with_shape(batch, seq)
    };
    let kvb = args.str("kv-budget", "");
    let fm = if kvb.is_empty() {
        fm
    } else {
        fm.with_kv_budget(parse_bytes(&kvb)?)?
    };
    eprintln!(
        "[engine] fused: {:.2} bits/weight over {} packed projections [{}]",
        fm.avg_bits(),
        fm.mats.len(),
        fm.scheme_summary()
    );
    Ok(fm)
}

/// Build the inference engine every serving command runs through: the
/// packed fused `(Q+LR)·x` engine (`--fused`, optionally packed on the fly
/// from dense weights with `--pack-dense`) or the dense native engine.
fn build_engine(rt: &Runtime, args: &Args, family: &str) -> Result<Box<dyn Engine>> {
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let replicas = args.usize("replicas", 1)?.max(1);
    if args.switch("fused") {
        let fm = build_fused(rt, args, family)?;
        if replicas > 1 {
            eprintln!("[engine] {replicas} fused replicas (private KV pools, least-loaded routing)");
            return Ok(Box::new(Replicas::new(fm, replicas)));
        }
        Ok(Box::new(fm))
    } else {
        if replicas > 1 {
            bail!("--replicas requires the packed engine; add --fused");
        }
        let params = if args.switch("pack-dense") {
            load_model_or_init(rt, args, family)?
        } else {
            load_model(rt, args, family)?
        };
        let eng = NativeEngine::new(&params, batch, seq)?;
        let kvb = args.str("kv-budget", "");
        let eng = if kvb.is_empty() {
            eng
        } else {
            eng.with_kv_budget(parse_bytes(&kvb)?)?
        };
        Ok(Box::new(eng))
    }
}

/// Build the optional speculative-decoding draft engine (`--draft PATH`,
/// depth `--speculate K`, default 4). The draft is always a packed
/// [`FusedModel`]: a low-bit aggressive plan from the same compression run
/// as the target, or — with `--pack-dense` and no `--draft` — a 2-bit pack
/// of the same dense weights, the artifact-free smoke pairing. Returns
/// `None` when neither flag is given. The draft keeps its own unbounded KV
/// pool: `--kv-budget` caps the target only, so draft state can always be
/// rebuilt after target-side preemption drops it.
fn build_draft(
    rt: &Runtime,
    args: &Args,
    family: &str,
) -> Result<Option<(Box<dyn Engine>, usize)>> {
    let draft_path = args.str("draft", "");
    if draft_path.is_empty() && args.str("speculate", "").is_empty() {
        return Ok(None);
    }
    let k = args.usize("speculate", 4)?;
    if k == 0 {
        bail!("--speculate wants a draft depth of at least 1, got 0");
    }
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let fam = rt.manifest.family(family)?;
    let fm = if !draft_path.is_empty() {
        FusedModel::load(fam, &PathBuf::from(&draft_path))?
    } else if args.switch("pack-dense") {
        FusedModel::pack_dense(&load_model_or_init(rt, args, family)?, "uniform", 2, 64)?
    } else {
        bail!("--speculate needs a draft engine: --draft runs/<family>-draft.odf (or --pack-dense)");
    };
    let fm = fm.with_shape(batch, seq);
    eprintln!(
        "[engine] speculative draft: {:.2} bits/weight over {} packed projections, k={k}",
        fm.avg_bits(),
        fm.mats.len()
    );
    Ok(Some((Box::new(fm), k)))
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let family = args.str("family", "tl-7s");
    let params = load_model(&rt, args, &family)?;
    let cfg = odlri::calib::CalibConfig {
        batches: args.usize("batches", 8)?,
        seed: args.u64("seed", 0)?,
    };
    let hessians = odlri::calib::calibrate(&rt, &params, &cfg)?;
    let out = PathBuf::from(args.str("out", &format!("runs/{family}.hess")));
    save_hessians(&hessians, &out)?;
    println!("calibrated {} matrices → {}", hessians.len(), out.display());
    Ok(())
}

pub fn save_hessians(
    hessians: &std::collections::BTreeMap<String, odlri::hessian::Hessian>,
    path: &std::path::Path,
) -> Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::File::create(path)?;
    f.write_all(&(hessians.len() as u32).to_le_bytes())?;
    for (name, h) in hessians {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        h.write_to(&mut f)?;
    }
    Ok(())
}

fn load_hessians(
    path: &std::path::Path,
) -> Result<std::collections::BTreeMap<String, odlri::hessian::Hessian>> {
    use std::io::Read as _;
    let mut f = std::fs::File::open(path)?;
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let count = u32::from_le_bytes(b4) as usize;
    let mut out = std::collections::BTreeMap::new();
    for _ in 0..count {
        f.read_exact(&mut b4)?;
        let nlen = u32::from_le_bytes(b4) as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        out.insert(name, odlri::hessian::Hessian::read_from(&mut f)?);
    }
    Ok(out)
}

fn pipeline_config(args: &Args) -> Result<PipelineConfig> {
    let init = InitKind::parse(&args.str("init", "odlri"))?;
    let workers = {
        let w = args.usize("workers", 0)?;
        if w == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            w
        }
    };
    Ok(PipelineConfig {
        init,
        rank: args.usize("rank", 64)?,
        lr_bits: args.usize("lr-bits", 4)? as u32,
        q_scheme: args.str("scheme", "e8"),
        q_bits: args.usize("bits", 2)? as u32,
        q_group: args.usize("group", 64)?,
        outer_iters: args.usize("iters", 15)?,
        lplr_iters: args.usize("lplr-iters", 10)?,
        hadamard: !args.switch("no-hadamard"),
        workers,
        seed: args.u64("seed", 0)?,
        verbose: args.switch("verbose"),
    })
}

fn cmd_compress(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let family = args.str("family", "tl-7s");
    let params = load_model(&rt, args, &family)?;
    let hessians = load_hessians(&PathBuf::from(
        args.str("hessians", &format!("runs/{family}.hess")),
    ))?;
    let cfg = pipeline_config(args)?;
    let fam = rt.manifest.family(&family)?;
    // Plan resolution order: --plan file > --budget planner > uniform
    // recipe from the CLI flags. `label` names the recipe in the summary
    // line and the default output path, so budget/plan runs do not
    // masquerade as (or overwrite) uniform ones.
    let plan_file = args.str("plan", "");
    let budget = args.f64("budget", 0.0)?;
    // `!(budget > 0.0)` also catches NaN, which `<= 0.0` would let slip
    // into the silent uniform fallback.
    if !args.str("budget", "").is_empty() && !(budget > 0.0 && budget.is_finite()) {
        bail!("--budget wants a positive finite avg-bits target, got {budget}");
    }
    let (plan, label) = if !plan_file.is_empty() {
        let text = std::fs::read_to_string(&plan_file)
            .map_err(|e| anyhow::anyhow!("reading plan file {plan_file}: {e}"))?;
        let plan = CompressionPlan::parse(&text, fam, &cfg)?;
        eprintln!("[plan] {plan_file}: per-projection plan loaded");
        (plan, "plan".to_string())
    } else if budget > 0.0 {
        let planner = BudgetPlanner::new(budget, cfg.clone());
        let plan = planner.plan(&params, &hessians)?;
        eprintln!(
            "[plan] {}: planned {:.3} avg bits under budget {budget:.3}",
            planner.name(),
            plan.avg_bits(fam)?
        );
        (plan, planner.name())
    } else {
        (CompressionPlan::uniform(fam, &cfg), cfg.init.name())
    };
    let rank_label = plan.rank_label();
    let pipe = CompressionPipeline::new(cfg.clone());
    let out = pipe.run_plan(&params, &hessians, &plan)?;
    if !plan.is_uniform() || args.switch("verbose") {
        out.plan.table(fam)?.print();
    }
    println!(
        "compressed {family} [{label}] rank={rank_label} lr_bits={}: avg_bits={:.3} mean_err={:.4e} in {:.1}s",
        plan.lr_bits_label(),
        out.model.avg_bits(),
        out.model.mean_act_err(),
        out.wall_secs
    );
    // Save the reconstructed weights for `eval`.
    let applied = out.model.apply_to(&params)?;
    let path = PathBuf::from(args.str(
        "out",
        &format!("runs/{family}.{label}.r{rank_label}.odw"),
    ));
    applied.save(&path)?;
    println!("wrote {}", path.display());
    // Deployment container for the fused serving path. The container
    // stores each projection's scheme-native codes exactly as the pipeline
    // quantized them — no re-quantization at packing time.
    if args.switch("fused") || !args.str("fused-out", "").is_empty() {
        let fm = out.model.to_fused(&params)?;
        // Canonical serving artifact path — matches the default that
        // `eval --fused` / `serve-bench --fused` look for.
        let fpath = PathBuf::from(args.str("fused-out", &format!("runs/{family}.odf")));
        fm.save(&fpath)?;
        println!(
            "wrote {} (scheme-exact packed Q [{}]: {:.2} bits/weight, {} packed)",
            fpath.display(),
            fm.scheme_summary(),
            fm.avg_bits(),
            odlri::util::human_bytes(fm.packed_bytes())
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let family = args.str("family", "tl-7s");
    let engine: Box<dyn Engine> = if args.switch("fused") {
        // The deployed container documents its (possibly heterogeneous)
        // per-projection plan; surface it next to the quality numbers.
        let fm = build_fused(&rt, args, &family)?;
        let fam = rt.manifest.family(&family)?;
        CompressionPlan::new(fm.plans.clone(), fam)?.table(fam)?.print();
        Box::new(fm)
    } else {
        build_engine(&rt, args, &family)?
    };
    let report = eval::evaluate(
        engine.as_ref(),
        args.usize("windows", 40)?,
        args.usize("task-items", 64)?,
        args.u64("seed", 1000)?,
    )?;
    println!("ppl wiki-sim = {:.4}", report.ppl_wiki);
    println!("ppl c4-sim   = {:.4}", report.ppl_c4);
    for t in &report.tasks {
        println!("{:<10} acc = {:.2}%", t.task.name(), t.accuracy * 100.0);
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    // train → inject outliers → calibrate → compress (CALDERA vs +ODLRI) →
    // eval, printing a mini Table-2 row pair.
    let rt = open_runtime(args)?;
    let family = args.str("family", "tl-7s");
    let steps = args.usize("steps", 300)?;
    let seed = args.u64("seed", 0)?;

    eprintln!("[1/5] training {family} for {steps} steps…");
    let tr = train(
        &rt,
        &TrainConfig {
            family: family.clone(),
            steps,
            seed,
            ..Default::default()
        },
    )?;
    let mut params = tr.params;
    inject_outliers(&mut params, 4, 16.0, seed)?;

    eprintln!("[2/5] calibrating…");
    let hessians = odlri::calib::calibrate(
        &rt,
        &params,
        &odlri::calib::CalibConfig { batches: 6, seed },
    )?;

    eprintln!("[3/5] evaluating FP32 baseline…");
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let base_engine = NativeEngine::new(&params, batch, seq)?;
    let base = eval::evaluate(&base_engine, 30, 48, 1000)?;

    let mut cfg = pipeline_config(args)?;
    let mut rows = Vec::new();
    for init in [InitKind::Caldera, InitKind::Odlri] {
        eprintln!("[4/5] compressing with {}…", init.name());
        cfg.init = init.clone();
        let out = CompressionPipeline::new(cfg.clone()).run(&params, &hessians)?;
        let applied = out.model.apply_to(&params)?;
        let applied_engine = NativeEngine::new(&applied, batch, seq)?;
        let rep = eval::evaluate(&applied_engine, 30, 48, 1000)?;
        rows.push((init.name(), out.model.avg_bits(), rep));
    }

    eprintln!("[5/5] report");
    println!(
        "\n== {family} (rank {}, {} iters) ==",
        cfg.rank, cfg.outer_iters
    );
    let fmt_tasks = |rep: &eval::EvalReport| {
        rep.tasks
            .iter()
            .map(|t| format!("{:.1}", t.accuracy * 100.0))
            .collect::<Vec<_>>()
            .join(" ")
    };
    println!(
        "{:<10} {:>8} {:>9} {:>9}  {}",
        "method", "avg-bits", "ppl-wiki", "ppl-c4", "task acc (wino rte piqa arce arcc)"
    );
    println!(
        "{:<10} {:>8} {:>9.3} {:>9.3}  {}",
        "fp32", "32", base.ppl_wiki, base.ppl_c4, fmt_tasks(&base)
    );
    for (name, bits, rep) in &rows {
        println!(
            "{:<10} {:>8.2} {:>9.3} {:>9.3}  {}",
            name,
            bits,
            rep.ppl_wiki,
            rep.ppl_c4,
            fmt_tasks(rep)
        );
    }
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let family = args.str("family", "tl-7s");
    let max_new = args.usize("max-new-tokens", 0)?;
    let cfg = ServeConfig {
        requests: args.usize("requests", 32)?,
        clients: args.usize("clients", 4)?,
        deadline: std::time::Duration::from_millis(args.u64("deadline-ms", 10)?),
        seed: args.u64("seed", 9)?,
        workload: if max_new == 0 {
            Workload::Score
        } else {
            Workload::Generate {
                max_new_tokens: max_new,
            }
        },
        prompt_len: args.usize("prompt-len", 0)?,
        shared_prompt: args.switch("shared-prompt"),
        prefill_chunk: args.usize("prefill-chunk", 0)?,
        batch_clients: args.usize("batch-clients", 0)?,
        long_prompt_len: args.usize("long-prompt-len", 0)?,
        queue_cap: args.usize("queue-cap", 0)?,
        deadline_ticks: args.usize("deadline-ticks", 0)?,
        chaos: odlri::serve::faults::FaultPlan::parse(&args.str("chaos", ""))?,
    };
    let engine = build_engine(&rt, args, &family)?;
    let speculation = build_draft(&rt, args, &family)?;
    if speculation.is_some() && max_new == 0 {
        bail!("--draft speculates on generation workloads; set --max-new-tokens");
    }
    let report = match &speculation {
        Some((draft, k)) => run_server_speculative(engine.as_ref(), draft.as_ref(), *k, &cfg)?,
        None => run_server(engine.as_ref(), &cfg)?,
    };
    let seq = if cfg.prompt_len == 0 {
        engine.spec().seq
    } else {
        cfg.prompt_len
    };
    println!(
        "served {} requests in {} forwards + {} decode steps over {:.2}s  ({:.0} req/s)",
        report.completed.len(),
        report.batches,
        report.decode_steps,
        report.wall_secs,
        report.requests_per_sec(),
    );
    println!(
        "request latency p50 = {:.1} ms   p95 = {:.1} ms",
        report.p50_ms(),
        report.p95_ms()
    );
    if max_new > 0 {
        println!(
            "generated {} tokens ({} via KV-cached decode at {:.0} tok/s; per-step p50 = {:.2} ms)",
            report.generated_tokens,
            report.decoded_tokens,
            report.decode_tokens_per_sec(),
            report.decode_p50_ms()
        );
        // Packed engines re-decode the whole packed Q payload once per
        // decode step; weight GB/s makes kernel wins visible from the CLI.
        if let Some(qb) = engine.decode_weight_bytes() {
            let decode_secs: f64 = report.decode_step_latencies_s.iter().sum();
            if report.decode_steps > 0 && decode_secs > 0.0 {
                println!(
                    "decode weight-throughput {:.2} GB/s over {} of packed Q ({} decode steps)",
                    qb as f64 * report.decode_steps as f64 / decode_secs / 1e9,
                    odlri::util::human_bytes(qb),
                    report.decode_steps
                );
            }
        }
    } else {
        println!(
            "scored {:.0} tok/s",
            report.requests_per_sec() * seq as f64
        );
        let finite = report.scores.iter().filter(|s| s.is_finite()).count();
        println!("finite scores: {finite}/{}", report.scores.len());
    }
    let mut spec_vs_plain: Option<(f64, f64)> = None;
    if let Some((_, k)) = &speculation {
        println!(
            "speculative decode: k={k}, acceptance {:.1}% (drafted {}, accepted {}, rejected {}; \
             {} draft steps + {} verify steps)",
            report.acceptance_rate() * 100.0,
            report.drafted_tokens,
            report.accepted_tokens,
            report.rejected_tokens,
            report.draft_steps,
            report.verify_steps
        );
        // Re-run the identical workload target-only so the report shows
        // what speculation actually bought (same engine, prompts, seeds —
        // greedy serving is deterministic, so only the timing differs).
        let plain = run_server(engine.as_ref(), &cfg)?;
        let (s_ms, p_ms) = (ms_per_decoded_tok(&report), ms_per_decoded_tok(&plain));
        println!(
            "speculative vs plain: {:.3} vs {:.3} ms/tok ({:.2}x)",
            s_ms,
            p_ms,
            if s_ms > 0.0 { p_ms / s_ms } else { 0.0 }
        );
        spec_vs_plain = Some((s_ms, p_ms));
    }
    if max_new > 0 {
        println!(
            "scheduler: {} preemptions, {} resumes (bit-exact re-prefill), {} rejected, \
             {} interleaved decode steps",
            report.preemptions, report.resumes, report.rejected, report.interleaved_decode_steps
        );
        for c in &report.classes {
            if c.requests == 0 {
                continue;
            }
            println!(
                "class {}: {} requests, ttft p50 {:.1} ms, {:.2}/{:.2} ms/tok p50/p99, \
                 {} preemptions",
                c.class.name(),
                c.requests,
                c.ttft_p50_ms,
                c.ms_per_tok_p50,
                c.ms_per_tok_p99,
                c.preemptions
            );
        }
    }
    // Degradation-ladder outcomes: printed whenever any robustness knob
    // produced a typed non-completion (or chaos was configured at all),
    // so fault-free runs keep the historical report shape.
    if !cfg.chaos.is_empty()
        || report.timed_out + report.shed + report.aborted + report.pool_retries > 0
    {
        println!(
            "degradation: {} timed out, {} shed, {} aborted, {} slow clients; \
             {} pool retries ({} injected), {} shard failures, {} failovers",
            report.timed_out,
            report.shed,
            report.aborted,
            report.slow_clients,
            report.pool_retries,
            report.injected_pool_faults,
            report.shard_failures,
            report.failovers,
        );
        println!(
            "speculation breaker: {} draft failures, {} trips, {} rounds suppressed",
            report.draft_failures, report.breaker_trips, report.breaker_skipped,
        );
    }
    if let Some(ps) = engine.pool_stats() {
        println!(
            "kv pool: {}/{} pages, {} shared, {} cow, {} reclaimed \
             (page = {} tokens / {}; peak {} pages of {} budgeted)",
            ps.resident_pages,
            ps.max_pages,
            ps.shared_adoptions,
            ps.cow_copies,
            ps.reclaimed_pages,
            ps.page_tokens,
            odlri::util::human_bytes(ps.page_bytes),
            ps.peak_resident_pages,
            odlri::util::human_bytes(ps.budget_bytes),
        );
    }
    if args.switch("json") {
        // Hand-rolled single-line JSON (no serde in the offline vendor
        // set); non-finite percentile samples become 0 so the line always
        // parses.
        let j = |x: f64| if x.is_finite() { x } else { 0.0 };
        let classes: Vec<String> = report
            .classes
            .iter()
            .filter(|c| c.requests > 0)
            .map(|c| {
                format!(
                    "{{\"class\":\"{}\",\"requests\":{},\"ttft_p50_ms\":{:.3},\
                     \"ms_per_tok_p50\":{:.3},\"ms_per_tok_p99\":{:.3},\"preemptions\":{}}}",
                    c.class.name(),
                    c.requests,
                    j(c.ttft_p50_ms),
                    j(c.ms_per_tok_p50),
                    j(c.ms_per_tok_p99),
                    c.preemptions
                )
            })
            .collect();
        let (s_ms, p_ms) = spec_vs_plain.unwrap_or((0.0, 0.0));
        println!(
            "{{\"requests\":{},\"batches\":{},\"decode_steps\":{},\
             \"interleaved_decode_steps\":{},\"generated_tokens\":{},\"decoded_tokens\":{},\
             \"preemptions\":{},\"resumes\":{},\"rejected\":{},\
             \"drafted_tokens\":{},\"accepted_tokens\":{},\"rejected_tokens\":{},\
             \"draft_steps\":{},\"verify_steps\":{},\"acceptance_rate\":{:.4},\
             \"timed_out\":{},\"shed\":{},\"aborted\":{},\"slow_clients\":{},\
             \"pool_retries\":{},\"injected_pool_faults\":{},\
             \"shard_failures\":{},\"failovers\":{},\
             \"draft_failures\":{},\"breaker_trips\":{},\"breaker_skipped\":{},\
             \"spec_ms_per_tok\":{:.3},\"plain_ms_per_tok\":{:.3},\"wall_secs\":{:.4},\
             \"p50_ms\":{:.3},\"p95_ms\":{:.3},\"classes\":[{}]}}",
            report.completed.len(),
            report.batches,
            report.decode_steps,
            report.interleaved_decode_steps,
            report.generated_tokens,
            report.decoded_tokens,
            report.preemptions,
            report.resumes,
            report.rejected,
            report.drafted_tokens,
            report.accepted_tokens,
            report.rejected_tokens,
            report.draft_steps,
            report.verify_steps,
            j(report.acceptance_rate()),
            report.timed_out,
            report.shed,
            report.aborted,
            report.slow_clients,
            report.pool_retries,
            report.injected_pool_faults,
            report.shard_failures,
            report.failovers,
            report.draft_failures,
            report.breaker_trips,
            report.breaker_skipped,
            j(s_ms),
            j(p_ms),
            j(report.wall_secs),
            j(report.p50_ms()),
            j(report.p95_ms()),
            classes.join(",")
        );
    }
    Ok(())
}

/// Decode cost per emitted token: total decode-tick wall time over tokens
/// that went through KV-cached decode. Speculative rounds count every
/// token they emit, which is exactly the comparison the speculative-vs-
/// plain line is after.
fn ms_per_decoded_tok(r: &ServeReport) -> f64 {
    let secs: f64 = r.decode_step_latencies_s.iter().sum();
    if r.decoded_tokens == 0 {
        0.0
    } else {
        secs * 1e3 / r.decoded_tokens as f64
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let family = args.str("family", "tl-7s");
    let engine = build_engine(&rt, args, &family)?;
    let seed = args.u64("seed", 0)?;
    let prompt_text = args.str("prompt", "");
    let prompt: Vec<i32> = if prompt_text.is_empty() {
        let n = args.usize("prompt-len", 32)?.max(1);
        let data = odlri::corpus::generate(odlri::corpus::Split::WikiSim, n + 1024, seed);
        data[..n].iter().map(|&b| b as i32).collect()
    } else {
        prompt_text.as_bytes().iter().map(|&b| b as i32).collect()
    };
    let sampling = match args.usize("top-k", 0)? {
        0 => Sampling::Greedy,
        k => Sampling::TopK {
            k,
            temperature: args.f64("temperature", 1.0)? as f32,
            seed,
        },
    };
    let max_new = args.usize("max-new-tokens", 64)?;
    // Captured before the engine may move into the speculative wrapper.
    let qb = engine.decode_weight_bytes();
    if let Some((draft, k)) = build_draft(&rt, args, &family)? {
        if !matches!(sampling, Sampling::Greedy) {
            bail!("--draft verifies greedy streams only; drop --top-k (or drop --draft)");
        }
        let spec = SpeculativeEngine::new(draft, engine, k)?;
        let out = spec.generate(&prompt, max_new)?;
        report_generation(&prompt, &out.gen, qb);
        let c = out.counters;
        let decode_s: f64 = out.gen.step_latencies_s.iter().sum();
        let emitted = out.gen.tokens.len().saturating_sub(1).max(1);
        println!(
            "speculative: k={k} over {} rounds — drafted {}, accepted {}, rejected {} \
             (acceptance {:.1}%); {} draft steps + {} verify steps, {:.2} ms/tok effective",
            c.rounds,
            c.drafted,
            c.accepted,
            c.rejected,
            c.acceptance_rate() * 100.0,
            c.draft_steps,
            c.verify_steps,
            decode_s * 1e3 / emitted as f64,
        );
        return Ok(());
    }
    let out = engine::generate(engine.as_ref(), &prompt, max_new, sampling)?;
    report_generation(&prompt, &out, qb);
    Ok(())
}

/// Shared tail of `generate`: token text, the per-step latency report, and
/// (for packed engines) decode weight throughput with the kernel-path
/// probe counters CI greps. Speculative runs pass per-*round* latencies —
/// every round emits at least one token — so the mean/percentiles read as
/// per-round there and the speculative summary line carries the effective
/// per-token cost.
fn report_generation(prompt: &[i32], out: &engine::GenOutput, qb: Option<usize>) {
    println!("prompt ({} tokens): {:?}", out.prompt_len, tokens_to_text(prompt));
    println!(
        "generated {} tokens: {:?}",
        out.tokens.len(),
        tokens_to_text(&out.tokens)
    );
    // Per-token latency report: the whole point of KV-cached decoding.
    // Same NaN-last ordering + nearest-rank formula as the serve report.
    let sorted = sort_nan_last(&out.step_latencies_s);
    let pick = |p: f64| -> f64 { nearest_rank(&sorted, p) };
    let total: f64 = out.step_latencies_s.iter().sum();
    let mean_ms = if out.step_latencies_s.is_empty() {
        0.0
    } else {
        total * 1e3 / out.step_latencies_s.len() as f64
    };
    println!(
        "prefill {:.2} ms   decode mean {:.2} ms/tok  p50 {:.2}  p95 {:.2}   ({:.0} tok/s)",
        out.prefill_s * 1e3,
        mean_ms,
        pick(0.50) * 1e3,
        pick(0.95) * 1e3,
        if total > 0.0 {
            out.step_latencies_s.len() as f64 / total
        } else {
            0.0
        }
    );
    // Packed engines re-decode the whole packed Q payload once per decode
    // step, so weight GB/s = q_bytes · steps / decode_secs; the kernel
    // probe counters expose whether the specialized fused dequant-dot path
    // was actually taken (CI greps this line).
    if let Some(qb) = qb {
        let steps = out.step_latencies_s.len();
        if steps > 0 && total > 0.0 {
            println!(
                "decode weight-throughput {:.2} GB/s over {} of packed Q   \
                 (decode path: specialized-dot x{}, panel x{})",
                qb as f64 * steps as f64 / total / 1e9,
                odlri::util::human_bytes(qb),
                odlri::fused::decode_kernel_calls(),
                odlri::fused::panel_kernel_calls(),
            );
        }
    }
}

/// Render byte-level tokens as text (tokens ≥ 256 from wide-vocab families
/// become '?').
fn tokens_to_text(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .map(|&t| if (0..256).contains(&t) { t as u8 } else { b'?' })
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::{build_draft, parse_bytes};
    use odlri::cli::{command_spec, Args};
    use odlri::runtime::Runtime;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        Args::parse_with(&argv, command_spec(&argv[0]).unwrap()).unwrap()
    }

    #[test]
    fn build_draft_surfaces_typed_errors_not_panics() {
        // Native runtime (no artifact dir): same environment the CLI gets.
        let rt = Runtime::open(std::path::Path::new("no-such-artifact-dir")).unwrap();
        // Neither flag given: no speculation.
        let none = parse("generate --fused --pack-dense");
        assert!(build_draft(&rt, &none, "tl-7s").unwrap().is_none());
        // A missing draft artifact is a typed open error naming the path,
        // not a panic.
        let missing = parse("generate --fused --draft /nonexistent/draft.odf --speculate 2");
        let err = build_draft(&rt, &missing, "tl-7s").unwrap_err();
        assert!(
            format!("{err:#}").contains("/nonexistent/draft.odf"),
            "err: {err:#}"
        );
        // Depth zero is rejected before any model loading happens.
        let zero = parse("generate --draft x.odf --speculate 0");
        let err = build_draft(&rt, &zero, "tl-7s").unwrap_err();
        assert!(err.to_string().contains("at least 1"), "err: {err:#}");
        // --speculate with no way to build a draft points at --draft.
        let bare = parse("generate --speculate 3");
        let err = build_draft(&rt, &bare, "tl-7s").unwrap_err();
        assert!(err.to_string().contains("--draft"), "err: {err:#}");
    }

    #[test]
    fn parse_bytes_suffixes_and_overflow() {
        assert_eq!(parse_bytes("512").unwrap(), 512);
        assert_eq!(parse_bytes("512k").unwrap(), 512 << 10);
        assert_eq!(parse_bytes("64m").unwrap(), 64 << 20);
        assert_eq!(parse_bytes("2G").unwrap(), 2 << 30);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("12q").is_err());
        // An oversized count must be an error, not a silent release-mode
        // wrap to an arbitrary (possibly tiny) budget.
        let err = parse_bytes("99999999999999999999g").unwrap_err();
        assert!(format!("{err:#}").contains("byte size"), "err: {err:#}");
        let err = parse_bytes("99999999999g").unwrap_err();
        assert!(format!("{err:#}").contains("overflows"), "err: {err:#}");
        let err = parse_bytes(&format!("{}g", usize::MAX)).unwrap_err();
        assert!(format!("{err:#}").contains("overflows"), "err: {err:#}");
    }
}
