//! Dense f32 matrix substrate.
//!
//! Everything the compression algorithms need — row-major [`Matrix`],
//! cache-blocked (and optionally multi-threaded) matmul, transposes, norms,
//! row/column ops — built on std only. This is deliberately small and
//! predictable rather than a general ndarray: all paper math is 2-D.

mod matmul;

pub use matmul::{
    axpy, dotp, matmul, matmul_into, matmul_nt, matmul_single_scopes, matmul_threads,
    matmul_tn, set_matmul_threads, MatmulSingleThreadScope,
};

use crate::util::rng::Pcg64;
use anyhow::{bail, Result};

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix[{}x{}]", self.rows, self.cols)?;
        if self.rows * self.cols <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Pcg64) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            *self.at_mut(i, j) = v[i];
        }
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// C = A @ B (convenience over [`matmul`]).
    pub fn dot(&self, other: &Matrix) -> Matrix {
        matmul(self, other)
    }

    /// self^T @ other without materializing the transpose.
    pub fn tdot(&self, other: &Matrix) -> Matrix {
        matmul_tn(self, other)
    }

    /// self @ other^T without materializing the transpose.
    pub fn dot_t(&self, other: &Matrix) -> Matrix {
        matmul_nt(self, other)
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        out
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        let mut out = self.clone();
        for a in out.data.iter_mut() {
            *a *= s;
        }
        out
    }

    pub fn scale_assign(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Scale column j by s (in place).
    pub fn scale_col(&mut self, j: usize, s: f32) {
        for i in 0..self.rows {
            *self.at_mut(i, j) *= s;
        }
    }

    /// Scale row i by s (in place).
    pub fn scale_row(&mut self, i: usize, s: f32) {
        for v in self.row_mut(i) {
            *v *= s;
        }
    }

    /// Multiply on the right by diag(d): scales column j by d[j].
    pub fn mul_diag_right(&self, d: &[f32]) -> Matrix {
        assert_eq!(d.len(), self.cols);
        let mut out = self.clone();
        for i in 0..out.rows {
            let r = out.row_mut(i);
            for j in 0..d.len() {
                r[j] *= d[j];
            }
        }
        out
    }

    /// Multiply on the left by diag(d): scales row i by d[i].
    pub fn mul_diag_left(&self, d: &[f32]) -> Matrix {
        assert_eq!(d.len(), self.rows);
        let mut out = self.clone();
        for i in 0..out.rows {
            let s = d[i];
            for v in out.row_mut(i) {
                *v *= s;
            }
        }
        out
    }

    pub fn frob_norm(&self) -> f32 {
        // Two-pass scaled sum to avoid overflow on large matrices.
        let mx = self.abs_max();
        if mx == 0.0 {
            return 0.0;
        }
        let mut s = 0.0f64;
        for &v in &self.data {
            let t = (v / mx) as f64;
            s += t * t;
        }
        mx * (s.sqrt() as f32)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&v| v as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// Extract a sub-matrix (row range, col range).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Gather the given columns into a new matrix (in index order).
    pub fn gather_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (k, &j) in idx.iter().enumerate() {
                dst[k] = src[j];
            }
        }
        out
    }

    /// Gather the given rows into a new matrix (in index order).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Keep only the listed columns, zeroing the rest (the paper's X_o / X_r
    /// split keeps original dimensions with complementary supports).
    pub fn mask_cols(&self, keep: &[usize]) -> Matrix {
        let mut mask = vec![false; self.cols];
        for &j in keep {
            mask[j] = true;
        }
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for j in 0..self.cols {
                if mask[j] {
                    dst[j] = src[j];
                }
            }
        }
        out
    }

    /// Keep only the listed rows, zeroing the rest.
    pub fn mask_rows(&self, keep: &[usize]) -> Matrix {
        let mut mask = vec![false; self.rows];
        for &i in keep {
            mask[i] = true;
        }
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            if mask[i] {
                out.row_mut(i).copy_from_slice(self.row(i));
            }
        }
        out
    }

    pub fn diag(&self) -> Vec<f32> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.at(i, i)).collect()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// Relative Frobenius error ‖a-b‖/‖b‖ (0 if both zero).
    pub fn rel_err(&self, reference: &Matrix) -> f32 {
        let denom = reference.frob_norm();
        let diff = self.sub(reference).frob_norm();
        if denom == 0.0 {
            diff
        } else {
            diff / denom
        }
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    // ---- serialization (little-endian, versioned header) ----

    /// Binary layout: magic "ODM1", u32 rows, u32 cols, f32 data.
    pub fn write_to(&self, w: &mut impl std::io::Write) -> Result<()> {
        w.write_all(b"ODM1")?;
        w.write_all(&(self.rows as u32).to_le_bytes())?;
        w.write_all(&(self.cols as u32).to_le_bytes())?;
        for &v in &self.data {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn read_from(r: &mut impl std::io::Read) -> Result<Matrix> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"ODM1" {
            bail!("bad matrix magic {magic:?}");
        }
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let rows = u32::from_le_bytes(b4) as usize;
        r.read_exact(&mut b4)?;
        let cols = u32::from_le_bytes(b4) as usize;
        let mut data = vec![0f32; rows * cols];
        let mut buf = vec![0u8; rows * cols * 4];
        r.read_exact(&mut buf)?;
        for (i, chunk) in buf.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(Matrix { rows, cols, data })
    }
}

/// ‖AX‖_F for the paper's activation-aware norms, given X as columns=samples.
pub fn act_norm(a: &Matrix, x: &Matrix) -> f32 {
    a.dot(x).frob_norm()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::new(1, 1);
        let a = Matrix::randn(37, 53, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(10, 20), a.at(20, 10));
    }

    #[test]
    fn add_sub_scale() {
        let a = m(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = m(2, 2, &[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(a.add(&b), m(2, 2, &[5.0; 4]));
        assert_eq!(a.sub(&a), Matrix::zeros(2, 2));
        assert_eq!(a.scale(2.0), m(2, 2, &[2.0, 4.0, 6.0, 8.0]));
    }

    #[test]
    fn frob_norm_matches_definition() {
        let a = m(2, 2, &[3.0, 0.0, 4.0, 0.0]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-6);
        assert_eq!(Matrix::zeros(3, 3).frob_norm(), 0.0);
    }

    #[test]
    fn diag_ops() {
        let a = m(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let d = a.mul_diag_right(&[2.0, 3.0, 4.0]);
        assert_eq!(d, m(2, 3, &[2.0, 6.0, 12.0, 8.0, 15.0, 24.0]));
        let e = a.mul_diag_left(&[10.0, 0.5]);
        assert_eq!(e, m(2, 3, &[10.0, 20.0, 30.0, 2.0, 2.5, 3.0]));
    }

    #[test]
    fn slice_gather_mask() {
        let a = Matrix::from_fn(4, 5, |i, j| (i * 5 + j) as f32);
        let s = a.slice(1, 3, 2, 4);
        assert_eq!(s, m(2, 2, &[7.0, 8.0, 12.0, 13.0]));
        let g = a.gather_cols(&[4, 0]);
        assert_eq!(g.col(0), a.col(4));
        assert_eq!(g.col(1), a.col(0));
        let mk = a.mask_cols(&[1]);
        assert_eq!(mk.col(1), a.col(1));
        assert_eq!(mk.col(0), vec![0.0; 4]);
        let mr = a.mask_rows(&[2]);
        assert_eq!(mr.row(2), a.row(2));
        assert_eq!(mr.row(0), &[0.0; 5]);
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Pcg64::new(7, 7);
        let a = Matrix::randn(13, 17, 2.0, &mut rng);
        let mut buf = Vec::new();
        a.write_to(&mut buf).unwrap();
        let b = Matrix::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn mask_split_is_complementary() {
        // X = X_o + X_r with complementary supports (paper §3.2).
        let mut rng = Pcg64::new(3, 1);
        let x = Matrix::randn(8, 10, 1.0, &mut rng);
        let keep = [1usize, 4, 7];
        let rest: Vec<usize> = (0..8).filter(|i| !keep.contains(i)).collect();
        let xo = x.mask_rows(&keep);
        let xr = x.mask_rows(&rest);
        assert_eq!(xo.add(&xr), x);
    }
}
