//! Cache-blocked, multi-threaded matrix multiplication.
//!
//! The compression loop is matmul-bound (LDLQ feedback, LPLR alternation,
//! Hessian products), so this gets a real implementation: i-k-j loop order
//! with 8-wide unrolled FMA over the contiguous B rows, L2-sized panel
//! blocking, and row-parallel threading over std::thread::scope. Perf notes
//! live in EXPERIMENTS.md §Perf.

use super::Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Global thread budget for matmul (0 = auto from available_parallelism).
static MATMUL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Count of live [`MatmulSingleThreadScope`]s. While any scope is alive,
/// matmuls run single-threaded regardless of the configured budget.
static MATMUL_SINGLE_SCOPES: AtomicUsize = AtomicUsize::new(0);

/// Override the matmul thread count (used by benches and the serving setup).
pub fn set_matmul_threads(n: usize) {
    MATMUL_THREADS.store(n, Ordering::Relaxed);
}

/// The configured matmul thread budget (0 = auto). Scoped caps
/// ([`MatmulSingleThreadScope`]) do NOT show up here — they never touch the
/// configured value.
pub fn matmul_threads() -> usize {
    MATMUL_THREADS.load(Ordering::Relaxed)
}

/// RAII single-threaded-matmul scope: while any instance is alive, matmuls
/// skip the thread fan-out. Used by the coordinator so per-matrix jobs do
/// not oversubscribe when its worker pool is already wide. Counted rather
/// than save/restore, so overlapping scopes on different threads and early
/// error returns compose correctly and the configured
/// [`set_matmul_threads`] value is never clobbered.
pub struct MatmulSingleThreadScope(());

impl MatmulSingleThreadScope {
    pub fn enter() -> MatmulSingleThreadScope {
        MATMUL_SINGLE_SCOPES.fetch_add(1, Ordering::Relaxed);
        MatmulSingleThreadScope(())
    }
}

impl Drop for MatmulSingleThreadScope {
    fn drop(&mut self) {
        MATMUL_SINGLE_SCOPES.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Number of live single-thread scopes (0 = multithreading available).
/// Exposed so tests can assert that error paths release their cap.
pub fn matmul_single_scopes() -> usize {
    MATMUL_SINGLE_SCOPES.load(Ordering::Relaxed)
}

fn threads_for(work: usize) -> usize {
    if MATMUL_SINGLE_SCOPES.load(Ordering::Relaxed) > 0 {
        return 1;
    }
    let cap = match MATMUL_THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    };
    // Don't spawn threads for small problems. Perf pass (EXPERIMENTS.md
    // §Perf iteration 1): at the original 2 MFLOP threshold a 128³ matmul
    // (4.2 MFLOP) was *slower* threaded than single-threaded (2.6 ms vs
    // 1.7 ms — spawn cost dominates); 24 MFLOP puts the crossover where
    // the measured win begins (352×128×512 = 46 MFLOP: 16.3 → 10.1 ms).
    if work < 24_000_000 {
        1
    } else {
        cap.min(16)
    }
}

/// C = A @ B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// C = A @ B into a pre-allocated output (overwrites C).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "matmul inner dims {k} vs {kb}");
    assert_eq!(c.shape(), (m, n), "matmul output shape");
    c.as_mut_slice().fill(0.0);
    let nthreads = threads_for(2 * m * n * k);
    if nthreads <= 1 || m < nthreads {
        kernel_rows(a.as_slice(), b.as_slice(), c.as_mut_slice(), 0, m, k, n);
        return;
    }
    // Split output rows across threads; each thread owns a disjoint slice of C.
    let a_s = a.as_slice();
    let b_s = b.as_slice();
    let rows_per = m.div_ceil(nthreads);
    let chunks: Vec<&mut [f32]> = c.as_mut_slice().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, chunk) in chunks.into_iter().enumerate() {
            let r0 = t * rows_per;
            let r1 = (r0 + chunk.len() / n).min(m);
            s.spawn(move || {
                kernel_rows_out(a_s, b_s, chunk, r0, r1, k, n);
            });
        }
    });
}

/// Core kernel computing rows [r0, r1) of C (C indexed absolutely).
fn kernel_rows(a: &[f32], b: &[f32], c: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    kernel_rows_out(a, b, &mut c[r0 * n..r1 * n], r0, r1, k, n);
}

/// Same, but C slice starts at row r0 (thread-local chunk).
fn kernel_rows_out(a: &[f32], b: &[f32], c: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    // Panel over k to keep the active B panel in L2 (~256 rows * n floats).
    const KB: usize = 256;
    for kp in (0..k).step_by(KB) {
        let kend = (kp + KB).min(k);
        for i in r0..r1 {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
            for p in kp..kend {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                axpy(av, brow, crow);
            }
        }
    }
}

/// crow += av * brow, 8-wide unrolled (autovectorizes to AVX on release).
/// Public: the fused `(Q+LR)·x` kernels stream dequantized rows through it.
#[inline]
pub fn axpy(av: f32, brow: &[f32], crow: &mut [f32]) {
    let n = brow.len();
    let chunks = n / 8;
    // Unrolled main loop.
    for c8 in 0..chunks {
        let o = c8 * 8;
        crow[o] += av * brow[o];
        crow[o + 1] += av * brow[o + 1];
        crow[o + 2] += av * brow[o + 2];
        crow[o + 3] += av * brow[o + 3];
        crow[o + 4] += av * brow[o + 4];
        crow[o + 5] += av * brow[o + 5];
        crow[o + 6] += av * brow[o + 6];
        crow[o + 7] += av * brow[o + 7];
    }
    for o in chunks * 8..n {
        crow[o] += av * brow[o];
    }
}

/// C = A^T @ B without materializing A^T.
/// A is (k x m) stored row-major; result is (m x n).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "matmul_tn inner dims");
    let mut c = Matrix::zeros(m, n);
    // For each row p of A (length m) and row p of B (length n):
    //   C[i, :] += A[p, i] * B[p, :]
    // This keeps both reads sequential; parallelize over k-panels with
    // per-thread accumulators, reduced at the end.
    let nthreads = threads_for(2 * m * n * k);
    if nthreads <= 1 {
        for p in 0..k {
            let arow = a.row(p);
            let brow = b.row(p);
            for i in 0..m {
                let av = arow[i];
                if av != 0.0 {
                    axpy(av, brow, c.row_mut(i));
                }
            }
        }
        return c;
    }
    let per = k.div_ceil(nthreads);
    let mut partials: Vec<Matrix> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..nthreads {
            let p0 = t * per;
            let p1 = ((t + 1) * per).min(k);
            if p0 >= p1 {
                continue;
            }
            handles.push(s.spawn(move || {
                let mut part = Matrix::zeros(m, n);
                for p in p0..p1 {
                    let arow = a.row(p);
                    let brow = b.row(p);
                    for i in 0..m {
                        let av = arow[i];
                        if av != 0.0 {
                            axpy(av, brow, part.row_mut(i));
                        }
                    }
                }
                part
            }));
        }
        for h in handles {
            partials.push(h.join().expect("matmul_tn worker panicked"));
        }
    });
    for p in partials {
        c.add_assign(&p);
    }
    c
}

/// C = A @ B^T without materializing B^T. A is (m x k), B is (n x k) → (m x n).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "matmul_nt inner dims");
    let mut c = Matrix::zeros(m, n);
    let nthreads = threads_for(2 * m * n * k);
    let run = |rows: std::ops::Range<usize>, out: &mut [f32]| {
        for i in rows.clone() {
            let arow = a.row(i);
            let orow = &mut out[(i - rows.start) * n..(i - rows.start + 1) * n];
            for (j, ov) in orow.iter_mut().enumerate() {
                *ov = dotp(arow, b.row(j));
            }
        }
    };
    if nthreads <= 1 || m < nthreads {
        let out = c.as_mut_slice();
        run(0..m, out);
        return c;
    }
    let rows_per = m.div_ceil(nthreads);
    let chunks: Vec<&mut [f32]> = c.as_mut_slice().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, chunk) in chunks.into_iter().enumerate() {
            let r0 = t * rows_per;
            let r1 = (r0 + chunk.len() / n).min(m);
            let runr = &run;
            s.spawn(move || runr(r0..r1, chunk));
        }
    });
    c
}

/// Dot product, 8-wide unrolled with 4 accumulators (better ILP + accuracy).
#[inline]
pub fn dotp(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = n / 4;
    for c in 0..chunks {
        let o = c * 4;
        s0 += x[o] * y[o];
        s1 += x[o + 1] * y[o + 1];
        s2 += x[o + 2] * y[o + 2];
        s3 += x[o + 3] * y[o + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for o in chunks * 4..n {
        s += x[o] * y[o];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        Matrix::from_fn(m, n, |i, j| {
            (0..k).map(|p| a.at(i, p) * b.at(p, j)).sum()
        })
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Pcg64::new(1, 1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (7, 9, 2), (16, 16, 16)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-4);
        }
    }

    #[test]
    fn matches_naive_threaded() {
        // Big enough to clear the threading threshold (see threads_for).
        let mut rng = Pcg64::new(2, 1);
        let a = Matrix::randn(300, 260, 1.0, &mut rng);
        let b = Matrix::randn(260, 310, 1.0, &mut rng);
        set_matmul_threads(4);
        let c = matmul(&a, &b);
        set_matmul_threads(1);
        let c1 = matmul(&a, &b);
        set_matmul_threads(0);
        assert!(c.max_abs_diff(&c1) < 1e-4);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-2);
    }

    #[test]
    fn single_thread_scopes_count_and_release() {
        // Lower-bound assertions only: other tests in this binary may hold
        // their own scopes concurrently, but ours are always included.
        let a = MatmulSingleThreadScope::enter();
        assert!(matmul_single_scopes() >= 1);
        let b = MatmulSingleThreadScope::enter();
        assert!(matmul_single_scopes() >= 2);
        drop(b);
        assert!(matmul_single_scopes() >= 1);
        drop(a);
        // A capped matmul still computes the right answer.
        let _scope = MatmulSingleThreadScope::enter();
        let mut rng = Pcg64::new(9, 1);
        let x = Matrix::randn(300, 260, 1.0, &mut rng);
        let y = Matrix::randn(260, 310, 1.0, &mut rng);
        let c = matmul(&x, &y);
        assert!(c.max_abs_diff(&naive(&x, &y)) < 1e-2);
    }

    #[test]
    fn tn_nt_match_explicit_transpose() {
        let mut rng = Pcg64::new(3, 1);
        let a = Matrix::randn(40, 30, 1.0, &mut rng);
        let b = Matrix::randn(40, 20, 1.0, &mut rng);
        let tn = matmul_tn(&a, &b); // (30x40)@(40x20)
        assert!(tn.max_abs_diff(&a.transpose().dot(&b)) < 1e-4);

        let a2 = Matrix::randn(25, 30, 1.0, &mut rng);
        let b2 = Matrix::randn(35, 30, 1.0, &mut rng);
        let nt = matmul_nt(&a2, &b2); // (25x30)@(30x35)
        assert!(nt.max_abs_diff(&a2.dot(&b2.transpose())) < 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(4, 1);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        let i = Matrix::eye(12);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn associativity_with_vectors() {
        // (A@B)@x == A@(B@x) within tolerance.
        let mut rng = Pcg64::new(5, 1);
        let a = Matrix::randn(20, 30, 1.0, &mut rng);
        let b = Matrix::randn(30, 25, 1.0, &mut rng);
        let x = Matrix::randn(25, 1, 1.0, &mut rng);
        let left = matmul(&matmul(&a, &b), &x);
        let right = matmul(&a, &matmul(&b, &x));
        assert!(left.max_abs_diff(&right) < 1e-3);
    }
}
