//! Hand-rolled argument parsing (clap is not in the offline vendor set).
//!
//! Grammar: `odlri <command> [positional] [--flag value]... [--switch]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        out.command = it
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("missing command; try `odlri help`"))?;
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // `--k=v`, `--k v`, or switch `--k`.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    out.flags
                        .insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        if argv.is_empty() {
            return Ok(Args {
                command: "help".into(),
                ..Default::default()
            });
        }
        Args::parse(&argv)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} wants an integer, got '{v}'")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} wants an integer, got '{v}'")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} wants a number, got '{v}'")),
        }
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    pub fn positional_at(&self, i: usize, what: &str) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing {what}; try `odlri help`"))
    }

    pub fn reject_unknown(&self, known_flags: &[&str], known_switches: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known_flags.contains(&k.as_str()) {
                bail!("unknown flag --{k} for `{}`", self.command);
            }
        }
        for s in &self.switches {
            if !known_switches.contains(&s.as_str()) {
                bail!("unknown switch --{s} for `{}`", self.command);
            }
        }
        Ok(())
    }
}

pub const HELP: &str = "\
odlri — Outlier-Driven Low-Rank Initialization for joint Q+LR decomposition
(reproduction of Cho et al., ACL 2025 Findings)

USAGE: odlri <command> [options]

COMMANDS
  train        Train a tiny model family via the AOT train-step artifact
                 --family tl-7s --steps 300 --seed 0 --out runs/
  calibrate    Capture activations and accumulate per-matrix Hessians
                 --family tl-7s --weights runs/tl-7s.odw --batches 8
  compress     Compress a trained model (CALDERA / +ODLRI)
                 --family tl-7s --init odlri|caldera|lr-first --rank 64
                 --lr-bits 4 --scheme e8|uniform|mxint --bits 2 --iters 15
                 --fused (also write runs/<family>.odf: the packed container
                 carrying the quantizer's native codes bit-exactly)
                 --fused-out PATH
  eval         Perplexity + zero-shot proxy accuracy of a weight file
                 --family tl-7s --weights runs/tl-7s.odw
                 --fused (packed engine; default weights runs/<family>.odf)
  pipeline     train → calibrate → compress → eval, end to end
                 --family tl-7s --steps 300 --rank 64
  exp <id>     Regenerate a paper table/figure into results/
                 ids: table1 fig2 fig3 fig4 fig5 table2 table3 table4
                      table5 table8 table9 table10 table11 t1norms all
  serve-bench  Dynamic-batching serving latency/throughput
                 --requests 32 --clients 4 --deadline-ms 10
                 --fused --weights runs/<family>.odf (packed (Q+LR)·x engine)
  artifacts    List available artifact entry points
  help         This message

Global flags: --artifacts DIR (default ./artifacts, or $ODLRI_ARTIFACTS).
Without artifacts every command runs on the built-in native engine.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn parses_flags_and_switches() {
        // Note: switches go last (or use --k=v); `--switch positional`
        // would bind the positional as the switch's value.
        let a = parse("compress pos1 --family tl-7s --rank=128 --verbose");
        assert_eq!(a.command, "compress");
        assert_eq!(a.str("family", ""), "tl-7s");
        assert_eq!(a.usize("rank", 0).unwrap(), 128);
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("eval");
        assert_eq!(a.usize("rank", 64).unwrap(), 64);
        let b = parse("eval --rank abc");
        assert!(b.usize("rank", 0).is_err());
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("train --bogus 3");
        assert!(a.reject_unknown(&["steps"], &[]).is_err());
        let b = parse("train --steps 3");
        assert!(b.reject_unknown(&["steps"], &[]).is_ok());
    }

    #[test]
    fn exp_positional() {
        let a = parse("exp table2 --quick");
        assert_eq!(a.positional_at(0, "experiment id").unwrap(), "table2");
        assert!(a.switch("quick"));
    }
}
