//! Hand-rolled argument parsing (clap is not in the offline vendor set).
//!
//! Grammar: `odlri <command> [positional] [--flag value]... [--switch]`.
//!
//! Each command registers its grammar in [`COMMANDS`]; [`Args::from_env`]
//! parses against it, so a known **switch never consumes a following
//! positional as its value** (the historical `--switch positional`
//! footgun), a known **flag always takes a value** — including negative
//! numbers and other leading-dash values — and unknown `--options` are
//! rejected up front with the command name. Unregistered commands fall
//! back to the heuristic parse ([`Args::parse`]).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// One command's option grammar: value-taking flags and boolean switches.
pub struct CommandSpec {
    pub name: &'static str,
    pub flags: &'static [&'static str],
    pub switches: &'static [&'static str],
}

/// The command registry — shared by the parser and `reject_unknown`.
pub const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "train",
        flags: &[
            "family", "steps", "corpus-tokens", "seed", "log-every", "out", "outliers",
            "artifacts",
        ],
        switches: &[],
    },
    CommandSpec {
        name: "calibrate",
        flags: &["family", "weights", "batches", "seed", "out", "artifacts"],
        switches: &[],
    },
    CommandSpec {
        name: "compress",
        flags: &[
            "family", "weights", "hessians", "init", "rank", "lr-bits", "scheme", "bits",
            "group", "iters", "lplr-iters", "workers", "seed", "out", "fused-out", "budget",
            "plan", "artifacts",
        ],
        switches: &["no-hadamard", "verbose", "fused"],
    },
    CommandSpec {
        name: "eval",
        flags: &["family", "weights", "windows", "task-items", "seed", "artifacts"],
        switches: &["fused", "pack-dense"],
    },
    CommandSpec {
        name: "pipeline",
        flags: &[
            "family", "steps", "seed", "init", "rank", "lr-bits", "scheme", "bits", "group",
            "iters", "lplr-iters", "workers", "artifacts",
        ],
        switches: &["no-hadamard", "verbose"],
    },
    CommandSpec {
        name: "exp",
        flags: &["results", "runs", "seed", "artifacts"],
        switches: &["quick", "trained"],
    },
    CommandSpec {
        name: "serve-bench",
        flags: &[
            "family", "weights", "requests", "clients", "deadline-ms", "seed",
            "max-new-tokens", "prompt-len", "kv-budget", "prefill-chunk",
            "batch-clients", "long-prompt-len", "replicas", "draft", "speculate",
            "chaos", "deadline-ticks", "queue-cap", "artifacts",
        ],
        switches: &["fused", "pack-dense", "shared-prompt", "json"],
    },
    CommandSpec {
        name: "generate",
        flags: &[
            "family", "weights", "prompt", "prompt-len", "max-new-tokens", "top-k",
            "temperature", "seed", "draft", "speculate", "artifacts",
        ],
        switches: &["fused", "pack-dense"],
    },
    CommandSpec {
        name: "artifacts",
        flags: &["artifacts"],
        switches: &[],
    },
    CommandSpec {
        name: "help",
        flags: &[],
        switches: &[],
    },
];

pub fn command_spec(name: &str) -> Option<&'static CommandSpec> {
    COMMANDS.iter().find(|c| c.name == name)
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Heuristic parse for commands without a registered grammar: `--k v`
    /// binds `v` unless it starts with `--`, so a switch directly before a
    /// positional would swallow it — registered commands use
    /// [`Args::parse_with`] instead, which cannot misbind.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        out.command = it
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("missing command; try `odlri help`"))?;
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // `--k=v`, `--k v`, or switch `--k`.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    out.flags
                        .insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    /// Grammar-aware parse: switches never take values, flags always do
    /// (accepting leading-dash values such as negative numbers), unknown
    /// options error immediately.
    pub fn parse_with(argv: &[String], spec: &CommandSpec) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter();
        out.command = it
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("missing command; try `odlri help`"))?;
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    if spec.switches.contains(&k) {
                        bail!("--{k} is a switch for `{}` and takes no value", out.command);
                    }
                    if !spec.flags.contains(&k) {
                        bail!("unknown flag --{k} for `{}`; try `odlri help`", out.command);
                    }
                    out.flags.insert(k.to_string(), v.to_string());
                } else if spec.switches.contains(&name) {
                    out.switches.push(name.to_string());
                } else if spec.flags.contains(&name) {
                    match it.next() {
                        // A value may start with a single dash (negative
                        // numbers); only another `--option` is refused.
                        Some(v) if !v.starts_with("--") => {
                            out.flags.insert(name.to_string(), v.clone());
                        }
                        _ => bail!("--{name} wants a value for `{}`", out.command),
                    }
                } else {
                    bail!(
                        "unknown option --{name} for `{}`; try `odlri help`",
                        out.command
                    );
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        if argv.is_empty() {
            return Ok(Args {
                command: "help".into(),
                ..Default::default()
            });
        }
        match command_spec(&argv[0]) {
            Some(spec) => Args::parse_with(&argv, spec),
            None => Args::parse(&argv),
        }
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} wants an integer, got '{v}'")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} wants an integer, got '{v}'")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} wants a number, got '{v}'")),
        }
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    pub fn positional_at(&self, i: usize, what: &str) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing {what}; try `odlri help`"))
    }

    pub fn reject_unknown(&self, known_flags: &[&str], known_switches: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known_flags.contains(&k.as_str()) {
                bail!("unknown flag --{k} for `{}`", self.command);
            }
        }
        for s in &self.switches {
            if !known_switches.contains(&s.as_str()) {
                bail!("unknown switch --{s} for `{}`", self.command);
            }
        }
        Ok(())
    }
}

pub const HELP: &str = "\
odlri — Outlier-Driven Low-Rank Initialization for joint Q+LR decomposition
(reproduction of Cho et al., ACL 2025 Findings)

USAGE: odlri <command> [options]

COMMANDS
  train        Train a tiny model family via the AOT train-step artifact
                 --family tl-7s --steps 300 --seed 0 --out runs/
                 --corpus-tokens 400000 --log-every 25
                 --outliers 4 (planted outlier-channel boosts)
  calibrate    Capture activations and accumulate per-matrix Hessians
                 --family tl-7s --weights runs/tl-7s.odw --batches 8
  compress     Compress a trained model (CALDERA / +ODLRI)
                 --family tl-7s --init odlri|caldera|lr-first --rank 64
                 --lr-bits 4 --scheme e8|uniform|mxint --bits 2 --iters 15
                 --group 64 (quantizer group size) --lplr-iters 10
                 --workers 0 (0 = all cores) --no-hadamard --verbose
                 --hessians FILE (default runs/<family>.hess)
                 --budget B (per-projection plan: outlier-sensitive
                 projections get more rank/bits under a model-wide
                 avg-bits ceiling B)
                 --plan FILE (explicit per-projection plan; key=value with
                 [projection] sections overriding the CLI recipe)
                 --fused (also write runs/<family>.odf: the packed ODF3
                 container carrying the quantizer's native codes
                 bit-exactly plus the per-projection plan)
                 --fused-out PATH
  eval         Perplexity + zero-shot proxy accuracy through the Engine API
                 --family tl-7s --weights runs/tl-7s.odw
                 --windows 40 (perplexity windows) --task-items 64
                 --fused (packed engine; default weights runs/<family>.odf)
  pipeline     train → calibrate → compress → eval, end to end
                 --family tl-7s --steps 300 --rank 64
  exp <id>     Regenerate a paper table/figure into results/
                 ids: table1 fig2 fig3 fig4 fig5 table2 table3 table4
                      table5 table8 table9 table10 table11 t1norms
                      budget (uniform vs per-projection plans)
                      speculate (draft-bits × k acceptance / ms-per-tok)
                      all
                 --results results/ --runs runs/ (output / weight dirs)
                 --quick (smaller grids) --trained (reuse runs/ weights)
  generate     KV-cached incremental decoding with a per-token latency
               report (packed engines additionally report decode
               weight-throughput in GB/s over Q and which decode kernel ran)
                 --prompt \"text\" (or --prompt-len N from the corpus)
                 --max-new-tokens 64 --top-k 0 (greedy) --temperature 1.0
                 --fused (packed engine) --pack-dense (pack weights at
                 8-bit on the fly — no .odf needed)
                 --draft PATH (speculative decoding: a low-bit packed
                 draft proposes tokens, the target verifies them in one
                 batched step — greedy output stays bit-identical)
                 --speculate K (draft depth per round, default 4; with
                 --pack-dense and no --draft a 2-bit draft is packed on
                 the fly from the same dense weights)
  serve-bench  Continuous-batching serving latency/throughput (packed
               generation workloads also report decode GB/s over Q)
                 --requests 32 --clients 4 --deadline-ms 10
                 --max-new-tokens N (generation workload; 0 = scoring)
                 --prompt-len N --fused --pack-dense
                 --weights runs/<family>.odf (packed (Q+LR)·x engine)
                 --kv-budget BYTES (hard paged-KV pool cap, e.g. 512k 64m;
                 sessions past the budget are preempted and later resumed
                 bit-exactly) --shared-prompt (every request reuses one
                 system prompt: benches cross-session KV prefix sharing)
                 --prefill-chunk T (chunked prefill: at most T prompt
                 tokens per tick, decode-first interleaving so a long
                 prompt never stalls decode; 0 = monolithic prefill)
                 --batch-clients K (last K client threads submit at Batch
                 priority; Interactive work overtakes queued Batch work,
                 FIFO within each class)
                 --long-prompt-len N (client 0's first generate request
                 carries an N-token prompt: stresses chunked prefill)
                 --replicas N (N packed-engine replicas with private KV
                 pools behind least-loaded routing; needs --fused)
                 --draft PATH --speculate K (speculative decoding for
                 greedy streams: reports acceptance rate and drafted /
                 accepted / rejected token counters)
                 --chaos SPEC (seeded fault injection, e.g.
                 \"pool=0.2,replica=0.1,draft=0.3,abort=0.1,slow=0.2\";
                 same --seed replays the same fault sequence; the report
                 gains shed / timed-out / failover / breaker counters)
                 --deadline-ticks N (per-request deadline in scheduler
                 ticks; expired requests answer TimedOut; 0 = none)
                 --queue-cap N (bounded admission queue: arrivals past the
                 cap are shed, Batch before Interactive; 0 = unbounded)
                 --json (append a one-line machine-readable report)
  artifacts    List available artifact entry points
  help         This message

Global flags: --artifacts DIR (default ./artifacts, or $ODLRI_ARTIFACTS).
Without artifacts every command runs on the built-in native engine.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        Args::parse(&argv).unwrap()
    }

    fn parse_reg(s: &str) -> Result<Args> {
        let argv: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        let spec = command_spec(&argv[0]).expect("registered command");
        Args::parse_with(&argv, spec)
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = parse("compress pos1 --family tl-7s --rank=128 --verbose");
        assert_eq!(a.command, "compress");
        assert_eq!(a.str("family", ""), "tl-7s");
        assert_eq!(a.usize("rank", 0).unwrap(), 128);
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("eval");
        assert_eq!(a.usize("rank", 64).unwrap(), 64);
        let b = parse("eval --rank abc");
        assert!(b.usize("rank", 0).is_err());
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("train --bogus 3");
        assert!(a.reject_unknown(&["steps"], &[]).is_err());
        let b = parse("train --steps 3");
        assert!(b.reject_unknown(&["steps"], &[]).is_ok());
    }

    #[test]
    fn exp_positional() {
        let a = parse("exp table2 --quick");
        assert_eq!(a.positional_at(0, "experiment id").unwrap(), "table2");
        assert!(a.switch("quick"));
    }

    #[test]
    fn registry_switch_never_consumes_a_positional() {
        // The historical footgun: the heuristic parse would bind `table2`
        // as --quick's value. The grammar-aware parse cannot.
        let a = parse_reg("exp --quick table2").unwrap();
        assert!(a.switch("quick"));
        assert_eq!(a.positional_at(0, "experiment id").unwrap(), "table2");
        assert_eq!(a.str("quick", "unset"), "unset");

        let b = parse_reg("compress --fused out.odw --rank 8").unwrap();
        assert!(b.switch("fused"));
        assert_eq!(b.positional, vec!["out.odw"]);
        assert_eq!(b.usize("rank", 0).unwrap(), 8);
    }

    #[test]
    fn serve_bench_scheduler_flags_are_registered() {
        let a = parse_reg(
            "serve-bench --fused --pack-dense --replicas 2 --prefill-chunk 16 \
             --batch-clients 1 --long-prompt-len 192 --json",
        )
        .unwrap();
        assert!(a.switch("fused") && a.switch("json"));
        assert_eq!(a.usize("replicas", 1).unwrap(), 2);
        assert_eq!(a.usize("prefill-chunk", 0).unwrap(), 16);
        assert_eq!(a.usize("batch-clients", 0).unwrap(), 1);
        assert_eq!(a.usize("long-prompt-len", 0).unwrap(), 192);
        // Robustness knobs: --chaos takes a spec string, the other two
        // integers — all flags, never switches.
        let b = parse_reg(
            "serve-bench --chaos pool=0.2,draft=0.3 --deadline-ticks 64 --queue-cap 8 --json",
        )
        .unwrap();
        assert_eq!(b.str("chaos", ""), "pool=0.2,draft=0.3");
        assert_eq!(b.usize("deadline-ticks", 0).unwrap(), 64);
        assert_eq!(b.usize("queue-cap", 0).unwrap(), 8);
        assert!(parse_reg("serve-bench --chaos").is_err());
    }

    #[test]
    fn speculation_flags_are_registered_on_both_decode_commands() {
        // --draft and --speculate are value-taking flags, never switches:
        // a following positional or path must bind as the value.
        let a = parse_reg("generate --fused --draft runs/tl-7s-draft.odf --speculate 4").unwrap();
        assert_eq!(a.str("draft", ""), "runs/tl-7s-draft.odf");
        assert_eq!(a.usize("speculate", 0).unwrap(), 4);
        let b = parse_reg(
            "serve-bench --fused --pack-dense --draft d.odf --speculate 2 --json",
        )
        .unwrap();
        assert_eq!(b.str("draft", ""), "d.odf");
        assert_eq!(b.usize("speculate", 0).unwrap(), 2);
        assert!(b.switch("json"));
        // A negative depth parses as a flag value but fails integer
        // conversion with a typed error (usize has no sign bit).
        let c = parse_reg("generate --speculate -2").unwrap();
        let err = c.usize("speculate", 4).unwrap_err();
        assert!(err.to_string().contains("--speculate"), "err: {err:#}");
        // Dangling flags are rejected at parse time, not at use time.
        assert!(parse_reg("generate --draft").is_err());
        assert!(parse_reg("serve-bench --draft --fused").is_err());
        let d = parse_reg("generate --speculate=3").unwrap();
        assert_eq!(d.usize("speculate", 0).unwrap(), 3);
    }

    #[test]
    fn registry_flag_accepts_negative_number_values() {
        let a = parse_reg("generate --temperature -0.75 --max-new-tokens 4").unwrap();
        assert!((a.f64("temperature", 0.0).unwrap() + 0.75).abs() < 1e-12);
        assert_eq!(a.usize("max-new-tokens", 0).unwrap(), 4);
        // `--k=v` spelling too.
        let b = parse_reg("generate --temperature=-1.5").unwrap();
        assert!((b.f64("temperature", 0.0).unwrap() + 1.5).abs() < 1e-12);
    }

    #[test]
    fn registry_rejects_malformed_options() {
        // Unknown option.
        assert!(parse_reg("eval --bogus 3").is_err());
        // Flag at end of line without a value.
        assert!(parse_reg("eval --weights").is_err());
        // Flag whose "value" is another option.
        assert!(parse_reg("eval --weights --fused").is_err());
        // Switch given a value.
        assert!(parse_reg("eval --fused=1").is_err());
    }

    #[test]
    fn every_builtin_command_is_registered() {
        for name in [
            "train",
            "calibrate",
            "compress",
            "eval",
            "pipeline",
            "exp",
            "serve-bench",
            "generate",
            "artifacts",
            "help",
        ] {
            assert!(command_spec(name).is_some(), "missing registry entry: {name}");
        }
        assert!(command_spec("nope").is_none());
    }
}
