//! Symmetric eigendecomposition (classical two-sided Jacobi).
//!
//! Fallback whitening path: when the outlier Hessian submatrix `H_o` is so
//! rank-deficient that even jittered Cholesky is distasteful, ODLRI can
//! whiten through `H_o = V diag(λ) V^T` with the PSD square root
//! `S_o = V diag(√λ₊)`. Also used by tests to cross-check the SVD.

use crate::tensor::Matrix;

/// Eigendecomposition of a symmetric matrix: A = V diag(vals) V^T,
/// eigenvalues sorted descending. Only the lower triangle of `a` is read.
pub fn eigh(a: &Matrix) -> (Vec<f32>, Matrix) {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "eigh needs a square matrix");
    // f64 working copy for stability.
    let mut w = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            // Symmetrize from the lower triangle.
            let v = if i >= j { a.at(i, j) } else { a.at(j, i) };
            w[i * n + j] = v as f64;
        }
    }
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 100;
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0f64;
        for i in 0..n {
            for j in i + 1..n {
                off += w[i * n + j] * w[i * n + j];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = w[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = w[p * n + p];
                let aqq = w[q * n + q];
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Update rows/cols p and q of W (symmetric rotation).
                for k in 0..n {
                    let wkp = w[k * n + p];
                    let wkq = w[k * n + q];
                    w[k * n + p] = c * wkp - s * wkq;
                    w[k * n + q] = s * wkp + c * wkq;
                }
                for k in 0..n {
                    let wpk = w[p * n + k];
                    let wqk = w[q * n + k];
                    w[p * n + k] = c * wpk - s * wqk;
                    w[q * n + k] = s * wpk + c * wqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Sort by eigenvalue descending.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a_, &b_| {
        w[b_ * n + b_]
            .partial_cmp(&w[a_ * n + a_])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let vals: Vec<f32> = idx.iter().map(|&i| w[i * n + i] as f32).collect();
    let mut vecs = Matrix::zeros(n, n);
    for (k, &j) in idx.iter().enumerate() {
        for i in 0..n {
            *vecs.at_mut(i, k) = v[i * n + j] as f32;
        }
    }
    (vals, vecs)
}

/// PSD square-root factor S with A ≈ S S^T, clamping negative eigenvalues
/// to zero. For full-rank PD matrices this matches Cholesky up to an
/// orthogonal factor, which is all whitening needs.
pub fn psd_sqrt(a: &Matrix) -> Matrix {
    let (vals, vecs) = eigh(a);
    let sq: Vec<f32> = vals.iter().map(|&l| l.max(0.0).sqrt()).collect();
    vecs.mul_diag_right(&sq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn reconstructs_symmetric() {
        let mut rng = Pcg64::new(50, 1);
        for n in [1usize, 3, 10, 32] {
            let b = Matrix::randn(n, n, 1.0, &mut rng);
            let a = b.add(&b.transpose()).scale(0.5);
            let (vals, vecs) = eigh(&a);
            let rec = vecs.mul_diag_right(&vals).dot_t(&vecs);
            assert!(rec.rel_err(&a) < 1e-3, "n={n} err={}", rec.rel_err(&a));
            // Orthogonal eigenvectors.
            assert!(vecs.tdot(&vecs).rel_err(&Matrix::eye(n)) < 1e-3);
            // Descending eigenvalues.
            for w in vals.windows(2) {
                assert!(w[0] >= w[1] - 1e-4);
            }
        }
    }

    #[test]
    fn known_eigenvalues() {
        // [[2,1],[1,2]] has eigenvalues 3, 1.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (vals, _) = eigh(&a);
        assert!((vals[0] - 3.0).abs() < 1e-5);
        assert!((vals[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn psd_sqrt_squares_back() {
        let mut rng = Pcg64::new(51, 1);
        let b = Matrix::randn(12, 20, 1.0, &mut rng);
        let a = b.dot_t(&b);
        let s = psd_sqrt(&a);
        assert!(s.dot_t(&s).rel_err(&a) < 1e-3);
    }

    #[test]
    fn psd_sqrt_handles_rank_deficiency() {
        // Rank-2 PSD in 5 dims.
        let mut rng = Pcg64::new(52, 1);
        let b = Matrix::randn(5, 2, 1.0, &mut rng);
        let a = b.dot_t(&b);
        let s = psd_sqrt(&a);
        assert!(s.dot_t(&s).rel_err(&a) < 1e-3);
    }

    #[test]
    fn eigvals_match_svd_singular_values_for_psd() {
        let mut rng = Pcg64::new(53, 1);
        let b = Matrix::randn(10, 14, 1.0, &mut rng);
        let a = b.dot_t(&b);
        let (vals, _) = eigh(&a);
        let svd = crate::linalg::svd_jacobi(&a);
        for (l, s) in vals.iter().zip(svd.s.iter()) {
            assert!((l - s).abs() < 1e-2 * s.max(1.0), "λ={l} σ={s}");
        }
    }
}
