//! Cholesky factorization and triangular solves.
//!
//! Used for (1) SVD-LLM-style whitening of the (outlier-restricted) Hessian
//! in ODLRI — `H_o = S_o S_o^T` with `S_o` lower-triangular (paper App. B.1),
//! (2) the LDLQ error-feedback quantizer, and (3) activation-aware least
//! squares in LPLR.

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor L with A = L L^T.
/// Fails if A is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // f64 accumulation: Hessians can be ill-conditioned.
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (d={sum:.3e})");
                }
                *l.at_mut(i, j) = (sum.sqrt()) as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Cholesky with automatic diagonal jitter: A + λ·mean(diag)·I, escalating λ
/// by 10× until the factorization succeeds (CALDERA's Hessian regularization
/// convention). Returns (L, λ_used).
pub fn cholesky_jittered(a: &Matrix, lambda0: f64) -> Result<(Matrix, f64)> {
    let n = a.rows();
    let mean_diag = {
        let d: f64 = (0..n).map(|i| a.at(i, i) as f64).sum();
        (d / n.max(1) as f64).max(1e-12)
    };
    let mut lambda = lambda0;
    for _ in 0..12 {
        let mut aj = a.clone();
        let jit = (lambda * mean_diag) as f32;
        for i in 0..n {
            *aj.at_mut(i, i) += jit;
        }
        if let Ok(l) = cholesky(&aj) {
            return Ok((l, lambda));
        }
        lambda = if lambda == 0.0 { 1e-8 } else { lambda * 10.0 };
    }
    bail!("cholesky failed even with jitter λ={lambda}");
}

/// Solve L X = B for X with L lower-triangular. B: (n x k).
pub fn solve_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(b.rows(), n);
    let k = b.cols();
    let mut x = b.clone();
    for col in 0..k {
        for i in 0..n {
            let mut sum = x.at(i, col) as f64;
            for j in 0..i {
                sum -= l.at(i, j) as f64 * x.at(j, col) as f64;
            }
            *x.at_mut(i, col) = (sum / l.at(i, i) as f64) as f32;
        }
    }
    x
}

/// Solve L^T X = B for X with L lower-triangular (i.e. upper-tri solve with
/// L's transpose, without materializing it).
pub fn solve_lower_transpose(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(b.rows(), n);
    let k = b.cols();
    let mut x = b.clone();
    for col in 0..k {
        for i in (0..n).rev() {
            let mut sum = x.at(i, col) as f64;
            for j in i + 1..n {
                // (L^T)[i, j] = L[j, i]
                sum -= l.at(j, i) as f64 * x.at(j, col) as f64;
            }
            *x.at_mut(i, col) = (sum / l.at(i, i) as f64) as f32;
        }
    }
    x
}

/// Solve U X = B for X with U upper-triangular. B: (n x k).
pub fn solve_upper(u: &Matrix, b: &Matrix) -> Matrix {
    let n = u.rows();
    assert_eq!(b.rows(), n);
    let k = b.cols();
    let mut x = b.clone();
    for col in 0..k {
        for i in (0..n).rev() {
            let mut sum = x.at(i, col) as f64;
            for j in i + 1..n {
                sum -= u.at(i, j) as f64 * x.at(j, col) as f64;
            }
            *x.at_mut(i, col) = (sum / u.at(i, i) as f64) as f32;
        }
    }
    x
}

/// Explicit inverse of a lower-triangular matrix (used for S_o^{-1} in the
/// ODLRI back-transform R_0 = sqrt(Σ) V^T S_o^{-1}).
pub fn tri_inverse_lower(l: &Matrix) -> Matrix {
    let n = l.rows();
    solve_lower(l, &Matrix::eye(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, rng: &mut Pcg64) -> Matrix {
        let a = Matrix::randn(n, n + 4, 1.0, rng);
        let mut h = a.dot_t(&a); // A A^T is PSD, nearly PD for n+4 samples
        for i in 0..n {
            *h.at_mut(i, i) += 0.1;
        }
        h
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Pcg64::new(20, 1);
        for n in [1usize, 2, 5, 16, 40] {
            let h = random_spd(n, &mut rng);
            let l = cholesky(&h).unwrap();
            let rec = l.dot_t(&l);
            assert!(rec.rel_err(&h) < 1e-4, "n={n} err={}", rec.rel_err(&h));
            // L is lower triangular.
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn jitter_rescues_singular() {
        // Rank-1 PSD matrix — plain cholesky fails at pivot 1 for n>1.
        let v = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let h = v.dot_t(&v);
        assert!(cholesky(&h).is_err());
        let (l, lam) = cholesky_jittered(&h, 1e-4).unwrap();
        assert!(lam >= 1e-4);
        assert!(l.dot_t(&l).rel_err(&h) < 0.05);
    }

    #[test]
    fn solves_match_inverse() {
        let mut rng = Pcg64::new(21, 1);
        let h = random_spd(12, &mut rng);
        let l = cholesky(&h).unwrap();
        let b = Matrix::randn(12, 3, 1.0, &mut rng);
        // L (L^T x) = b  ⇒  x = H^{-1} b
        let y = solve_lower(&l, &b);
        let x = solve_lower_transpose(&l, &y);
        let hx = h.dot(&x);
        assert!(hx.rel_err(&b) < 1e-3, "err={}", hx.rel_err(&b));
    }

    #[test]
    fn solve_upper_works() {
        let u = Matrix::from_vec(3, 3, vec![2.0, 1.0, 0.5, 0.0, 3.0, -1.0, 0.0, 0.0, 4.0]);
        let x_true = Matrix::from_vec(3, 2, vec![1.0, -2.0, 0.5, 3.0, 2.0, 0.0]);
        let b = u.dot(&x_true);
        let x = solve_upper(&u, &b);
        assert!(x.max_abs_diff(&x_true) < 1e-5);
    }

    #[test]
    fn tri_inverse_is_inverse() {
        let mut rng = Pcg64::new(22, 1);
        let h = random_spd(10, &mut rng);
        let l = cholesky(&h).unwrap();
        let linv = tri_inverse_lower(&l);
        let prod = l.dot(&linv);
        assert!(prod.rel_err(&Matrix::eye(10)) < 1e-4);
    }
}
