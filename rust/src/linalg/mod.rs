//! Dense linear algebra substrate (std-only, f32 with f64 accumulation
//! where it matters).
//!
//! Everything the paper's algorithms need: Cholesky (for Hessian whitening
//! and LDLQ), Householder QR (for randomized SVD and LPLR least squares),
//! one-sided Jacobi SVD (exact, used for the truncated factorization in
//! ODLRI and LRApprox), randomized subspace SVD (fast path for large
//! matrices), and a symmetric eigendecomposition (whitening fallback when
//! the outlier Hessian submatrix is rank-deficient).

mod cholesky;
mod eigh;
mod qr;
mod svd;

pub use cholesky::{cholesky, cholesky_jittered, solve_lower, solve_lower_transpose, solve_upper, tri_inverse_lower};
pub use eigh::{eigh, psd_sqrt};
pub use qr::{householder_qr, thin_qr};
pub use svd::{randomized_svd, svd_jacobi, truncated_svd, Svd};

use crate::tensor::Matrix;

/// Solve the least-squares problem min ‖A X - B‖_F via QR (A tall, full rank).
/// A: (m x n) with m >= n, B: (m x k) → X: (n x k).
pub fn lstsq(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n) = a.shape();
    assert!(m >= n, "lstsq requires a tall matrix");
    let (q, r) = thin_qr(a);
    // X = R^{-1} Q^T B
    let qtb = q.tdot(b);
    solve_upper(&r, &qtb)
}

/// Relative reconstruction check helper used across tests.
pub fn recon_err(a: &Matrix, b: &Matrix) -> f32 {
    a.rel_err(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn lstsq_recovers_planted_solution() {
        let mut rng = Pcg64::new(10, 1);
        let a = Matrix::randn(40, 12, 1.0, &mut rng);
        let x_true = Matrix::randn(12, 3, 1.0, &mut rng);
        let b = a.dot(&x_true);
        let x = lstsq(&a, &b);
        assert!(x.max_abs_diff(&x_true) < 1e-3, "err={}", x.max_abs_diff(&x_true));
    }

    #[test]
    fn lstsq_minimizes_residual() {
        // For noisy b, the residual must be orthogonal to the column space.
        let mut rng = Pcg64::new(11, 1);
        let a = Matrix::randn(30, 8, 1.0, &mut rng);
        let b = Matrix::randn(30, 2, 1.0, &mut rng);
        let x = lstsq(&a, &b);
        let resid = b.sub(&a.dot(&x));
        let at_r = a.tdot(&resid);
        assert!(at_r.abs_max() < 1e-3, "A^T r = {}", at_r.abs_max());
    }
}
