//! Singular value decomposition.
//!
//! Two engines:
//! * [`svd_jacobi`] — one-sided Jacobi rotation SVD. Exact (to f32 round-off),
//!   O(m n² · sweeps); the workhorse for the ≤512-dim matrices of the tiny
//!   model families and for the r×r cores of the randomized path.
//! * [`randomized_svd`] — Halko-style sketch + power iterations + small exact
//!   SVD; used when only a rank-r truncation is needed and min(m,n) is large.
//!
//! [`truncated_svd`] picks the engine by problem size; decomposition code
//! (ODLRI init, LRApprox, LPLR) always calls it.

use super::qr::thin_qr;
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// SVD result: A = U diag(s) V^T with U (m x k), s (k), V (n x k),
/// singular values sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub v: Matrix,
}

impl Svd {
    /// Reconstruct U diag(s) V^T.
    pub fn reconstruct(&self) -> Matrix {
        let us = self.u.mul_diag_right(&self.s);
        us.dot_t(&self.v)
    }

    /// Truncate to the top-r singular triplets.
    pub fn truncate(&self, r: usize) -> Svd {
        let r = r.min(self.s.len());
        Svd {
            u: self.u.slice(0, self.u.rows(), 0, r),
            s: self.s[..r].to_vec(),
            v: self.v.slice(0, self.v.rows(), 0, r),
        }
    }

    /// Split into (L, R) with the paper's symmetric-sqrt convention:
    /// L = U √Σ, R = √Σ V^T  (App. B.1).
    pub fn split_lr(&self) -> (Matrix, Matrix) {
        let sq: Vec<f32> = self.s.iter().map(|&x| x.max(0.0).sqrt()).collect();
        let l = self.u.mul_diag_right(&sq);
        let r = self.v.mul_diag_right(&sq).transpose();
        (l, r)
    }
}

/// One-sided Jacobi SVD of A (any shape). Returns the full economy SVD with
/// k = min(m, n) triplets.
pub fn svd_jacobi(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        // Factor the transpose and swap U/V.
        let t = svd_jacobi(&a.transpose());
        return Svd {
            u: t.v,
            s: t.s,
            v: t.u,
        };
    }
    let _ = (m, n);
    jacobi_with_v(a)
}

/// Internal: one-sided Jacobi tracking V explicitly — rotate the columns of
/// a working copy G until pairwise orthogonal while accumulating the same
/// rotations into V; then σ_j = ‖g_j‖ and u_j = g_j/σ_j.
fn jacobi_with_v(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    debug_assert!(m >= n);
    let mut g = a.clone();
    let mut v = Matrix::eye(n);
    let max_sweeps = 60;
    loop_sweeps(&mut g, &mut v, m, n, max_sweeps);
    // Extract singular values and U.
    let mut idx: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| {
            let mut s = 0f64;
            for i in 0..m {
                let x = g.at(i, j) as f64;
                s += x * x;
            }
            s.sqrt()
        })
        .collect();
    idx.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
    let mut u = Matrix::zeros(m, n);
    let mut vv = Matrix::zeros(n, n);
    let mut s = vec![0f32; n];
    for (k, &j) in idx.iter().enumerate() {
        let nj = norms[j];
        s[k] = nj as f32;
        if nj > 1e-20 {
            for i in 0..m {
                *u.at_mut(i, k) = (g.at(i, j) as f64 / nj) as f32;
            }
        } else {
            // Null direction: leave a zero column (consumers treat s=0).
            *u.at_mut(k.min(m - 1), k) = 1.0;
        }
        for i in 0..n {
            *vv.at_mut(i, k) = v.at(i, j);
        }
    }
    Svd { u, s, v: vv }
}

fn loop_sweeps(g: &mut Matrix, v: &mut Matrix, m: usize, n: usize, max_sweeps: usize) {
    let eps = 1e-12f64;
    for _ in 0..max_sweeps {
        let mut rotated = false;
        for p in 0..n {
            for q in p + 1..n {
                let (mut app, mut aqq, mut apq) = (0f64, 0f64, 0f64);
                for i in 0..m {
                    let gp = g.at(i, p) as f64;
                    let gq = g.at(i, q) as f64;
                    app += gp * gp;
                    aqq += gq * gq;
                    apq += gp * gq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                rotated = true;
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let gp = g.at(i, p) as f64;
                    let gq = g.at(i, q) as f64;
                    *g.at_mut(i, p) = (c * gp - s * gq) as f32;
                    *g.at_mut(i, q) = (s * gp + c * gq) as f32;
                }
                for i in 0..n {
                    let vp = v.at(i, p) as f64;
                    let vq = v.at(i, q) as f64;
                    *v.at_mut(i, p) = (c * vp - s * vq) as f32;
                    *v.at_mut(i, q) = (s * vp + c * vq) as f32;
                }
            }
        }
        if !rotated {
            break;
        }
    }
}

/// Randomized truncated SVD (Halko–Martinsson–Tropp): rank-r approximation
/// with `oversample` extra sketch columns and `power_iters` subspace
/// iterations. Deterministic given `rng`.
pub fn randomized_svd(
    a: &Matrix,
    rank: usize,
    oversample: usize,
    power_iters: usize,
    rng: &mut Pcg64,
) -> Svd {
    let (m, n) = a.shape();
    let k = (rank + oversample).min(n).min(m);
    // Sketch: Y = A Ω, Ω ~ N(0,1)^{n x k}
    let omega = Matrix::randn(n, k, 1.0, rng);
    let mut y = a.dot(&omega);
    // Power iterations with QR re-orthonormalization for spectral accuracy.
    for _ in 0..power_iters {
        let (q, _) = thin_qr(&y);
        let z = a.tdot(&q); // (n x k)
        let (qz, _) = thin_qr(&z);
        y = a.dot(&qz);
    }
    let (q, _) = thin_qr(&y); // (m x k)
    // B = Q^T A  (k x n), exact SVD of the small B.
    let b = q.tdot(a);
    let sb = svd_jacobi(&b);
    let u = q.dot(&sb.u);
    Svd {
        u,
        s: sb.s,
        v: sb.v,
    }
    .truncate(rank)
}

/// Rank-r truncated SVD with automatic engine choice.
pub fn truncated_svd(a: &Matrix, rank: usize, rng: &mut Pcg64) -> Svd {
    let (m, n) = a.shape();
    let k = m.min(n);
    let rank = rank.min(k);
    // Jacobi is O(k² · max(m,n) · sweeps); the randomized path costs a few
    // rank-k matmuls. Heuristic crossover: use exact for small problems or
    // when nearly full rank is requested.
    if k <= 96 || rank * 3 >= k {
        svd_jacobi(a).truncate(rank)
    } else {
        randomized_svd(a, rank, 8.min(k - rank), 2, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planted_lowrank(m: usize, n: usize, r: usize, rng: &mut Pcg64) -> Matrix {
        let l = Matrix::randn(m, r, 1.0, rng);
        let rr = Matrix::randn(r, n, 1.0, rng);
        l.dot(&rr)
    }

    #[test]
    fn jacobi_reconstructs() {
        let mut rng = Pcg64::new(40, 1);
        for &(m, n) in &[(6usize, 6usize), (20, 8), (8, 20), (1, 5), (33, 17)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = svd_jacobi(&a);
            assert!(
                svd.reconstruct().rel_err(&a) < 1e-4,
                "{m}x{n} err={}",
                svd.reconstruct().rel_err(&a)
            );
            // Orthonormal factors.
            let k = m.min(n);
            assert!(svd.u.tdot(&svd.u).rel_err(&Matrix::eye(k)) < 1e-3);
            assert!(svd.v.tdot(&svd.v).rel_err(&Matrix::eye(k)) < 1e-3);
            // Descending singular values.
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-5);
            }
        }
    }

    #[test]
    fn jacobi_matches_known_values() {
        // diag(3, 2, 1) embedded in a rotation-free matrix.
        let a = Matrix::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let svd = svd_jacobi(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn truncation_recovers_planted_rank() {
        let mut rng = Pcg64::new(41, 1);
        let a = planted_lowrank(40, 30, 5, &mut rng);
        let svd = svd_jacobi(&a);
        // Singular values beyond rank 5 are ~0.
        assert!(svd.s[5] < 1e-3 * svd.s[0]);
        let t = svd.truncate(5);
        assert!(t.reconstruct().rel_err(&a) < 1e-3);
    }

    #[test]
    fn randomized_matches_exact_on_lowrank() {
        let mut rng = Pcg64::new(42, 1);
        let a = planted_lowrank(120, 100, 10, &mut rng);
        let mut rng2 = Pcg64::new(43, 1);
        let rsvd = randomized_svd(&a, 10, 6, 2, &mut rng2);
        assert!(rsvd.reconstruct().rel_err(&a) < 1e-3);
    }

    #[test]
    fn randomized_close_on_decaying_spectrum() {
        // Spectrum with geometric decay: randomized rank-8 ≈ exact rank-8.
        let mut rng = Pcg64::new(44, 1);
        let u = thin_qr(&Matrix::randn(80, 30, 1.0, &mut rng)).0;
        let v = thin_qr(&Matrix::randn(60, 30, 1.0, &mut rng)).0;
        let s: Vec<f32> = (0..30).map(|i| 0.7f32.powi(i as i32)).collect();
        let a = u.mul_diag_right(&s).dot_t(&v);
        let exact = svd_jacobi(&a).truncate(8).reconstruct();
        let mut rng2 = Pcg64::new(45, 1);
        let approx = randomized_svd(&a, 8, 8, 3, &mut rng2).reconstruct();
        let e_exact = exact.rel_err(&a);
        let e_approx = approx.rel_err(&a);
        assert!(
            e_approx < e_exact * 1.2 + 1e-4,
            "exact={e_exact} approx={e_approx}"
        );
    }

    #[test]
    fn split_lr_multiplies_back() {
        let mut rng = Pcg64::new(46, 1);
        let a = planted_lowrank(25, 35, 6, &mut rng);
        let svd = truncated_svd(&a, 6, &mut rng);
        let (l, r) = svd.split_lr();
        assert_eq!(l.shape(), (25, 6));
        assert_eq!(r.shape(), (6, 35));
        assert!(l.dot(&r).rel_err(&a) < 1e-3);
    }

    #[test]
    fn zero_matrix_svd() {
        let a = Matrix::zeros(5, 4);
        let svd = svd_jacobi(&a);
        assert!(svd.s.iter().all(|&s| s == 0.0));
        assert!(svd.reconstruct().frob_norm() == 0.0);
    }
}
