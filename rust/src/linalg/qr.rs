//! Householder QR factorization.
//!
//! Used by the randomized SVD (orthonormalizing sketches) and by the
//! least-squares solves inside LPLR. Numerically robust (Householder, not
//! Gram–Schmidt) with f64 accumulation in the reflector applications.

use crate::tensor::Matrix;

/// Full Householder QR. Returns (Q, R) with Q: (m x m) orthogonal and
/// R: (m x n) upper-triangular (trapezoidal when m > n).
pub fn householder_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    let mut r = a.clone();
    let mut q = Matrix::eye(m);
    let steps = n.min(m.saturating_sub(1));
    let mut v = vec![0f32; m];
    for k in 0..steps {
        // Build the Householder vector for column k below the diagonal.
        let mut norm2 = 0f64;
        for i in k..m {
            let x = r.at(i, k) as f64;
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm < 1e-30 {
            continue;
        }
        let akk = r.at(k, k) as f64;
        let alpha = if akk >= 0.0 { -norm } else { norm };
        let mut vnorm2 = 0f64;
        for i in k..m {
            let vi = if i == k {
                r.at(i, k) as f64 - alpha
            } else {
                r.at(i, k) as f64
            };
            v[i] = vi as f32;
            vnorm2 += vi * vi;
        }
        if vnorm2 < 1e-30 {
            continue;
        }
        let beta = 2.0 / vnorm2;
        // R ← (I - beta v v^T) R
        for j in k..n {
            let mut dot = 0f64;
            for i in k..m {
                dot += v[i] as f64 * r.at(i, j) as f64;
            }
            let s = (beta * dot) as f32;
            for i in k..m {
                *r.at_mut(i, j) -= s * v[i];
            }
        }
        // Q ← Q (I - beta v v^T)
        for i in 0..m {
            let mut dot = 0f64;
            for j in k..m {
                dot += q.at(i, j) as f64 * v[j] as f64;
            }
            let s = (beta * dot) as f32;
            for j in k..m {
                *q.at_mut(i, j) -= s * v[j];
            }
        }
    }
    // Zero out the strictly-lower part of R (numerical dust).
    for i in 1..m {
        for j in 0..i.min(n) {
            *r.at_mut(i, j) = 0.0;
        }
    }
    (q, r)
}

/// Thin QR for a tall matrix: Q (m x n) with orthonormal columns, R (n x n).
pub fn thin_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    assert!(m >= n, "thin_qr requires m >= n, got {m}x{n}");
    let (q, r) = householder_qr(a);
    (q.slice(0, m, 0, n), r.slice(0, n, 0, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::new(30, 1);
        for &(m, n) in &[(5usize, 5usize), (10, 4), (4, 7), (1, 1), (30, 30)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (q, r) = householder_qr(&a);
            assert!(q.dot(&r).rel_err(&a) < 1e-4, "{m}x{n}");
            // Q orthogonal.
            let qtq = q.tdot(&q);
            assert!(qtq.rel_err(&Matrix::eye(m)) < 1e-4, "{m}x{n} Q not orth");
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg64::new(31, 1);
        let a = Matrix::randn(8, 6, 1.0, &mut rng);
        let (_, r) = householder_qr(&a);
        for i in 0..8 {
            for j in 0..6.min(i) {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn thin_qr_columns_orthonormal() {
        let mut rng = Pcg64::new(32, 1);
        let a = Matrix::randn(50, 12, 1.0, &mut rng);
        let (q, r) = thin_qr(&a);
        assert_eq!(q.shape(), (50, 12));
        assert_eq!(r.shape(), (12, 12));
        assert!(q.tdot(&q).rel_err(&Matrix::eye(12)) < 1e-4);
        assert!(q.dot(&r).rel_err(&a) < 1e-4);
    }

    #[test]
    fn handles_rank_deficient() {
        // Two identical columns.
        let mut rng = Pcg64::new(33, 1);
        let c = Matrix::randn(10, 1, 1.0, &mut rng);
        let mut a = Matrix::zeros(10, 2);
        for i in 0..10 {
            *a.at_mut(i, 0) = c.at(i, 0);
            *a.at_mut(i, 1) = c.at(i, 0);
        }
        let (q, r) = householder_qr(&a);
        assert!(q.dot(&r).rel_err(&a) < 1e-4);
    }
}
