//! Experiment harness: one driver per paper table/figure (DESIGN.md §4).
//!
//! `odlri exp <id>` regenerates the artifact into `results/<id>.{md,csv}`.
//! Matrix-level experiments (table1, figs, table8) run on synthetic
//! outlier-planted problems by default (`--trained` switches to the trained
//! tiny model); model-level tables train/calibrate each family once and
//! cache the result under `runs/`.

mod matrix_level;
mod model_level;

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::runtime::XlaRuntime;

pub struct ExpContext<'a> {
    pub args: &'a Args,
    pub results: PathBuf,
    pub runs: PathBuf,
    /// Reduced iteration/sweep counts for smoke runs.
    pub quick: bool,
    pub seed: u64,
}

impl<'a> ExpContext<'a> {
    pub fn new(args: &'a Args) -> Result<ExpContext<'a>> {
        let ctx = ExpContext {
            args,
            results: PathBuf::from(args.str("results", "results")),
            runs: PathBuf::from(args.str("runs", "runs")),
            quick: args.switch("quick"),
            seed: args.u64("seed", 0)?,
        };
        std::fs::create_dir_all(&ctx.results)?;
        std::fs::create_dir_all(&ctx.runs)?;
        Ok(ctx)
    }

    pub fn outer_iters(&self) -> usize {
        if self.quick {
            5
        } else {
            15
        }
    }

    pub fn open_runtime(&self) -> Result<XlaRuntime> {
        let dir = {
            let d = self.args.str("artifacts", "");
            if d.is_empty() {
                crate::runtime::default_artifact_dir()
            } else {
                PathBuf::from(d)
            }
        };
        XlaRuntime::open(&dir).context(
            "experiments need the AOT artifacts; run `make artifacts` first",
        )
    }
}

/// Run one experiment (or `all`).
pub fn run(id: &str, args: &Args) -> Result<()> {
    let ctx = ExpContext::new(args)?;
    match id {
        "table1" => matrix_level::table1(&ctx),
        "t1norms" => matrix_level::t1norms(&ctx),
        "fig2" => matrix_level::fig23(&ctx, true),
        "fig3" => matrix_level::fig23(&ctx, false),
        "fig4" => matrix_level::fig45(&ctx, true),
        "fig5" => matrix_level::fig45(&ctx, false),
        "table8" => matrix_level::table8(&ctx),
        "table2" => model_level::table2(&ctx),
        "table3" => model_level::table3(&ctx),
        "table4" => model_level::table4(&ctx),
        "table5" => model_level::table5(&ctx),
        "table9" => model_level::table9(&ctx),
        "table10" => model_level::table10(&ctx),
        "table11" => model_level::table11(&ctx),
        "budget" => model_level::budget(&ctx),
        "speculate" => model_level::speculate(&ctx),
        "all" => {
            for id in [
                "table1", "t1norms", "fig2", "fig3", "fig4", "fig5", "table8",
                "table2", "table3", "table4", "table5", "table9", "table10",
                "table11", "budget", "speculate",
            ] {
                eprintln!("\n===== exp {id} =====");
                run(id, args)?;
            }
            Ok(())
        }
        other => bail!(
            "unknown experiment '{other}'; known: table1 t1norms fig2 fig3 \
             fig4 fig5 table2 table3 table4 table5 table8 table9 table10 \
             table11 budget speculate all"
        ),
    }
}
