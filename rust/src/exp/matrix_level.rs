//! Matrix-level experiments: Table 1 (+Tables 12/13), Figures 2–5, Table 8.
//!
//! These reproduce the paper's per-matrix analyses. By default they run on
//! synthetic problems with planted activation outliers (fast, deterministic,
//! and exhibiting exactly the phenomenon the paper's Llama2-7B matrices
//! show); pass `--trained` to use projections of the trained `tl-7s` model
//! with real captured Hessians instead.

use anyhow::Result;

use super::ExpContext;
use crate::calib::{synthetic_calib, synthetic_weight};
use crate::decompose::{DecompMetrics, Initializer, JointConfig, JointOptimizer};
use crate::hessian::Hessian;
use crate::lowrank::LowRankConfig;
use crate::quant::E8Lattice;
use crate::report::{SeriesSet, Table};
use crate::tensor::Matrix;
use crate::util::fnv1a;
use crate::util::rng::Pcg64;

/// A matrix-level problem instance.
pub struct Problem {
    pub label: String,
    pub w: Matrix,
    pub hessian: Hessian,
    pub outliers: Vec<usize>,
}

/// The 7 projection types in paper order.
const PROJ_TYPES: [&str; 7] = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];

/// Shape of a projection for the synthetic path (mirrors tl-7s).
fn proj_shape(proj: &str) -> (usize, usize) {
    match proj {
        "wgate" | "wup" => (352, 128),
        "wdown" => (128, 352),
        _ => (128, 128),
    }
}

/// Regime chosen to mirror the paper's Llama2-7B statistics at our scale
/// (see EXPERIMENTS.md §Calibration-regime): ~2% of channels are outliers
/// with activation norms ~6× (H diag ~36×) and slightly amplified salient
/// columns, putting the outlier share of tr(W H Wᵀ) near 60%.
fn synthetic_problem(layer: usize, proj: &str, seed: u64) -> Problem {
    let (m, n) = proj_shape(proj);
    let key = fnv1a(format!("{layer}.{proj}").as_bytes()) ^ seed;
    let n_out = (n / 48).max(2);
    let calib = synthetic_calib(n, 4 * n, n_out, 6.0, key);
    let w = synthetic_weight(m, n, &calib.outlier_channels, key ^ 0x77);
    Problem {
        label: format!("layer{layer}.{proj}"),
        w,
        hessian: calib.hessian,
        outliers: calib.outlier_channels,
    }
}

/// Fetch a problem: trained model projection (with captured Hessian) when
/// `--trained`, synthetic otherwise.
pub fn problem(ctx: &ExpContext, layer: usize, proj: &str) -> Result<Problem> {
    if !ctx.args.switch("trained") {
        return Ok(synthetic_problem(layer, proj, ctx.seed));
    }
    let rt = ctx.open_runtime()?;
    let (params, hessians) = super::model_level::ensure_model(ctx, &rt, "tl-7s")?;
    let name = format!("layer{layer}.{proj}");
    let w = params.get_matrix(&name)?;
    let hessian = hessians
        .get(&name)
        .ok_or_else(|| anyhow::anyhow!("no Hessian for {name}"))?
        .clone();
    let k = Initializer::odlri_k(32, w.cols()).max(4);
    let outliers = hessian.topk_diag(k);
    Ok(Problem {
        label: name,
        w,
        hessian,
        outliers,
    })
}

fn joint(ctx: &ExpContext, rank: usize, lr_bits: u32, seed: u64) -> JointConfig {
    JointConfig {
        outer_iters: ctx.outer_iters(),
        lowrank: LowRankConfig {
            rank,
            lr_bits,
            lplr_iters: if ctx.quick { 3 } else { 10 },
            reg: 1e-4,
        },
        hadamard: true,
        reg: 1e-4,
        seed,
    }
}

fn run_init(
    ctx: &ExpContext,
    p: &Problem,
    init: &Initializer,
    rank: usize,
    lr_bits: u32,
) -> DecompMetrics {
    let quant = E8Lattice::new(2);
    let cfg = joint(ctx, rank, lr_bits, ctx.seed ^ fnv1a(p.label.as_bytes()));
    let opt = JointOptimizer::new(&quant, cfg);
    opt.run(&p.w, &p.hessian, init).metrics
}

/// The paper's rank mapped to our scaled-down matrices. Tiny matrices have
/// far less redundancy, so the relative rank is 4× the paper's r/n (the
/// same mapping as the model-level RANK_MAP): paper 256@4096 → 32@128.
fn scaled_rank(n: usize, paper_rank: usize) -> usize {
    (n * paper_rank / 1024).max(2)
}

// ---------------------------------------------------------------- Table 1

/// Table 1: ‖QX‖ and ‖LRX‖ (normalized by ‖WX‖) at first and last
/// iteration under Zero vs LRApprox(W) initialization (layer-"1" key proj).
pub fn table1(ctx: &ExpContext) -> Result<()> {
    let p = problem(ctx, 0, "wk")?;
    let rank = scaled_rank(p.w.cols(), 256);
    let mut t = Table::new(
        "Table 1 — Effect of LR initialization in CALDERA (key proj, layer 0)",
        &["Init", "Iter", "|QX|/|WX|", "|LRX|/|WX|"],
    );
    for (init, name) in [
        (Initializer::Zero, "0"),
        (Initializer::LrApproxW, "LRApprox(W)"),
    ] {
        let m = run_init(ctx, &p, &init, rank, 16);
        let first = 1; // index 0 is the init state; paper's "first" = iter 1
        let last = m.q_norm.len() - 1;
        for (label, i) in [("First", first), ("Last", last)] {
            t.row(vec![
                name.into(),
                label.into(),
                format!("{:.3}", m.q_norm[i]),
                format!("{:.3}", m.lr_norm[i]),
            ]);
        }
    }
    t.print();
    t.save(&ctx.results, "table1")?;
    Ok(())
}

/// Tables 12/13 (App. C.4): the same trace for all 7 projection types of
/// layers 0 and 2.
pub fn t1norms(ctx: &ExpContext) -> Result<()> {
    let mut t = Table::new(
        "Tables 12–13 — LR-initialization roles across weight types (layers 0, 2)",
        &[
            "Weight", "Iter", "0: |QX|", "0: |LRX|", "LRApprox: |QX|", "LRApprox: |LRX|",
        ],
    );
    for layer in [0usize, 2] {
        for proj in PROJ_TYPES {
            let p = problem(ctx, layer, proj)?;
            let rank = scaled_rank(p.w.cols(), 256);
            let mz = run_init(ctx, &p, &Initializer::Zero, rank, 16);
            let ml = run_init(ctx, &p, &Initializer::LrApproxW, rank, 16);
            let last = mz.q_norm.len() - 1;
            for (label, i) in [("First", 1usize), ("Last", last)] {
                t.row(vec![
                    format!("L{layer}.{proj}"),
                    label.into(),
                    format!("{:.3}", mz.q_norm[i]),
                    format!("{:.3}", mz.lr_norm[i]),
                    format!("{:.3}", ml.q_norm[i]),
                    format!("{:.3}", ml.lr_norm[i]),
                ]);
            }
        }
    }
    t.print();
    t.save(&ctx.results, "t1norms")?;
    Ok(())
}

// ---------------------------------------------------------- Figures 2–5

const INITS: [(&str, fn(usize, usize) -> Initializer); 3] = [
    ("zero", |_r, _n| Initializer::Zero),
    ("lrapprox", |_r, _n| Initializer::LrApproxW),
    ("odlri", |r, n| Initializer::Odlri {
        k: Initializer::odlri_k(r, n),
    }),
];

fn figure_for(
    ctx: &ExpContext,
    layers: &[usize],
    projs: &[&str],
    scale_not_err: bool,
    stem: &str,
    title: &str,
) -> Result<()> {
    for &layer in layers {
        for proj in projs {
            let p = problem(ctx, layer, proj)?;
            let rank = scaled_rank(p.w.cols(), 256);
            let iters: Vec<f64> = (1..=ctx.outer_iters()).map(|i| i as f64).collect();
            let mut set = SeriesSet::new(
                &format!("{title} — layer{layer}.{proj} (rank {rank}, 4-bit LR)"),
                "iteration",
                iters,
            );
            for (name, mk) in INITS {
                let init = mk(rank, p.w.cols());
                let m = run_init(ctx, &p, &init, rank, 4);
                let ys: Vec<f64> = (1..m.act_err.len())
                    .map(|i| {
                        if scale_not_err {
                            m.quant_scale[i] as f64
                        } else {
                            m.act_err[i]
                        }
                    })
                    .collect();
                set.add(name, ys);
            }
            println!("{}", set.to_summary());
            set.save(&ctx.results, &format!("{stem}_l{layer}_{proj}"))?;
        }
    }
    Ok(())
}

/// Figures 2 (scale=true) and 3 (scale=false): Key/Value/Down of layer "10".
pub fn fig23(ctx: &ExpContext, scale: bool) -> Result<()> {
    let (stem, title) = if scale {
        ("fig2", "Fig 2 — Quantization scale")
    } else {
        ("fig3", "Fig 3 — Normalized activation-aware error")
    };
    figure_for(ctx, &[2], &["wk", "wv", "wdown"], scale, stem, title)
}

/// Figures 4 (scale) and 5 (error): 6 projection types, layers 0 and 3.
pub fn fig45(ctx: &ExpContext, scale: bool) -> Result<()> {
    let (stem, title) = if scale {
        ("fig4", "Fig 4 — Quantization scale")
    } else {
        ("fig5", "Fig 5 — Normalized activation-aware error")
    };
    figure_for(
        ctx,
        &[0, 3],
        &["wk", "wv", "wo", "wgate", "wup", "wdown"],
        scale,
        stem,
        title,
    )
}

// ---------------------------------------------------------------- Table 8

/// Table 8 (App. B.3): H vs H_o driving the ODLRI factorization —
/// normalized norms of LR and the residual E_LR on X_o and X_r.
pub fn table8(ctx: &ExpContext) -> Result<()> {
    let p = problem(ctx, 2, "wk")?;
    let n = p.w.cols();
    let rank = scaled_rank(n, 256);
    let k = Initializer::odlri_k(rank, n).max(p.outliers.len().min(4));
    let idx = p.hessian.topk_diag(k);
    let rest: Vec<usize> = (0..n).filter(|i| !idx.contains(i)).collect();
    let h_o = p.hessian.restricted(&idx);
    let h_r = p.hessian.restricted(&rest);

    let norm = |a: &Matrix, h: &Matrix| crate::decompose::h_norm(a, h);
    let wxo = norm(&p.w, &h_o);
    let wxr = norm(&p.w, &h_r);

    let mut t = Table::new(
        "Table 8 — Hessian selection in ODLRI (layer-2 key proj)",
        &[
            "Hessian",
            "|LRXo|/|WXo|",
            "|E_LR Xo|/|WXo|",
            "|LRXr|/|WXr|",
            "|E_LR Xr|/|WXr|",
        ],
    );
    // App. B.3 validates the *initialization*: the L₀R₀ produced by
    // whitening against H vs H_o (running the joint loop afterwards mixes
    // in the LRApprox refits and washes the comparison out — we verified
    // both protocols; the init-time one carries the paper's signature
    // ‖E_LR X_o‖ ≈ 0).
    let mut rng = Pcg64::new(ctx.seed, 0x7AB8);
    for (name, lr) in [
        (
            "H",
            crate::lowrank::whitened_svd_lr(&p.w, &p.hessian.regularized(1e-4), rank, &mut rng),
        ),
        (
            "H_o",
            crate::decompose::odlri_init(&p.w, &p.hessian, rank, k, &mut rng),
        ),
    ] {
        let prod = lr.product();
        let resid = p.w.sub(&prod);
        t.row(vec![
            name.into(),
            format!("{:.3}", norm(&prod, &h_o) / wxo),
            format!("{:.3}", norm(&resid, &h_o) / wxo),
            format!("{:.3}", norm(&prod, &h_r) / wxr),
            format!("{:.3}", norm(&resid, &h_r) / wxr),
        ]);
    }
    t.print();
    t.save(&ctx.results, "table8")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_problem_is_deterministic() {
        let a = synthetic_problem(0, "wk", 0);
        let b = synthetic_problem(0, "wk", 0);
        assert_eq!(a.w, b.w);
        assert_eq!(a.outliers, b.outliers);
        let c = synthetic_problem(1, "wk", 0);
        assert_ne!(a.w, c.w);
    }

    #[test]
    fn proj_shapes_match_family() {
        assert_eq!(proj_shape("wq"), (128, 128));
        assert_eq!(proj_shape("wgate"), (352, 128));
        assert_eq!(proj_shape("wdown"), (128, 352));
    }

    #[test]
    fn scaled_rank_mapping() {
        assert_eq!(scaled_rank(128, 256), 32);
        assert_eq!(scaled_rank(128, 64), 8);
        assert_eq!(scaled_rank(352, 256), 88);
        assert_eq!(scaled_rank(16, 64), 2); // floor
    }
}
