//! Model-level experiments: Tables 2, 3, 4, 5, 9, 10, 11.
//!
//! Each table cell = compress a trained model with one configuration and
//! evaluate (perplexity on wiki-sim/c4-sim, five zero-shot proxies).
//! Trained weights + Hessians are produced once per family and cached in
//! `runs/` — delete the files to retrain.
//!
//! Rank mapping: the paper's ranks {64, 128, 256} on 4096-dim matrices
//! correspond to r/n ∈ {1/64, 1/32, 1/16}; our d=128 families use
//! {8, 16, 32} (rows are labelled "ours (paper)").

use std::collections::BTreeMap;

use anyhow::Result;

use super::ExpContext;
use crate::calib::{calibrate, CalibConfig};
use crate::coordinator::{
    BudgetPlanner, CompressionPipeline, CompressionPlan, InitKind, PipelineConfig, Planner,
};
use crate::engine::speculative::SpeculativeEngine;
use crate::engine::{generate, NativeEngine, Sampling};
use crate::eval::{evaluate, EvalReport};
use crate::fused::FusedModel;
use crate::hessian::Hessian;
use crate::model::{inject_outliers, ModelParams};
use crate::report::Table;
use crate::runtime::XlaRuntime;
use crate::train::{train, TrainConfig};

/// Paper rank → our rank for d=128-scale families.
pub const RANK_MAP: [(usize, usize); 3] = [(64, 8), (128, 16), (256, 32)];

/// Dense-weight engine at the runtime's block shape (all table cells are
/// scored through the Engine API).
fn dense_engine(rt: &XlaRuntime, params: &ModelParams) -> Result<NativeEngine> {
    NativeEngine::new(params, rt.manifest.batch, rt.manifest.seq)
}

/// Train + outlier-inject + calibrate a family once; cache under runs/.
pub fn ensure_model(
    ctx: &ExpContext,
    rt: &XlaRuntime,
    family: &str,
) -> Result<(ModelParams, BTreeMap<String, Hessian>)> {
    let fam = rt.manifest.family(family)?.clone();
    let wpath = ctx.runs.join(format!("{family}.odw"));
    let hpath = ctx.runs.join(format!("{family}.hess"));
    if wpath.exists() && hpath.exists() {
        let params = ModelParams::load(&fam, &wpath)?;
        let hessians = load_hessians_file(&hpath)?;
        return Ok((params, hessians));
    }
    let steps = if ctx.quick { 80 } else { 150 };
    eprintln!("[ensure_model] training {family} ({steps} steps)…");
    let tr = train(
        rt,
        &TrainConfig {
            family: family.to_string(),
            steps,
            seed: ctx.seed,
            log_every: 50,
            ..Default::default()
        },
    )?;
    let mut params = tr.params;
    inject_outliers(&mut params, 4, 16.0, ctx.seed)?;
    eprintln!("[ensure_model] calibrating {family}…");
    let hessians = calibrate(
        rt,
        &params,
        &CalibConfig {
            batches: if ctx.quick { 3 } else { 8 },
            seed: ctx.seed,
        },
    )?;
    params.save(&wpath)?;
    save_hessians_file(&hessians, &hpath)?;
    // Record the loss curve for EXPERIMENTS.md.
    let curve: String = tr
        .losses
        .iter()
        .map(|(s, l)| format!("{s},{l}\n"))
        .collect();
    std::fs::write(ctx.runs.join(format!("{family}.losses.csv")), curve)?;
    Ok((params, hessians))
}

fn save_hessians_file(
    hessians: &BTreeMap<String, Hessian>,
    path: &std::path::Path,
) -> Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::File::create(path)?;
    f.write_all(&(hessians.len() as u32).to_le_bytes())?;
    for (name, h) in hessians {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        h.write_to(&mut f)?;
    }
    Ok(())
}

fn load_hessians_file(path: &std::path::Path) -> Result<BTreeMap<String, Hessian>> {
    use std::io::Read as _;
    let mut f = std::fs::File::open(path)?;
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let count = u32::from_le_bytes(b4) as usize;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        f.read_exact(&mut b4)?;
        let nlen = u32::from_le_bytes(b4) as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        out.insert(String::from_utf8(nb)?, Hessian::read_from(&mut f)?);
    }
    Ok(out)
}

/// One table cell: compress + eval. Returns (avg_bits, report).
pub fn run_cell(
    ctx: &ExpContext,
    rt: &XlaRuntime,
    params: &ModelParams,
    hessians: &BTreeMap<String, Hessian>,
    cfg: PipelineConfig,
) -> Result<(f64, EvalReport)> {
    let plan = CompressionPlan::uniform(&params.family, &cfg);
    run_cell_plan(ctx, rt, params, hessians, cfg, &plan)
}

/// One table cell under an explicit per-projection plan.
pub fn run_cell_plan(
    ctx: &ExpContext,
    rt: &XlaRuntime,
    params: &ModelParams,
    hessians: &BTreeMap<String, Hessian>,
    cfg: PipelineConfig,
    plan: &CompressionPlan,
) -> Result<(f64, EvalReport)> {
    let out = CompressionPipeline::new(cfg).run_plan(params, hessians, plan)?;
    let applied = out.model.apply_to(params)?;
    let (wins, items) = if ctx.quick { (12, 32) } else { (30, 64) };
    let rep = evaluate(&dense_engine(rt, &applied)?, wins, items, 1000)?;
    Ok((out.model.avg_bits(), rep))
}

fn base_cfg(ctx: &ExpContext) -> PipelineConfig {
    PipelineConfig {
        outer_iters: ctx.outer_iters(),
        lplr_iters: if ctx.quick { 3 } else { 10 },
        seed: ctx.seed,
        ..Default::default()
    }
}

fn fmt_tasks(rep: &EvalReport) -> Vec<String> {
    rep.tasks
        .iter()
        .map(|t| format!("{:.1}", t.accuracy * 100.0))
        .collect()
}

/// Shared engine for the PPL+accuracy tables.
#[allow(clippy::too_many_arguments)]
fn ppl_table(
    ctx: &ExpContext,
    stem: &str,
    title: &str,
    families: &[&str],
    ranks: &[(usize, usize)],
    lr_bits: u32,
    with_tasks: bool,
    extra_rows: &[InitKind],
) -> Result<()> {
    let rt = ctx.open_runtime()?;
    let mut headers = vec!["Model", "Method", "Rank", "AvgBits", "Wiki-sim", "C4-sim"];
    if with_tasks {
        headers.extend(["Wino", "RTE", "PiQA", "ArcE", "ArcC"]);
    }
    let mut t = Table::new(title, &headers.iter().map(|s| &**s).collect::<Vec<_>>());
    for family in families {
        let (params, hessians) = ensure_model(ctx, &rt, family)?;
        // FP32 reference row.
        let (wins, items) = if ctx.quick { (12, 32) } else { (30, 64) };
        let base = evaluate(&dense_engine(&rt, &params)?, wins, items, 1000)?;
        let mut row = vec![
            family.to_string(),
            "uncompressed".into(),
            "-".into(),
            "32".into(),
            format!("{:.3}", base.ppl_wiki),
            format!("{:.3}", base.ppl_c4),
        ];
        if with_tasks {
            row.extend(fmt_tasks(&base));
        }
        t.row(row);
        for &(paper_rank, our_rank) in ranks {
            let mut methods: Vec<InitKind> = vec![InitKind::Caldera, InitKind::Odlri];
            methods.extend_from_slice(extra_rows);
            for init in methods {
                let mut cfg = base_cfg(ctx);
                cfg.init = init.clone();
                cfg.rank = our_rank;
                cfg.lr_bits = lr_bits;
                let (bits, rep) = run_cell(ctx, &rt, &params, &hessians, cfg)?;
                let method = match &init {
                    InitKind::Caldera => "CALDERA".to_string(),
                    InitKind::Odlri => "+ODLRI".to_string(),
                    other => other.name(),
                };
                let mut row = vec![
                    family.to_string(),
                    method,
                    format!("{our_rank} ({paper_rank})"),
                    format!("{bits:.2}"),
                    format!("{:.3}", rep.ppl_wiki),
                    format!("{:.3}", rep.ppl_c4),
                ];
                if with_tasks {
                    row.extend(fmt_tasks(&rep));
                }
                t.row(row);
                eprintln!("  [cell] {family} {} r{our_rank} done", init.name());
            }
        }
    }
    t.print();
    t.save(&ctx.results, stem)?;
    Ok(())
}

/// Table 2: Llama2-sim families, 2-bit Q + 4-bit LR, PPL + zero-shot.
pub fn table2(ctx: &ExpContext) -> Result<()> {
    let ranks: Vec<(usize, usize)> = if ctx.quick {
        vec![(256, 32)]
    } else {
        RANK_MAP.to_vec()
    };
    ppl_table(
        ctx,
        "table2",
        "Table 2 — CALDERA vs +ODLRI on Llama2-sim (Q 2-bit E8, LR 4-bit)",
        &["tl-7s", "tl-13s"],
        &ranks,
        4,
        true,
        &[],
    )
}

/// Table 3: 16-bit LR perplexities.
pub fn table3(ctx: &ExpContext) -> Result<()> {
    let ranks: Vec<(usize, usize)> = if ctx.quick {
        vec![(256, 32)]
    } else {
        RANK_MAP.to_vec()
    };
    ppl_table(
        ctx,
        "table3",
        "Table 3 — CALDERA vs +ODLRI, LR unquantized (Q 2-bit E8, LR 16-bit)",
        &["tl-7s", "tl-13s"],
        &ranks,
        16,
        false,
        &[],
    )
}

/// Table 4: Llama3-sim and Mistral-sim generalization (4-bit LR).
pub fn table4(ctx: &ExpContext) -> Result<()> {
    let ranks: Vec<(usize, usize)> = if ctx.quick {
        vec![(256, 32)]
    } else {
        RANK_MAP.to_vec()
    };
    ppl_table(
        ctx,
        "table4",
        "Table 4 — Generalization: tl3-8s (Llama3-sim) and tm-7s (Mistral-sim)",
        &["tl3-8s", "tm-7s"],
        &ranks,
        4,
        false,
        &[],
    )
}

/// Table 5: k = r vs k < r at rank 32 (paper 256), LR 16-bit and 4-bit.
pub fn table5(ctx: &ExpContext) -> Result<()> {
    let rt = ctx.open_runtime()?;
    let (params, hessians) = ensure_model(ctx, &rt, "tl-7s")?;
    let rank = 32;
    let mut t = Table::new(
        "Table 5 — ODLRI outlier count: k = r vs k < r (rank 32, tl-7s)",
        &["ODLRI", "LR bits", "Wiki-sim", "C4-sim"],
    );
    for lr_bits in [16u32, 4] {
        for (label, k) in [
            ("H_o (k = r)", rank),
            ("H_o (k < r)", crate::decompose::Initializer::odlri_k(rank, 128)),
        ] {
            let mut cfg = base_cfg(ctx);
            cfg.init = InitKind::OdlriK(k);
            cfg.rank = rank;
            cfg.lr_bits = lr_bits;
            let (_bits, rep) = run_cell(ctx, &rt, &params, &hessians, cfg)?;
            t.row(vec![
                format!("{label} [k={k}]"),
                lr_bits.to_string(),
                format!("{:.3}", rep.ppl_wiki),
                format!("{:.3}", rep.ppl_c4),
            ]);
        }
    }
    t.print();
    t.save(&ctx.results, "table5")?;
    Ok(())
}

/// Table 9: zero-shot accuracies, LR 16-bit, plus the QuIP#-only (rank 0)
/// baseline row.
pub fn table9(ctx: &ExpContext) -> Result<()> {
    let rt = ctx.open_runtime()?;
    let ranks: Vec<(usize, usize)> = if ctx.quick {
        vec![(256, 32)]
    } else {
        RANK_MAP.to_vec()
    };
    let mut t = Table::new(
        "Table 9 — Zero-shot accuracy, LR 16-bit (plus QuIP#-only rank-0 row)",
        &["Model", "Method", "Rank", "Wino", "RTE", "PiQA", "ArcE", "ArcC"],
    );
    for family in ["tl-7s", "tl-13s"] {
        let (params, hessians) = ensure_model(ctx, &rt, family)?;
        for &(paper_rank, our_rank) in &ranks {
            for init in [InitKind::Caldera, InitKind::Odlri] {
                let mut cfg = base_cfg(ctx);
                cfg.init = init.clone();
                cfg.rank = our_rank;
                cfg.lr_bits = 16;
                let (_b, rep) = run_cell(ctx, &rt, &params, &hessians, cfg)?;
                let mut row = vec![
                    family.to_string(),
                    match init {
                        InitKind::Caldera => "CALDERA".into(),
                        _ => "+ODLRI".into(),
                    },
                    format!("{our_rank} ({paper_rank})"),
                ];
                row.extend(fmt_tasks(&rep));
                t.row(row);
            }
        }
        // QuIP# row: pure 2-bit LDLQ quantization, no low-rank component.
        let mut cfg = base_cfg(ctx);
        cfg.init = InitKind::Caldera;
        cfg.rank = 0;
        cfg.lr_bits = 16;
        cfg.outer_iters = 1;
        let (_b, rep) = run_cell(ctx, &rt, &params, &hessians, cfg)?;
        let mut row = vec![family.to_string(), "QuIP#".into(), "0".into()];
        row.extend(fmt_tasks(&rep));
        t.row(row);
    }
    t.print();
    t.save(&ctx.results, "table9")?;
    Ok(())
}

/// Table 10: extreme low ranks (paper 16/32 → ours 2/4), 4-bit LR.
pub fn table10(ctx: &ExpContext) -> Result<()> {
    ppl_table(
        ctx,
        "table10",
        "Table 10 — Extreme compression: ranks 2 (16) and 4 (32), LR 4-bit",
        &["tl-7s"],
        &[(16, 2), (32, 4)],
        4,
        true,
        &[],
    )
}

/// Table 11: MXINT 3-bit quantizer ablation on tl-7s and tg-2s (Gemma-sim),
/// LR 16-bit, ranks 4 (32) and 8 (64).
pub fn table11(ctx: &ExpContext) -> Result<()> {
    let rt = ctx.open_runtime()?;
    let mut t = Table::new(
        "Table 11 — MXINT-base vs +ODLRI (Q 3-bit MXINT b32, LR 16-bit)",
        &["Model", "Method", "Rank", "Wiki-sim PPL"],
    );
    for family in ["tl-7s", "tg-2s"] {
        let (params, hessians) = ensure_model(ctx, &rt, family)?;
        let (wins, items) = if ctx.quick { (12, 16) } else { (30, 32) };
        let base = evaluate(&dense_engine(&rt, &params)?, wins, items, 1000)?;
        t.row(vec![
            family.into(),
            "FP32".into(),
            "-".into(),
            format!("{:.3}", base.ppl_wiki),
        ]);
        for &(paper_rank, our_rank) in &[(32usize, 4usize), (64, 8)] {
            for (label, init) in [
                ("MXINT-base", InitKind::Caldera),
                ("+ODLRI", InitKind::Odlri),
            ] {
                let mut cfg = base_cfg(ctx);
                cfg.init = init;
                cfg.rank = our_rank;
                cfg.lr_bits = 16;
                cfg.q_scheme = "mxint".into();
                cfg.q_bits = 3;
                cfg.q_group = 32;
                cfg.hadamard = false; // MXINT-base applies no incoherence
                let (_b, rep) = run_cell(ctx, &rt, &params, &hessians, cfg)?;
                t.row(vec![
                    family.into(),
                    label.into(),
                    format!("{our_rank} ({paper_rank})"),
                    format!("{:.3}", rep.ppl_wiki),
                ]);
            }
        }
    }
    t.print();
    t.save(&ctx.results, "table11")?;
    Ok(())
}

/// Plan-API experiment (ours, beyond the paper): uniform recipes vs the
/// sensitivity-driven [`BudgetPlanner`] at matched average bits on tl-7s.
/// The budget rows reuse the uniform rows' measured avg-bits as their
/// ceilings, so each pair compares equal-size models where only the
/// per-projection allocation differs.
pub fn budget(ctx: &ExpContext) -> Result<()> {
    let rt = ctx.open_runtime()?;
    let (params, hessians) = ensure_model(ctx, &rt, "tl-7s")?;
    let fam = params.family.clone();
    let base = {
        let mut c = base_cfg(ctx);
        c.rank = 16;
        c.lr_bits = 4;
        c
    };
    let mut t = Table::new(
        "Budget planning — uniform vs per-projection plans (tl-7s, Q e8 + LR 4-bit)",
        &["Plan", "AvgBits", "Ranks", "QBits", "Wiki-sim", "C4-sim"],
    );
    let mut budgets = Vec::new();
    let uniform_ranks: &[usize] = if ctx.quick { &[16] } else { &[8, 16] };
    for &rank in uniform_ranks {
        let mut cfg = base.clone();
        cfg.rank = rank;
        let plan = CompressionPlan::uniform(&fam, &cfg);
        let (bits, rep) = run_cell_plan(ctx, &rt, &params, &hessians, cfg, &plan)?;
        t.row(vec![
            format!("uniform r{rank}"),
            format!("{bits:.3}"),
            plan.rank_label(),
            plan.bits_label(),
            format!("{:.3}", rep.ppl_wiki),
            format!("{:.3}", rep.ppl_c4),
        ]);
        budgets.push(bits);
        eprintln!("  [cell] uniform r{rank} done ({bits:.3} bits)");
    }
    for budget in budgets {
        let planner = BudgetPlanner::new(budget, base.clone());
        let plan = planner.plan(&params, &hessians)?;
        let (ranks, qbits) = (plan.rank_label(), plan.bits_label());
        let (bits, rep) = run_cell_plan(ctx, &rt, &params, &hessians, base.clone(), &plan)?;
        t.row(vec![
            planner.name(),
            format!("{bits:.3}"),
            ranks,
            qbits,
            format!("{:.3}", rep.ppl_wiki),
            format!("{:.3}", rep.ppl_c4),
        ]);
        eprintln!("  [cell] {} done ({bits:.3} bits)", planner.name());
    }
    t.print();
    t.save(&ctx.results, "budget")?;
    Ok(())
}

/// Speculative-decoding experiment (ours, beyond the paper): draft-bits ×
/// k acceptance rate and ms/tok on tl-7s. The target is a 4-bit uniform
/// pack of the trained weights; drafts are packed from the same dense
/// weights at decreasing bit widths — the paper's claim that ODLRI keeps
/// low-bit Q accurate shows up here as acceptance rate. Every cell's token
/// stream is asserted bit-identical to plain target-only greedy decoding
/// before its timing is reported.
pub fn speculate(ctx: &ExpContext) -> Result<()> {
    let rt = ctx.open_runtime()?;
    let (params, _hessians) = ensure_model(ctx, &rt, "tl-7s")?;
    let b = rt.manifest.batch;
    let pack = |bits: u32| -> Result<FusedModel> {
        Ok(FusedModel::pack_dense(&params, "uniform", bits, 64)?.with_shape(b, 256))
    };
    let prompt_len = 32usize;
    let data = crate::corpus::generate(crate::corpus::Split::WikiSim, prompt_len + 1024, ctx.seed);
    let prompt: Vec<i32> = data[..prompt_len].iter().map(|&x| x as i32).collect();
    let max_new = if ctx.quick { 24 } else { 64 };
    let target = pack(4)?;
    let plain = generate(&target, &prompt, max_new, Sampling::Greedy)?;
    let plain_secs: f64 = plain.step_latencies_s.iter().sum();
    let plain_ms = plain_secs * 1e3 / plain.tokens.len().saturating_sub(1).max(1) as f64;
    let mut t = Table::new(
        "Speculative decoding — draft bits × k (tl-7s, 4-bit uniform target, greedy)",
        &[
            "DraftBits", "k", "Accept%", "DraftSteps", "VerifySteps", "ms/tok", "PlainMsTok",
            "Speedup",
        ],
    );
    let ks: &[usize] = if ctx.quick { &[2, 4] } else { &[1, 2, 4, 8] };
    let draft_bits: &[u32] = if ctx.quick { &[2] } else { &[2, 3, 4] };
    for &bits in draft_bits {
        for &k in ks {
            let spec = SpeculativeEngine::new(Box::new(pack(bits)?), Box::new(pack(4)?), k)?;
            let out = spec.generate(&prompt, max_new)?;
            anyhow::ensure!(
                out.gen.tokens == plain.tokens,
                "speculative stream diverged from plain greedy at draft {bits}b k={k}"
            );
            let c = out.counters;
            let secs: f64 = out.gen.step_latencies_s.iter().sum();
            let ms = secs * 1e3 / out.gen.tokens.len().saturating_sub(1).max(1) as f64;
            t.row(vec![
                format!("{bits}"),
                format!("{k}"),
                format!("{:.1}", c.acceptance_rate() * 100.0),
                format!("{}", c.draft_steps),
                format!("{}", c.verify_steps),
                format!("{ms:.3}"),
                format!("{plain_ms:.3}"),
                format!("{:.2}x", if ms > 0.0 { plain_ms / ms } else { 0.0 }),
            ]);
            eprintln!(
                "  [cell] draft {bits}b k={k}: acceptance {:.1}%, {} verify steps",
                c.acceptance_rate() * 100.0,
                c.verify_steps
            );
        }
    }
    t.print();
    t.save(&ctx.results, "speculate")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_map_ratios() {
        // r/n ratio scaled consistently (4× relatively larger: tiny models
        // have far less weight redundancy than 7B ones, so the same
        // absolute ratio would starve the LR term entirely).
        for (paper, ours) in RANK_MAP {
            let paper_ratio = paper as f64 / 4096.0;
            let our_ratio = ours as f64 / 128.0;
            assert!((our_ratio / paper_ratio - 4.0).abs() < 0.01);
        }
    }
}
