//! Markdown/CSV table emitters for the experiment harness.
//!
//! Every `odlri exp <id>` driver produces a [`Table`] (paper-style rows) or
//! a [`Series`] set (figure curves) and writes them under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// A markdown table with a caption.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<w$} |", c, w = widths[i]);
            }
            s
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }

    pub fn save(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// A set of named (x, y) series — one CSV with an x column plus one column
/// per series (the figure-reproduction format).
#[derive(Clone, Debug)]
pub struct SeriesSet {
    pub title: String,
    pub x_label: String,
    pub x: Vec<f64>,
    pub series: Vec<(String, Vec<f64>)>,
}

impl SeriesSet {
    pub fn new(title: &str, x_label: &str, x: Vec<f64>) -> SeriesSet {
        SeriesSet {
            title: title.to_string(),
            x_label: x_label.to_string(),
            x,
            series: Vec::new(),
        }
    }

    pub fn add(&mut self, name: &str, y: Vec<f64>) -> &mut Self {
        assert_eq!(y.len(), self.x.len(), "series length mismatch");
        self.series.push((name.to_string(), y));
        self
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let names: Vec<&str> = self.series.iter().map(|(n, _)| n.as_str()).collect();
        let _ = writeln!(out, "{},{}", self.x_label, names.join(","));
        for (i, &xv) in self.x.iter().enumerate() {
            let ys: Vec<String> = self
                .series
                .iter()
                .map(|(_, y)| format!("{:.6e}", y[i]))
                .collect();
            let _ = writeln!(out, "{xv},{}", ys.join(","));
        }
        out
    }

    /// Render a compact ASCII view (min→max per series) so figure shapes can
    /// be eyeballed in the terminal / EXPERIMENTS.md.
    pub fn to_summary(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {} (x = {})\n", self.title, self.x_label);
        for (name, y) in &self.series {
            let first = y.first().copied().unwrap_or(f64::NAN);
            let last = y.last().copied().unwrap_or(f64::NAN);
            let min = y.iter().cloned().fold(f64::INFINITY, f64::min);
            let _ = writeln!(
                out,
                "- {:<24} first={:.4e} last={:.4e} min={:.4e}",
                name, first, last, min
            );
        }
        out
    }

    pub fn save(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_summary())?;
        Ok(())
    }
}

/// Format a float like the paper's tables (2 decimals).
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a probability/accuracy as percent with 2 decimals.
pub fn pct(v: f64) -> String {
    format!("{:.2}", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("Demo", &["Method", "PPL"]);
        t.row(vec!["CALDERA".into(), "7.34".into()]);
        t.row(vec!["+ODLRI".into(), "7.20".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["hello, world".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"hello, world\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn series_csv() {
        let mut s = SeriesSet::new("fig", "iter", vec![1.0, 2.0, 3.0]);
        s.add("odlri", vec![0.3, 0.2, 0.1]);
        s.add("zero", vec![0.5, 0.4, 0.35]);
        let csv = s.to_csv();
        assert!(csv.starts_with("iter,odlri,zero"));
        assert_eq!(csv.lines().count(), 4);
    }
}
