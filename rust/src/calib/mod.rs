//! Calibration: per-projection Hessians from real model activations
//! (via the `capture_<family>` artifact) or from synthetic outlier-planted
//! activations (matrix-level experiments).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::corpus::{self, Split};
use crate::hessian::Hessian;
use crate::model::ModelParams;
use crate::runtime::{Value, XlaRuntime};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Which activation capture feeds which projection matrix.
/// Per layer the capture artifact emits (attn_in, attn_ctx, mlp_in,
/// mlp_mid); q/k/v share attn_in, gate/up share mlp_in.
fn capture_consumers(layer: usize) -> [(usize, Vec<String>); 4] {
    let p = format!("layer{layer}.");
    [
        (0, vec![format!("{p}wq"), format!("{p}wk"), format!("{p}wv")]),
        (1, vec![format!("{p}wo")]),
        (2, vec![format!("{p}wgate"), format!("{p}wup")]),
        (3, vec![format!("{p}wdown")]),
    ]
}

#[derive(Clone, Debug)]
pub struct CalibConfig {
    /// Number of capture batches to stream.
    pub batches: usize,
    pub seed: u64,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig {
            batches: 8,
            seed: 0,
        }
    }
}

/// Run calibration through the model: returns one [`Hessian`] per
/// projection matrix (q/k/v share the same accumulated H, as they share
/// inputs — same as real LLM pipelines).
pub fn calibrate(
    rt: &XlaRuntime,
    params: &ModelParams,
    cfg: &CalibConfig,
) -> Result<BTreeMap<String, Hessian>> {
    let fam = &params.family;
    let artifact = format!("capture_{}", fam.name);
    rt.warm(&artifact)?;
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let data = corpus::generate(Split::Train, 200_000, cfg.seed ^ 0xCA11B);
    let mut rng = Pcg64::new(cfg.seed, 0xCA11B);

    let mut hessians: BTreeMap<String, Hessian> = BTreeMap::new();
    for _ in 0..cfg.batches {
        let tokens = corpus::sample_batch(&data, batch, seq, &mut rng);
        let mut inputs = params.values.clone();
        inputs.push(Value::from_vec_i32(vec![batch, seq], tokens));
        let outs = rt.exec(&artifact, &inputs)?;
        debug_assert_eq!(outs.len(), 4 * fam.n_layers);
        for layer in 0..fam.n_layers {
            for (slot, consumers) in capture_consumers(layer) {
                let x = outs[4 * layer + slot].to_matrix()?;
                for name in consumers {
                    hessians
                        .entry(name)
                        .or_insert_with(|| Hessian::zeros(x.rows()))
                        .accumulate(&x);
                }
            }
        }
    }
    Ok(hessians)
}

/// Synthetic calibration for matrix-level experiments (Table 1, Figs 2–5 on
/// standalone matrices): heavy-tailed activations with `n_outliers` planted
/// outlier channels boosted by `boost`.
pub struct SyntheticCalib {
    pub x: Matrix,
    pub hessian: Hessian,
    pub outlier_channels: Vec<usize>,
}

pub fn synthetic_calib(
    n: usize,
    samples: usize,
    n_outliers: usize,
    boost: f32,
    seed: u64,
) -> SyntheticCalib {
    let mut rng = Pcg64::new(seed, 0x5CA1);
    let mut x = Matrix::randn(n, samples, 1.0, &mut rng);
    let idx = rng.sample_indices(n, n_outliers);
    for &c in &idx {
        x.scale_row(c, boost * rng.uniform_in(0.75, 1.25));
    }
    let mut sorted = idx;
    sorted.sort_unstable();
    let hessian = Hessian::from_acts(&x);
    SyntheticCalib {
        x,
        hessian,
        outlier_channels: sorted,
    }
}

/// A weight matrix with realistic structure for the matrix-level
/// experiments: base Gaussian + a mild low-rank component + *amplified*
/// salient columns on the outlier channels.
///
/// The amplification (3× RMS) puts the problem in the regime the paper's
/// Figure 2 exhibits: the salient columns both (a) interact with outlier
/// activations — so their rounding error dominates the activation-aware
/// objective — and (b) carry enough Frobenius mass to stretch the
/// quantizer's dynamic range. When ODLRI absorbs them into L₀R₀, the
/// residual handed to `Quantize` is smoother and the chosen scale drops;
/// zero-init leaves them in place and pays for it at every iteration.
pub fn synthetic_weight(
    m: usize,
    n: usize,
    outlier_channels: &[usize],
    seed: u64,
) -> Matrix {
    let mut rng = Pcg64::new(seed, 0x3E16);
    let mut w = Matrix::randn(m, n, 1.0, &mut rng);
    let l = Matrix::randn(m, 4, 0.5, &mut rng);
    let r = Matrix::randn(4, n, 0.5, &mut rng);
    w.add_assign(&l.dot(&r));
    for &c in outlier_channels {
        w.scale_col(c, 3.0);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_calib_plants_outliers() {
        let c = synthetic_calib(64, 256, 4, 20.0, 9);
        assert_eq!(c.outlier_channels.len(), 4);
        let top = c.hessian.topk_diag(4);
        assert_eq!(top, c.outlier_channels);
        assert_eq!(c.x.shape(), (64, 256));
    }

    #[test]
    fn synthetic_weight_has_amplified_salient_columns() {
        let ch = vec![3usize, 17];
        let w = synthetic_weight(32, 48, &ch, 5);
        let col_norm = |j: usize| -> f32 {
            w.col(j).iter().map(|v| v * v).sum::<f32>().sqrt()
        };
        let salient = (col_norm(3) + col_norm(17)) / 2.0;
        let normal: f32 = (0..48)
            .filter(|j| !ch.contains(j))
            .map(col_norm)
            .sum::<f32>()
            / 46.0;
        assert!(salient > normal * 2.0, "salient={salient} normal={normal}");
    }

    #[test]
    fn capture_consumer_map_covers_all_projections() {
        let mut names: Vec<String> = Vec::new();
        for layer in 0..3 {
            for (_, consumers) in capture_consumers(layer) {
                names.extend(consumers);
            }
        }
        assert_eq!(names.len(), 21);
        assert!(names.contains(&"layer2.wdown".to_string()));
        let unique: std::collections::BTreeSet<_> = names.iter().collect();
        assert_eq!(unique.len(), names.len());
    }
}
