//! Native fused `(Q + L·R)·x` inference engine over **packed** weights —
//! the serving hot path.
//!
//! The compression pipeline produces `W ≈ Q + L·R`, where `Q` is a low-bit
//! quantized matrix and `L·R` a skinny low-rank correction. The historical
//! eval path called `CompressedMatrix::reconstruct()`, densifying every
//! layer to f32 before any matmul — which throws away the entire memory
//! and bandwidth win at inference time. This module keeps the structure on
//! the hot path:
//!
//! * [`FusedQlrMatrix`] holds `Q` as a [`PackedMatrix`] in the quantizer's
//!   **native** code layout plus the `L`/`R` factors, and computes
//!   `y = Q·x + L·(R·x)` with blocked, multithreaded kernels that
//!   dequantize `Q` **on the fly**, one row/panel at a time — the full
//!   dense `Q + L·R` is never materialized.
//! * [`FusedModel`] is a whole compressed transformer in that form: dense
//!   embeddings/norms plus one `FusedQlrMatrix` per projection, driving the
//!   shared native forward ([`crate::runtime::native::forward_with`]).
//! * [`qlr_matmul`]/[`qlr_matmul_t`] are the dense-`Q` fused helpers used
//!   by the `kernel_fused_qlr` and `fwd_fused_*` artifact semantics.
//!
//! ## Numerical contract (scheme-exact `Q`)
//!
//! The container stores each quantizer's native codes — uniform b-bit
//! grid codes, E8 lattice coordinates + global scale, MXINT mantissas +
//! shared block exponents — encoded under the *same frozen scales* the
//! quantizer rounded with, so `fm.q.unpack()` reproduces the pipeline's
//! `Q` **bit-exactly** (`max_abs_diff == 0`; property-tested per scheme
//! below). There is no "repack at 8 bits with headroom" fallback: `--fused`
//! eval measures the decomposition ODLRI actually optimized. When the
//! pipeline ran with Hadamard incoherence processing (the default), the
//! codes stay in the rotated basis and carry the sign diagonals; the
//! kernels fold the rotation into the skinny activations
//! (`Q·x = D_m H_m (Q̃ · (H_n D_n x))`) so decoding stays dense-free, while
//! `unpack()`/`reconstruct()` replay the exact un-rotation. Every fused
//! kernel matches the dense `reconstruct()`-then-matmul reference within
//! 1e-4 relative error.
//!
//! ## Container format (v3)
//!
//! ```text
//! .odf model container   magic ODF3 (reads ODF2/ODF1)
//!   family name, batch, seq
//!   dense section: non-projection params only
//!   packed section, per projection:
//!     name, MatrixPlan metadata (init, rank, lr_bits, scheme, bits,
//!     group, hadamard — see `coordinator::MatrixPlan::write_to`),
//!     fused matrix
//! fused matrix           magic ODQ2 (reads ODQ1)
//!   PackedMatrix (ODP2/ODP1 — see `quant::packed` for the per-scheme
//!   layouts), then L and R as dense f32 matrices
//! ```
//!
//! v3 adds the per-projection plan metadata so a deployed container
//! documents the (possibly heterogeneous) recipe it was compressed under;
//! ODF2/ODF1 streams still read, with each matrix mapped to a uniform-style
//! plan synthesized from its own observable shape/scheme/rotation.
//! Version bumps change the magic; readers stay backward compatible.
//! Footprint reporting (`byte_size`/`bits_per_weight`/`avg_bits`) is
//! derived from the actual serialized length, so it cannot drift from the
//! on-disk format.
//!
//! ## Decode kernels (specialized vs panel) and dispatch rules
//!
//! Two kernel families serve `X·(Q+LR)ᵀ`:
//!
//! * **Panel** ([`FusedQlrMatrix::matmul_t`]) — blocks of `Q` rows are
//!   decoded to an f32 panel (through the word-level unpackers of
//!   [`crate::quant::PackedMatrix::dequant_row_fast_into`], bit-identical
//!   to the reference decoder) and multiplied with the cache-blocked
//!   `matmul_nt`. Best when there are many activation rows to amortize the
//!   panel (prefill, scoring forwards).
//! * **Decode** ([`FusedQlrMatrix::decode_matmul_t`] /
//!   [`FusedQlrMatrix::matvec`]) — per-token generation's hot path. Each
//!   `Q` row's integer codes are extracted once per call and every output
//!   element is one group-hoisted fused dequant-dot
//!   ([`crate::quant::PackedMatrix::dot_row_codes`]): no decoded row
//!   buffer, no per-element scale lookup, no per-element zero branch, no
//!   `Matrix` round-trip for single vectors.
//!
//! [`FusedModel`]'s `project` dispatches on the activation row count:
//! calls with at most `max_batch` (= `self.batch`) rows — every
//! scheduler decode step by construction — take the decode kernel; larger
//! calls take the panel kernel. The choice depends only on the row count,
//! and each decode-kernel output element depends only on its own
//! activation row, so per-session decode output is independent of batch
//! composition (the continuous-batching invariant). The two kernels agree
//! to f32 rounding (summation order differs); process-wide counters
//! ([`decode_kernel_calls`] / [`panel_kernel_calls`]) let smoke tests and
//! the CLI assert the specialized path is actually taken.
//!
//! Threading reuses [`crate::exec::parallel_map`] over output-row blocks
//! and the panel/blocking idiom of [`crate::tensor::matmul`].

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::{InitKind, MatrixPlan};
use crate::engine::{Engine, EngineSpec, Session};
use crate::exec;
use crate::lowrank::LrPair;
use crate::model::{CompressedModel, ModelParams};
use crate::quant::{PackedMatrix, PackedScheme};
use crate::runtime::kvpool::{KvPool, PoolStats, DEFAULT_PAGE_TOKENS};
use crate::runtime::native::{
    forward_with, fwd_decode, fwd_prefill, fwd_prefill_chunk, KvCache, ParamView, ProjectionOps,
};
use crate::runtime::{FamilySpec, Value, NATIVE_BATCH, NATIVE_SEQ};
use crate::tensor::{axpy, dotp, matmul_nt, Matrix};

/// Process-wide tallies of which `X·(Q+LR)ᵀ` kernel ran: the decode-regime
/// fused dequant-dot ([`FusedQlrMatrix::decode_matmul_t`] / [`FusedQlrMatrix::matvec`])
/// vs the blocked panel kernel ([`FusedQlrMatrix::matmul_t`]). Cheap relaxed
/// counters so smoke tests and the CLI can assert the specialized decode
/// path is actually taken instead of silently falling back.
static DECODE_DOT_CALLS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
static PANEL_CALLS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Calls answered by the specialized decode kernel since process start.
pub fn decode_kernel_calls() -> u64 {
    DECODE_DOT_CALLS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Calls answered by the blocked panel kernel since process start.
pub fn panel_kernel_calls() -> u64 {
    PANEL_CALLS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Dense-`Q` fused product `(Q + L·R)·X` — two skinny matmuls instead of a
/// dense `Q + L·R` materialization. `x` is (in, cols).
pub fn qlr_matmul(q: &Matrix, l: &Matrix, r: &Matrix, x: &Matrix) -> Matrix {
    let mut y = q.dot(x);
    if l.cols() > 0 {
        y.add_assign(&l.dot(&r.dot(x)));
    }
    y
}

/// Dense-`Q` fused product `X·(Q + L·R)ᵀ = X·Qᵀ + (X·Rᵀ)·Lᵀ` for
/// activations `x` of shape (tokens, in).
pub fn qlr_matmul_t(x: &Matrix, q: &Matrix, l: &Matrix, r: &Matrix) -> Matrix {
    let mut y = matmul_nt(x, q);
    if l.cols() > 0 {
        let xr = matmul_nt(x, r); // (tokens, rank)
        y.add_assign(&matmul_nt(&xr, l)); // (tokens, out)
    }
    y
}

fn fused_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// A compressed projection kept in deployment form: packed `Q` plus `L`/`R`.
#[derive(Clone, Debug, PartialEq)]
pub struct FusedQlrMatrix {
    pub q: PackedMatrix,
    pub l: Matrix,
    pub r: Matrix,
}

impl FusedQlrMatrix {
    pub fn new(q: PackedMatrix, lr: LrPair) -> Result<FusedQlrMatrix> {
        if lr.l.rows() != q.rows || lr.r.cols() != q.cols || lr.l.cols() != lr.r.rows() {
            bail!(
                "fused factor shapes L{:?} R{:?} incompatible with Q {}x{}",
                lr.l.shape(),
                lr.r.shape(),
                q.rows,
                q.cols
            );
        }
        Ok(FusedQlrMatrix {
            q,
            l: lr.l,
            r: lr.r,
        })
    }

    pub fn out_dim(&self) -> usize {
        self.q.rows
    }

    pub fn in_dim(&self) -> usize {
        self.q.cols
    }

    pub fn rank(&self) -> usize {
        self.l.cols()
    }

    /// Dense `Q + L·R` (tests/debugging only — the kernels never call this).
    /// `Q` decodes bit-exactly, so this matches the pipeline's
    /// `CompressedMatrix::reconstruct()` with zero error.
    pub fn reconstruct(&self) -> Matrix {
        let mut w = self.q.unpack();
        if self.rank() > 0 {
            w.add_assign(&self.l.dot(&self.r));
        }
        w
    }

    /// Serialized footprint in bytes — measured by serializing into a
    /// counting sink, so it is the on-disk size by construction and cannot
    /// drift from the format.
    pub fn byte_size(&self) -> usize {
        let mut count = crate::quant::ByteCount(0);
        self.write_to(&mut count)
            // lint:allow(hot-path-panic) ByteCount's Write impl never errors; write_to has no other failure source
            .expect("counting writer is infallible");
        count.0
    }

    /// Effective bits per weight of the deployment form.
    pub fn bits_per_weight(&self) -> f64 {
        self.byte_size() as f64 * 8.0 / (self.q.rows * self.q.cols) as f64
    }

    /// `y = (Q + L·R)·X` for `x` of shape (in, cols): blocked over output
    /// rows, each block dequantizing its `Q` rows on the fly. Rotated codes
    /// fold the Hadamard transform into the skinny activations
    /// (`Q·x = D_m H_m (Q̃ · (H_n D_n x))`) — never into a dense `Q`.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        let (m, n) = (self.q.rows, self.q.cols);
        assert_eq!(x.rows(), n, "fused matmul inner dims");
        let cols = x.cols();
        let rotated_x;
        let xq: &Matrix = match &self.q.rotation {
            Some(rot) => {
                rotated_x = rot.rotate_acts(x);
                &rotated_x
            }
            None => x,
        };
        let mut out = Matrix::zeros(m, cols);
        let nblocks = self.row_blocks(cols);
        let block = m.div_ceil(nblocks);
        let blocks: Vec<(usize, Matrix)> = exec::parallel_map(nblocks, fused_workers(), |bi| {
            let r0 = (bi * block).min(m);
            let r1 = ((bi + 1) * block).min(m);
            let mut part = Matrix::zeros(r1 - r0, cols);
            let mut wrow = vec![0f32; n];
            let mut qcodes: Vec<i32> = Vec::new();
            for i in r0..r1 {
                self.q.dequant_row_fast_into(i, &mut qcodes, &mut wrow);
                let orow = part.row_mut(i - r0);
                for (j, &wv) in wrow.iter().enumerate() {
                    if wv != 0.0 {
                        axpy(wv, xq.row(j), orow);
                    }
                }
            }
            (r0, part)
        });
        for (r0, part) in blocks {
            for i in 0..part.rows() {
                out.row_mut(r0 + i).copy_from_slice(part.row(i));
            }
        }
        if let Some(rot) = &self.q.rotation {
            out = rot.unrotate_out(&out);
        }
        if self.rank() > 0 {
            let rx = self.r.dot(x); // (rank, cols) — factors live unrotated
            out.add_assign(&self.l.dot(&rx));
        }
        out
    }

    /// `y = X·(Q + L·R)ᵀ` for activations `x` of shape (tokens, in) — the
    /// transformer layout. Blocked over output columns: each block decodes
    /// a panel of `Q` rows (word-level fast decode, bit-identical to the
    /// reference) and reuses the cache-blocked [`matmul_nt`].
    /// Rotated codes: `X·Qᵀ = ((X D_n) H_n · Q̃ᵀ) H_m D_m`.
    pub fn matmul_t(&self, x: &Matrix) -> Matrix {
        let (m, n) = (self.q.rows, self.q.cols);
        assert_eq!(x.cols(), n, "fused matmul_t inner dims");
        PANEL_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let t = x.rows();
        let rotated_x;
        let xq: &Matrix = match &self.q.rotation {
            Some(rot) => {
                rotated_x = rot.rotate_acts_t(x);
                &rotated_x
            }
            None => x,
        };
        let mut out = Matrix::zeros(t, m);
        let nblocks = self.row_blocks(t);
        let block = m.div_ceil(nblocks);
        let blocks: Vec<(usize, Matrix)> = exec::parallel_map(nblocks, fused_workers(), |bi| {
            let r0 = (bi * block).min(m);
            let r1 = ((bi + 1) * block).min(m);
            let mut panel = Matrix::zeros(r1 - r0, n);
            let mut qcodes: Vec<i32> = Vec::new();
            for i in r0..r1 {
                self.q.dequant_row_fast_into(i, &mut qcodes, panel.row_mut(i - r0));
            }
            (r0, matmul_nt(xq, &panel)) // (t, r1-r0)
        });
        for (c0, part) in blocks {
            for i in 0..t {
                out.row_mut(i)[c0..c0 + part.cols()].copy_from_slice(part.row(i));
            }
        }
        if let Some(rot) = &self.q.rotation {
            out = rot.unrotate_out_t(&out);
        }
        if self.rank() > 0 {
            let xr = matmul_nt(x, &self.r); // (t, rank)
            out.add_assign(&matmul_nt(&xr, &self.l));
        }
        out
    }

    /// Decode-regime kernel: `y = X·(Q + L·R)ᵀ` for a **small** number of
    /// activation rows — a decode step's batch of sessions. Each `Q` row's
    /// integer codes are extracted once per call (word-level unpackers) and
    /// every output element is one group-hoisted fused dequant-dot
    /// ([`PackedMatrix::dot_row_codes`]): no decoded panel, no per-element
    /// scale lookup, no per-element zero branch. Row-local by construction
    /// — `out[t][i]` depends only on activation row `t` — so a session's
    /// logits are independent of which other sessions share the step (the
    /// batch-composition invariance continuous batching relies on).
    pub fn decode_matmul_t(&self, x: &Matrix) -> Matrix {
        let (m, n) = (self.q.rows, self.q.cols);
        assert_eq!(x.cols(), n, "fused decode_matmul_t inner dims");
        DECODE_DOT_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let t = x.rows();
        if t == 0 {
            return Matrix::zeros(0, m);
        }
        let rotated_x;
        let xq: &Matrix = match &self.q.rotation {
            Some(rot) => {
                rotated_x = rot.rotate_acts_t(x);
                &rotated_x
            }
            None => x,
        };
        let nblocks = self.row_blocks(t);
        let block = m.div_ceil(nblocks);
        // Per block: (first Q row, row-major (q_row, act_row) dot results).
        let blocks: Vec<(usize, Vec<f32>)> = exec::parallel_map(nblocks, fused_workers(), |bi| {
            let r0 = (bi * block).min(m);
            let r1 = ((bi + 1) * block).min(m);
            let mut part = vec![0f32; (r1 - r0) * t];
            let mut qcodes: Vec<i32> = Vec::new();
            for i in r0..r1 {
                self.q.load_row_codes(i, &mut qcodes);
                for (ti, slot) in part[(i - r0) * t..(i - r0 + 1) * t].iter_mut().enumerate() {
                    *slot = self.q.dot_row_codes(i, &qcodes, xq.row(ti));
                }
            }
            (r0, part)
        });
        let mut out = Matrix::zeros(t, m);
        for (r0, part) in blocks {
            for (ri, chunk) in part.chunks(t).enumerate() {
                for (ti, &v) in chunk.iter().enumerate() {
                    *out.at_mut(ti, r0 + ri) = v;
                }
            }
        }
        if let Some(rot) = &self.q.rotation {
            out = rot.unrotate_out_t(&out);
        }
        if self.rank() > 0 {
            let xr = matmul_nt(x, &self.r); // (t, rank)
            out.add_assign(&matmul_nt(&xr, &self.l));
        }
        out
    }

    /// `y = (Q + L·R)·x` for a single vector — the slice form of the decode
    /// kernel: no `Matrix` round-trip, each output element one fused
    /// dequant-dot. Matches [`FusedQlrMatrix::decode_matmul_t`] on a 1-row
    /// matrix exactly (same per-element op sequence; tested).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let (m, n) = (self.q.rows, self.q.cols);
        assert_eq!(x.len(), n, "fused matvec inner dims");
        DECODE_DOT_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let rotated_x;
        let xq: &[f32] = match &self.q.rotation {
            Some(rot) => {
                rotated_x = rot.rotate_vec(x);
                &rotated_x
            }
            None => x,
        };
        let mut y = vec![0f32; m];
        let mut qcodes: Vec<i32> = Vec::new();
        for (i, slot) in y.iter_mut().enumerate() {
            self.q.load_row_codes(i, &mut qcodes);
            *slot = self.q.dot_row_codes(i, &qcodes, xq);
        }
        if let Some(rot) = &self.q.rotation {
            rot.unrotate_vec(&mut y);
        }
        if self.rank() > 0 {
            let mut rx = vec![0f32; self.rank()];
            for (k, slot) in rx.iter_mut().enumerate() {
                *slot = dotp(self.r.row(k), x);
            }
            for (i, slot) in y.iter_mut().enumerate() {
                *slot += dotp(self.l.row(i), &rx);
            }
        }
        y
    }

    /// Block count heuristic: parallelize only when the decode+FMA work is
    /// worth the thread fan-out (mirrors `tensor::matmul`'s threshold).
    fn row_blocks(&self, cols: usize) -> usize {
        let work = 2 * self.q.rows * self.q.cols * cols.max(1);
        if work < 4_000_000 {
            1
        } else {
            (fused_workers() * 4).min(self.q.rows.max(1))
        }
    }

    // ---- serialization ----

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(b"ODQ2")?;
        self.q.write_to(w)?;
        self.l.write_to(w)?;
        self.r.write_to(w)?;
        Ok(())
    }

    pub fn read_from(r: &mut impl Read) -> Result<FusedQlrMatrix> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"ODQ2" && &magic != b"ODQ1" {
            bail!("bad fused-matrix magic {magic:?}");
        }
        let q = PackedMatrix::read_from(r)?;
        let l = Matrix::read_from(r)?;
        let rm = Matrix::read_from(r)?;
        FusedQlrMatrix::new(q, LrPair { l, r: rm })
    }
}

/// A whole compressed model in deployment form: dense embed/norms/unembed
/// plus one packed fused projection per compressible matrix. Implements
/// [`ProjectionOps`] (native forward) and [`crate::engine::Engine`]
/// (scoring, perplexity/task eval, and KV-cached incremental generation
/// serving) — `reconstruct()` is never on the inference path.
pub struct FusedModel {
    pub family: FamilySpec,
    /// Uncompressed non-projection parameters (embed/norms/unembed);
    /// projection slots are zeroed — the fused forward never reads them and
    /// the `.odf` container never stores them.
    dense: ModelParams,
    /// `dense` resolved to matrices once, so serving batches borrow instead
    /// of re-copying every parameter per forward.
    dense_mats: Vec<Matrix>,
    pub mats: BTreeMap<String, FusedQlrMatrix>,
    /// Per-projection recipe metadata (carried in the ODF3 container;
    /// synthesized from the matrices themselves for ODF2/ODF1 reads and
    /// `pack_dense`). Purely documentary — the kernels read only `mats`.
    pub plans: BTreeMap<String, MatrixPlan>,
    pub batch: usize,
    pub seq: usize,
    /// Paged KV pool all generation sessions draw from (prefix sharing +
    /// hard byte budget — see [`crate::runtime::kvpool`]).
    pool: KvPool,
    /// True once `with_kv_budget` pinned an explicit budget.
    explicit_budget: bool,
}

/// Hard cap on any name-length field read from a fused container. Real
/// family/param/matrix names are tens of bytes; a length beyond this is a
/// corrupt count and must error before it sizes an allocation.
pub const MAX_NAME_BYTES: usize = 4096;

/// Hard cap on a dense param's rank read from a fused container (real
/// shapes are 1-D/2-D; 8 leaves headroom without admitting a 4-billion
/// iteration dim-read loop from one flipped bit).
pub const MAX_TENSOR_DIMS: usize = 8;

fn checked_name_len(raw: u32, what: &str) -> Result<usize> {
    let n = raw as usize;
    if n > MAX_NAME_BYTES {
        bail!(
            "fused container: {what} length {n} exceeds the {MAX_NAME_BYTES}-byte \
             cap — corrupt count field"
        );
    }
    Ok(n)
}

/// The uniform-style plan an ODF2/ODF1 matrix (or a `pack_dense` one) maps
/// to: everything observable comes from the matrix itself (realized rank,
/// packed scheme/bits/group, rotation); the init is unknown so it records
/// the pipeline default, and factors are stored f32 so `lr_bits` is 16.
fn synthesized_plan(fm: &FusedQlrMatrix) -> MatrixPlan {
    let (scheme, bits, group) = match &fm.q.scheme {
        PackedScheme::Uniform {
            bits, group_size, ..
        } => ("uniform", *bits, *group_size),
        PackedScheme::E8 { bits, .. } => ("e8", *bits, 64),
        PackedScheme::MxInt { bits, block, .. } => ("mxint", *bits, *block),
    };
    MatrixPlan {
        init: InitKind::Odlri,
        rank: fm.rank(),
        lr_bits: 16,
        q_scheme: scheme.into(),
        q_bits: bits,
        q_group: group.max(1),
        hadamard: fm.q.rotation.is_some(),
    }
}

impl FusedModel {
    /// Build the deployment container: replace the projection slots of the
    /// dense params with **empty** placeholders (the fused forward reads
    /// projections only from the packed `mats`, so no dense projection
    /// memory stays resident) and resolve the rest to matrices once.
    fn assemble(
        family: FamilySpec,
        base: &ModelParams,
        mats: BTreeMap<String, FusedQlrMatrix>,
        plans: BTreeMap<String, MatrixPlan>,
    ) -> Result<FusedModel> {
        let mut dense = base.clone();
        for name in &family.projections {
            let idx = family.param_index(name)?;
            dense.values[idx] = Value::from_vec_f32(vec![0], Vec::new());
        }
        let dense_mats = dense
            .values
            .iter()
            .map(|v| v.to_matrix())
            .collect::<Result<Vec<_>>>()?;
        for name in mats.keys() {
            if !plans.contains_key(name) {
                bail!("fused model is missing plan metadata for '{name}'");
            }
        }
        let pool = KvPool::with_default_budget(
            family.n_layers,
            family.kv_dim(),
            4 * NATIVE_SEQ,
            NATIVE_BATCH,
        );
        Ok(FusedModel {
            family,
            dense,
            dense_mats,
            mats,
            plans,
            batch: NATIVE_BATCH,
            seq: NATIVE_SEQ,
            pool,
            explicit_budget: false,
        })
    }

    /// Deployment form of a pipeline result: every projection's `Q` carried
    /// as the quantizer's native codes (scheme-exact — no re-quantization),
    /// factors kept skinny, plan metadata riding along (with the realized
    /// rank, which may be below the requested one on small matrices).
    pub fn from_compressed(model: &CompressedModel, base: &ModelParams) -> Result<FusedModel> {
        if base.family.name != model.family.name {
            bail!(
                "compressed model family '{}' != params family '{}'",
                model.family.name,
                base.family.name
            );
        }
        let mut mats = BTreeMap::new();
        let mut plans = BTreeMap::new();
        for (name, cm) in &model.matrices {
            mats.insert(name.clone(), cm.to_fused()?);
            plans.insert(
                name.clone(),
                MatrixPlan {
                    rank: cm.rank(),
                    ..cm.plan.clone()
                },
            );
        }
        FusedModel::assemble(model.family.clone(), base, mats, plans)
    }

    /// Quantize an *uncompressed* model's projections directly with any
    /// scheme (`"uniform"`/`"e8"`/`"mxint"`, rank-0 factors) and pack the
    /// native codes — fused serving without a compression run. Uniform at
    /// 8 bits is near-lossless.
    pub fn pack_dense(
        base: &ModelParams,
        scheme: &str,
        bits: u32,
        group: usize,
    ) -> Result<FusedModel> {
        let quant = crate::quant::make_quantizer(scheme, bits, group)?;
        let fam = base.family.clone();
        let mut mats = BTreeMap::new();
        let mut plans = BTreeMap::new();
        for name in &fam.projections {
            let w = base.get_matrix(name)?;
            let out = quant.quantize(&w);
            let lr = LrPair::zeros(w.rows(), w.cols(), 0);
            let fm = FusedQlrMatrix::new(out.packed, lr)?;
            plans.insert(
                name.clone(),
                MatrixPlan {
                    init: InitKind::Caldera,
                    ..synthesized_plan(&fm)
                },
            );
            mats.insert(name.clone(), fm);
        }
        FusedModel::assemble(fam, base, mats, plans)
    }

    /// Override the forward block shape (defaults mirror the artifacts).
    /// Re-derives the default KV pool budget for the new shape unless one
    /// was pinned via [`with_kv_budget`](FusedModel::with_kv_budget).
    pub fn with_shape(mut self, batch: usize, seq: usize) -> FusedModel {
        self.batch = batch;
        self.seq = seq;
        if !self.explicit_budget {
            self.pool = KvPool::with_default_budget(
                self.family.n_layers,
                self.family.kv_dim(),
                4 * seq.max(1),
                batch,
            );
        }
        self
    }

    /// Pin a hard KV pool byte budget (the `--kv-budget` knob); call after
    /// `with_shape`. Errors if the budget holds less than one page.
    pub fn with_kv_budget(mut self, bytes: usize) -> Result<FusedModel> {
        self.pool = KvPool::new(
            self.family.n_layers,
            self.family.kv_dim(),
            DEFAULT_PAGE_TOKENS,
            bytes,
        )?;
        self.explicit_budget = true;
        Ok(self)
    }

    /// The paged KV pool this model's sessions draw from. Replica fleets
    /// use pool identity ([`KvPool::ptr_eq`]) to map a session's cache
    /// back to the shard hosting it (failover needs to know which
    /// sessions a quarantined shard orphans).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// A replica of this model for shard-parallel serving: identical
    /// packed weights and shape, but a **fresh, private** KV pool of the
    /// same geometry and budget. Replication is nearly free in the
    /// paper's regime — the packed `Q + L·R` weights are a few bits per
    /// parameter — and identical weights make decode on any replica
    /// bit-identical, so a session's output never depends on which shard
    /// hosts it.
    pub fn fork_replica(&self) -> FusedModel {
        let pool = KvPool::new(
            self.family.n_layers,
            self.family.kv_dim(),
            self.pool.page_tokens(),
            self.pool.budget_bytes(),
        )
        // lint:allow(hot-path-panic) self.pool was built from this exact geometry/budget, which KvPool::new already accepted
        .expect("existing pool geometry always holds a page");
        FusedModel {
            family: self.family.clone(),
            dense: self.dense.clone(),
            dense_mats: self.dense_mats.clone(),
            mats: self.mats.clone(),
            plans: self.plans.clone(),
            batch: self.batch,
            seq: self.seq,
            pool,
            explicit_budget: self.explicit_budget,
        }
    }

    /// Logits for a row-major (batch, seq) token block → (batch·seq, vocab).
    pub fn forward(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<Matrix> {
        let view = ParamView::from_slice(&self.family, &self.dense_mats)?;
        forward_with(&self.family, &view, self, tokens, batch, seq, None)
    }

    /// Total deployment footprint of the packed projections.
    pub fn packed_bytes(&self) -> usize {
        self.mats.values().map(|m| m.byte_size()).sum()
    }

    /// Serialized bytes of the packed `Q` payloads alone (codes + scales,
    /// excluding the f32 factors) — the weight stream every decode step
    /// re-reads, so `packed_q_bytes / step_seconds` is the decode weight
    /// throughput the CLI reports.
    pub fn packed_q_bytes(&self) -> usize {
        self.mats.values().map(|m| m.q.byte_size()).sum()
    }

    /// Mean bits/weight across the packed projections.
    pub fn avg_bits(&self) -> f64 {
        let mut bits = 0.0;
        let mut weights = 0.0;
        for m in self.mats.values() {
            bits += m.byte_size() as f64 * 8.0;
            weights += (m.q.rows * m.q.cols) as f64;
        }
        if weights == 0.0 {
            0.0
        } else {
            bits / weights
        }
    }

    /// Per-scheme projection counts for logs, e.g. `"e8+rot×7"`.
    pub fn scheme_summary(&self) -> String {
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for m in self.mats.values() {
            *counts.entry(m.q.scheme_name()).or_insert(0) += 1;
        }
        counts
            .into_iter()
            .map(|(k, v)| format!("{k}×{v}"))
            .collect::<Vec<_>>()
            .join(", ")
    }

    // ---- serialization (`.odf` container) ----

    /// Serialize the v3 container (header, dense section, then per
    /// projection: name + plan metadata + packed matrix).
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        self.write_container(w, true)
    }

    /// Legacy v2 container writer (no per-matrix plan metadata) — kept so
    /// the ODF2 read path stays regression-tested against real v2 bytes.
    pub fn write_to_v2(&self, w: &mut impl Write) -> Result<()> {
        self.write_container(w, false)
    }

    fn write_container(&self, w: &mut impl Write, v3: bool) -> Result<()> {
        w.write_all(if v3 { b"ODF3" } else { b"ODF2" })?;
        let nb = self.family.name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(self.batch as u32).to_le_bytes())?;
        w.write_all(&(self.seq as u32).to_le_bytes())?;
        // Dense section: only the non-projection params — the projections
        // live exclusively in packed form, so the container is genuinely
        // small.
        let keep: Vec<usize> = (0..self.family.params.len())
            .filter(|&i| !self.family.projections.contains(&self.family.params[i].0))
            .collect();
        w.write_all(&(keep.len() as u32).to_le_bytes())?;
        for &i in &keep {
            let (pname, shape) = &self.family.params[i];
            let nb = pname.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            for &x in self.dense.values[i].f32_data()? {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        w.write_all(&(self.mats.len() as u32).to_le_bytes())?;
        for (name, m) in &self.mats {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            if v3 {
                self.plans
                    .get(name)
                    .ok_or_else(|| anyhow::anyhow!("no plan metadata for '{name}'"))?
                    .write_to(w)?;
            }
            m.write_to(w)?;
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        self.write_to(&mut f)
    }

    /// Read a v3/v2/v1 container. v2/v1 matrices get a synthesized
    /// uniform-style plan (observable fields from the matrix itself).
    ///
    /// Length fields read from the stream are range-checked *before* they
    /// size an allocation ([`MAX_NAME_BYTES`], [`MAX_TENSOR_DIMS`]): a
    /// corrupt count must surface as a ranged error, not an allocation
    /// bomb or a multi-gigabyte read loop.
    pub fn read_from(family: &FamilySpec, f: &mut impl Read) -> Result<FusedModel> {
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        let v3 = &magic == b"ODF3";
        if !v3 && &magic != b"ODF2" && &magic != b"ODF1" {
            bail!("bad fused-model magic {magic:?}");
        }
        let mut b4 = [0u8; 4];
        let mut next_u32 = |f: &mut dyn Read| -> Result<u32> {
            f.read_exact(&mut b4)?;
            Ok(u32::from_le_bytes(b4))
        };
        let nlen = checked_name_len(next_u32(f)?, "family name")?;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        if name != family.name {
            bail!("fused model is for family '{name}', expected '{}'", family.name);
        }
        let batch = next_u32(f)? as usize;
        let seq = next_u32(f)? as usize;
        // Dense section: empty placeholders for projection slots (never
        // read — no transient dense-model allocation), zero-init for the
        // rest, then fill the stored params.
        let mut values: Vec<Value> = family
            .params
            .iter()
            .map(|(pname, sh)| {
                if family.projections.contains(pname) {
                    Value::from_vec_f32(vec![0], Vec::new())
                } else {
                    Value::from_vec_f32(sh.clone(), vec![0.0; sh.iter().product()])
                }
            })
            .collect();
        let mut filled = vec![false; family.params.len()];
        let ndense = next_u32(f)? as usize;
        for _ in 0..ndense {
            let nlen = checked_name_len(next_u32(f)?, "dense param name")?;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let pname = String::from_utf8(nb)?;
            let ndim = next_u32(f)? as usize;
            if ndim > MAX_TENSOR_DIMS {
                bail!(
                    "fused container: dense param '{pname}' claims {ndim} dims \
                     (cap {MAX_TENSOR_DIMS}) — corrupt count field"
                );
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(next_u32(f)? as usize);
            }
            let idx = family.param_index(&pname)?;
            if dims != family.params[idx].1 {
                bail!("fused container shape mismatch for '{pname}'");
            }
            let count: usize = dims.iter().product();
            let mut buf = vec![0u8; count * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            values[idx] = Value::from_vec_f32(dims, data);
            filled[idx] = true;
        }
        // A structurally-valid but truncated container must not load into a
        // silently-garbage model: every non-projection param is required.
        for (i, (pname, _)) in family.params.iter().enumerate() {
            if !family.projections.contains(pname) && !filled[i] {
                bail!("fused container is missing dense param '{pname}'");
            }
        }
        let dense = ModelParams {
            family: family.clone(),
            values,
        };
        let count = next_u32(f)? as usize;
        let mut mats = BTreeMap::new();
        let mut plans = BTreeMap::new();
        for _ in 0..count {
            let nlen = checked_name_len(next_u32(f)?, "matrix name")?;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let mname = String::from_utf8(nb)?;
            let plan = if v3 { Some(MatrixPlan::read_from(f)?) } else { None };
            let fm = FusedQlrMatrix::read_from(f)?;
            let shape = family.param_shape(&mname)?;
            if shape != &[fm.out_dim(), fm.in_dim()][..] {
                bail!("fused matrix '{mname}' shape mismatch");
            }
            let plan = match plan {
                Some(p) => {
                    // Every plan field the codes can contradict is checked:
                    // a corrupt or hand-edited container must not load into
                    // a model whose plan table misdescribes what is served.
                    // (`q_group` is excluded: packers clamp it to the
                    // column count, so the stored group legitimately
                    // differs from the requested one.)
                    let synth = synthesized_plan(&fm);
                    if p.hadamard != synth.hadamard {
                        bail!(
                            "fused matrix '{mname}': plan hadamard={} but codes are {}",
                            p.hadamard,
                            if synth.hadamard { "rotated" } else { "unrotated" }
                        );
                    }
                    if p.q_scheme != synth.q_scheme || p.q_bits != synth.q_bits {
                        bail!(
                            "fused matrix '{mname}': plan says {}x{}b but codes are {}x{}b",
                            p.q_scheme,
                            p.q_bits,
                            synth.q_scheme,
                            synth.q_bits
                        );
                    }
                    if p.rank != fm.rank() {
                        bail!(
                            "fused matrix '{mname}': plan rank {} but factors are rank {}",
                            p.rank,
                            fm.rank()
                        );
                    }
                    p
                }
                None => synthesized_plan(&fm),
            };
            plans.insert(mname.clone(), plan);
            mats.insert(mname, fm);
        }
        for pname in &family.projections {
            if !mats.contains_key(pname) {
                bail!("fused container is missing packed projection '{pname}'");
            }
        }
        let loaded = FusedModel::assemble(family.clone(), &dense, mats, plans)?;
        Ok(FusedModel {
            batch,
            seq,
            ..loaded
        })
    }

    pub fn load(family: &FamilySpec, path: &Path) -> Result<FusedModel> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        FusedModel::read_from(family, &mut f)
    }
}

impl ProjectionOps for FusedModel {
    fn project(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        match self.mats.get(name) {
            // Decode-regime dispatch: a decode step carries at most
            // `max_batch` (= self.batch) session rows, so any call this
            // small routes through the fused dequant-dot kernel; larger
            // calls (prefill, scoring) amortize a decoded panel instead.
            // The choice depends only on the row count — never on which
            // sessions share the step — so per-session decode output stays
            // independent of batch composition.
            Some(m) if x.rows() <= self.batch => Ok(m.decode_matmul_t(x)),
            Some(m) => Ok(m.matmul_t(x)),
            None => bail!("no fused projection '{name}'"),
        }
    }
}

/// Projection provider for *chunked* prefill: the kernel regime is pinned
/// by the **full prompt's** row count, not the chunk's. One-shot prefill
/// dispatches on `prompt_len` rows; a chunk of the same prompt may carry
/// fewer rows and would fall into the decode-kernel regime, whose
/// summation order differs from the panel kernel's at f32 rounding. Both
/// kernels are exactly row-local, so pinning the regime makes every
/// chunking produce bit-identical K/V rows and logits to the one-shot
/// path — the chunked-prefill contract.
struct ChunkProj<'a> {
    fm: &'a FusedModel,
    decode_regime: bool,
}

impl ProjectionOps for ChunkProj<'_> {
    fn project(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        match self.fm.mats.get(name) {
            Some(m) if self.decode_regime => Ok(m.decode_matmul_t(x)),
            Some(m) => Ok(m.matmul_t(x)),
            None => bail!("no fused projection '{name}'"),
        }
    }
}

/// The packed deployment form serves the full generation-first API: every
/// projection of scoring, prefill, *and* per-token decode goes through the
/// dequant-on-the-fly fused kernels — no dense `W` is ever materialized on
/// any serving path.
impl Engine for FusedModel {
    fn spec(&self) -> EngineSpec {
        EngineSpec {
            vocab: self.family.vocab,
            max_batch: self.batch,
            seq: self.seq,
            max_context: 4 * self.seq,
            kv_budget: self.pool.budget_bytes(),
        }
    }

    fn forward_batch(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<Matrix> {
        self.forward(tokens, batch, seq)
    }

    fn decode_weight_bytes(&self) -> Option<usize> {
        Some(self.packed_q_bytes())
    }

    fn prefill(&self, tokens: &[i32]) -> Result<(Session, Matrix)> {
        let view = ParamView::from_slice(&self.family, &self.dense_mats)?;
        // Same paged-session protocol as NativeEngine: adopt a registered
        // identical prefix (storage only — logits keep the full-forward
        // bit-identity), then publish this prompt's pages.
        let mut cache = KvCache::paged(&self.pool, 4 * self.seq);
        cache.adopt_prefix(tokens);
        let logits = fwd_prefill(&self.family, &view, self, tokens, &mut cache)?;
        cache.register_prefix(tokens);
        Ok((Session::new(tokens.to_vec(), cache), logits))
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(
        &self,
        prompt: &[i32],
        state: &mut Option<KvCache>,
        upto: usize,
    ) -> Result<Matrix> {
        let view = ParamView::from_slice(&self.family, &self.dense_mats)?;
        let cache = state.get_or_insert_with(|| {
            let mut c = KvCache::paged(&self.pool, 4 * self.seq);
            c.adopt_prefix(prompt);
            c
        });
        let done = cache.len();
        if upto <= done || upto > prompt.len() {
            bail!(
                "prefill chunk target {upto} outside ({done}, {}]",
                prompt.len()
            );
        }
        // Pin the kernel regime to what one-shot prefill over the whole
        // prompt would dispatch (see [`ChunkProj`]) so any chunking stays
        // bit-identical to `prefill`.
        let proj = ChunkProj {
            fm: self,
            decode_regime: prompt.len() <= self.batch,
        };
        let logits =
            fwd_prefill_chunk(&self.family, &view, &proj, &prompt[done..upto], cache)?;
        if upto == prompt.len() {
            cache.register_prefix(prompt);
        }
        Ok(logits)
    }

    fn decode_step(&self, sessions: &mut [&mut Session], tokens: &[i32]) -> Result<Matrix> {
        if sessions.len() != tokens.len() {
            bail!(
                "decode step: {} tokens for {} sessions",
                tokens.len(),
                sessions.len()
            );
        }
        let view = ParamView::from_slice(&self.family, &self.dense_mats)?;
        let logits = {
            let mut caches: Vec<&mut KvCache> =
                sessions.iter_mut().map(|s| &mut s.cache).collect();
            fwd_decode(&self.family, &view, self, tokens, &mut caches)?
        };
        for (s, &t) in sessions.iter_mut().zip(tokens) {
            s.tokens.push(t);
        }
        Ok(logits)
    }

    fn verify_step(&self, session: &mut Session, tokens: &[i32]) -> Result<Matrix> {
        if tokens.is_empty() {
            bail!("verify step needs at least one token");
        }
        let view = ParamView::from_slice(&self.family, &self.dense_mats)?;
        // One chunked causal forward, pinned to the *decode* kernel
        // regime: sequential decode steps always carry one row per
        // session and hence dispatch to `decode_matmul_t`, whose f32
        // summation order differs from the panel kernel's. Both kernels
        // are exactly row-local, so with the regime pinned each verify
        // row is bit-identical to the decode step that would have fed
        // the same token — the speculative accept/reject comparison
        // never sees kernel-induced drift.
        let proj = ChunkProj {
            fm: self,
            decode_regime: true,
        };
        let logits = fwd_prefill_chunk(&self.family, &view, &proj, tokens, &mut session.cache)?;
        session.tokens.extend_from_slice(tokens);
        Ok(logits)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.pool.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{Initializer, JointConfig, JointOptimizer};
    use crate::hadamard::Incoherence;
    use crate::hessian::Hessian;
    use crate::lowrank::{svd_lr, LowRankConfig};
    use crate::model::CompressedMatrix;
    use crate::quant::{make_quantizer, Quantizer as _, UniformQuantizer};
    use crate::testing;
    use crate::util::rng::Pcg64;

    /// A plausible plan record for hand-built test matrices.
    fn test_plan(scheme: &str, rank: usize, bits: u32, group: usize, hadamard: bool) -> MatrixPlan {
        MatrixPlan {
            init: InitKind::Caldera,
            rank,
            lr_bits: 16,
            q_scheme: scheme.into(),
            q_bits: bits,
            q_group: group,
            hadamard,
        }
    }

    /// Quantize → factorize-residual → pack the quantizer's native codes,
    /// returning both the pipeline's dense `CompressedMatrix` and the
    /// scheme-exact packed fused form.
    fn random_compressed(
        rng: &mut Pcg64,
        scheme: &str,
        m: usize,
        n: usize,
        rank: usize,
        bits: u32,
        group: usize,
    ) -> (CompressedMatrix, FusedQlrMatrix) {
        let w = testing::gen_matrix(rng, m, n);
        let quant = make_quantizer(scheme, bits, group).unwrap();
        let qout = quant.quantize(&w);
        let lr = if rank == 0 {
            LrPair::zeros(m, n, 0)
        } else {
            let resid = w.sub(&qout.deq);
            svd_lr(&resid, rank.min(m).min(n), rng)
        };
        let cm = CompressedMatrix {
            q: qout.deq,
            q_packed: qout.packed,
            lr,
            quant_scale: qout.scale,
            final_act_err: 0.0,
            plan: test_plan(scheme, rank, bits, group, false),
            q_bits_overhead: quant.bits_with_overhead(m, n),
        };
        let fm = cm.to_fused().unwrap();
        (cm, fm)
    }

    #[test]
    fn fused_kernels_match_dense_reconstruct_per_quantizer() {
        testing::quick("fused-vs-dense", |rng| {
            let m = testing::gen_dim(rng, 4, 48);
            let n = testing::gen_dim(rng, 4, 48);
            let rank = rng.below(5); // 0..=4
            let scheme = ["uniform", "e8", "mxint"][rng.below(3)];
            let bits = 2 + rng.below(3) as u32;
            let group = [8usize, 16, 32][rng.below(3)];
            let (cm, fm) = random_compressed(rng, scheme, m, n, rank, bits, group);
            // Scheme-exact storage: the packed container decodes the
            // pipeline's Q and reconstruction with ZERO error.
            assert_eq!(
                fm.q.unpack().max_abs_diff(&cm.q),
                0.0,
                "{scheme} packed Q not bit-exact"
            );
            assert_eq!(
                fm.reconstruct().max_abs_diff(&cm.reconstruct()),
                0.0,
                "{scheme} fused reconstruct diverged from compressed"
            );
            let dense = fm.reconstruct();
            let cols = 1 + rng.below(12);
            let x = testing::gen_matrix(rng, n, cols);

            let fused = fm.matmul(&x);
            let reference = dense.dot(&x);
            assert!(
                fused.rel_err(&reference) < 1e-4,
                "{scheme} matmul rel err {}",
                fused.rel_err(&reference)
            );

            let xt = testing::gen_matrix(rng, cols, n);
            let fused_t = fm.matmul_t(&xt);
            let reference_t = matmul_nt(&xt, &dense);
            assert!(
                fused_t.rel_err(&reference_t) < 1e-4,
                "{scheme} matmul_t rel err {}",
                fused_t.rel_err(&reference_t)
            );
        });
    }

    #[test]
    fn uniform_packing_is_exact_end_to_end() {
        // For the uniform quantizer the packed container carries the
        // quantizer's own codes and frozen scales: the fused path
        // reproduces the pipeline's Q with zero error (no scale-recompute
        // rounding — the old 1e-5 tolerance is gone for good).
        testing::quick("fused-uniform-exact", |rng| {
            let m = testing::gen_dim(rng, 4, 40);
            let n = testing::gen_dim(rng, 4, 40);
            let bits = 2 + rng.below(3) as u32;
            let group = [8usize, 32][rng.below(2)];
            let rank = rng.below(4);
            let w = testing::gen_matrix(rng, m, n);
            let quant = UniformQuantizer::new(bits, group);
            let qout = quant.quantize(&w);
            let lr = if rank == 0 {
                LrPair::zeros(m, n, 0)
            } else {
                svd_lr(&w.sub(&qout.deq), rank.min(m).min(n), rng)
            };
            let cm = CompressedMatrix {
                q: qout.deq,
                q_packed: qout.packed,
                lr,
                quant_scale: qout.scale,
                final_act_err: 0.0,
                plan: test_plan("uniform", rank, bits, group, false),
                q_bits_overhead: quant.bits_with_overhead(m, n),
            };
            let fm = cm.to_fused().unwrap();
            assert_eq!(
                fm.q.unpack().max_abs_diff(&cm.q),
                0.0,
                "uniform pack not bit-exact"
            );
            let x = testing::gen_matrix(rng, n, 1 + rng.below(8));
            let fused = fm.matmul(&x);
            let reference = cm.reconstruct().dot(&x);
            assert!(
                fused.rel_err(&reference) < 1e-4,
                "rel err {}",
                fused.rel_err(&reference)
            );
        });
    }

    #[test]
    fn rotated_codes_kernels_match_dense() {
        // Incoherence-rotated codes (the LDLQ + Hadamard deployment case):
        // unpack is bit-exact against the pipeline's un-rotation, and both
        // kernels fold the rotation into the activations correctly.
        testing::quick("fused-rotated", |rng| {
            let m = testing::gen_dim(rng, 4, 32);
            let n = testing::gen_dim(rng, 4, 32);
            let scheme = ["uniform", "e8", "mxint"][rng.below(3)];
            let rank = rng.below(4);
            let w = testing::gen_matrix(rng, m, n);
            let inc = Incoherence::new(m, n, rng);
            let quant = make_quantizer(scheme, 3, 8).unwrap();
            let qout = quant.quantize(&inc.apply(&w));
            let q_orig = inc.unapply(&qout.deq);
            let packed = qout
                .packed
                .with_rotation(inc.left_signs.clone(), inc.right_signs.clone());
            let lr = if rank == 0 {
                LrPair::zeros(m, n, 0)
            } else {
                svd_lr(&w.sub(&q_orig), rank.min(m).min(n), rng)
            };
            let fm = FusedQlrMatrix::new(packed, lr).unwrap();
            assert_eq!(
                fm.q.unpack().max_abs_diff(&q_orig),
                0.0,
                "{scheme} rotated decode not bit-exact"
            );
            let dense = fm.reconstruct();
            let x = testing::gen_matrix(rng, n, 1 + rng.below(6));
            assert!(
                fm.matmul(&x).rel_err(&dense.dot(&x)) < 1e-4,
                "{scheme} rotated matmul"
            );
            let xt = testing::gen_matrix(rng, 1 + rng.below(6), n);
            assert!(
                fm.matmul_t(&xt).rel_err(&matmul_nt(&xt, &dense)) < 1e-4,
                "{scheme} rotated matmul_t"
            );
        });
    }

    #[test]
    fn ldlq_rotated_pipeline_is_served_exactly() {
        // Full-pipeline parity: run the joint optimizer (LDLQ + Hadamard
        // incoherence) per scheme and assert the fused container serves the
        // exact decomposition it produced — reconstruction error 0, kernel
        // error < 1e-4.
        let mut rng = Pcg64::new(40, 1);
        for scheme in ["uniform", "e8", "mxint"] {
            let w = Matrix::randn(20, 32, 1.0, &mut rng);
            let acts = Matrix::randn(32, 48, 1.0, &mut rng);
            let hess = Hessian::from_acts(&acts);
            let quant = make_quantizer(scheme, 2, 8).unwrap();
            let cfg = JointConfig {
                outer_iters: 2,
                hadamard: true,
                lowrank: LowRankConfig {
                    rank: 4,
                    lr_bits: 16,
                    ..Default::default()
                },
                ..Default::default()
            };
            let d = JointOptimizer::new(quant.as_ref(), cfg).run(&w, &hess, &Initializer::Zero);
            let cm = CompressedMatrix {
                q: d.q.clone(),
                q_packed: d.q_packed.clone(),
                lr: d.lr.clone(),
                quant_scale: 0.0,
                final_act_err: 0.0,
                plan: test_plan(scheme, 4, 2, 8, true),
                q_bits_overhead: quant.bits_with_overhead(20, 32),
            };
            let fm = cm.to_fused().unwrap();
            assert!(fm.q.rotation.is_some(), "{scheme}: rotation metadata lost");
            assert_eq!(
                fm.reconstruct().max_abs_diff(&cm.reconstruct()),
                0.0,
                "{scheme}: fused serving is not the optimized decomposition"
            );
            let x = Matrix::randn(32, 5, 1.0, &mut rng);
            assert!(
                fm.matmul(&x).rel_err(&cm.reconstruct().dot(&x)) < 1e-4,
                "{scheme}: rotated kernel diverged"
            );
        }
    }

    #[test]
    fn dense_qlr_helpers_match_materialized() {
        testing::quick("qlr-dense-helpers", |rng| {
            let m = testing::gen_dim(rng, 2, 32);
            let n = testing::gen_dim(rng, 2, 32);
            let rank = rng.below(5);
            let q = testing::gen_matrix(rng, m, n);
            let l = Matrix::randn(m, rank, 1.0, rng);
            let r = Matrix::randn(rank, n, 1.0, rng);
            let w = if rank > 0 { q.add(&l.dot(&r)) } else { q.clone() };
            let x = testing::gen_matrix(rng, n, 1 + rng.below(6));
            assert!(qlr_matmul(&q, &l, &r, &x).rel_err(&w.dot(&x)) < 1e-4);
            let xt = testing::gen_matrix(rng, 1 + rng.below(6), n);
            assert!(qlr_matmul_t(&xt, &q, &l, &r).rel_err(&matmul_nt(&xt, &w)) < 1e-4);
        });
    }

    #[test]
    fn matvec_matches_matmul_column() {
        // The decode kernel's group-hoisted summation order differs from
        // the blocked matmul's, so agreement is to f32 rounding.
        let mut rng = Pcg64::new(31, 1);
        let (_cm, fm) = random_compressed(&mut rng, "uniform", 24, 16, 3, 4, 8);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) * 0.25 - 2.0).collect();
        let y = fm.matvec(&x);
        let xm = Matrix::from_vec(16, 1, x);
        let ym = fm.matmul(&xm);
        assert_eq!(y.len(), 24);
        for i in 0..24 {
            let tol = 1e-4 * ym.at(i, 0).abs().max(1.0);
            assert!((y[i] - ym.at(i, 0)).abs() < tol, "row {i}");
        }
    }

    #[test]
    fn decode_kernel_matches_panel_kernel_per_scheme() {
        // The specialized decode kernel and the blocked panel kernel are
        // the same linear map computed in different summation orders —
        // agreement to f32 rounding across schemes, ranks, and rotation.
        testing::quick("decode-vs-panel", |rng| {
            let m = testing::gen_dim(rng, 4, 40);
            let n = testing::gen_dim(rng, 4, 40);
            let scheme = ["uniform", "e8", "mxint"][rng.below(3)];
            let bits = 2 + rng.below(3) as u32;
            let rank = rng.below(4);
            let fm = if rng.below(2) == 1 {
                // Hadamard-rotated codes: the decode kernel must fold the
                // rotation into the activations exactly like the panel one.
                let w = testing::gen_matrix(rng, m, n);
                let inc = Incoherence::new(m, n, rng);
                let qout = make_quantizer(scheme, bits, 8).unwrap().quantize(&inc.apply(&w));
                let packed = qout
                    .packed
                    .with_rotation(inc.left_signs.clone(), inc.right_signs.clone());
                let lr = if rank == 0 {
                    LrPair::zeros(m, n, 0)
                } else {
                    svd_lr(&w.sub(&inc.unapply(&qout.deq)), rank.min(m).min(n), rng)
                };
                FusedQlrMatrix::new(packed, lr).unwrap()
            } else {
                random_compressed(rng, scheme, m, n, rank, bits, 8).1
            };
            let t = 1 + rng.below(4);
            let x = testing::gen_matrix(rng, t, n);
            let fast = fm.decode_matmul_t(&x);
            let panel = fm.matmul_t(&x);
            assert!(
                fast.rel_err(&panel) < 1e-4,
                "{scheme}@{bits}b rel err {}",
                fast.rel_err(&panel)
            );
        });
    }

    #[test]
    fn decode_kernel_is_row_local() {
        // The batch-composition invariance continuous batching relies on:
        // a row decoded inside a batch produces **exactly** the output it
        // produces alone, and the single-vector matvec is the same kernel.
        testing::quick("decode-row-local", |rng| {
            let m = testing::gen_dim(rng, 4, 32);
            let n = testing::gen_dim(rng, 4, 32);
            let scheme = ["uniform", "e8", "mxint"][rng.below(3)];
            let rank = rng.below(3);
            let (_cm, fm) = random_compressed(rng, scheme, m, n, rank, 3, 8);
            let t = 2 + rng.below(3);
            let x = testing::gen_matrix(rng, t, n);
            let batched = fm.decode_matmul_t(&x);
            for ti in 0..t {
                let solo = fm.decode_matmul_t(&Matrix::from_vec(1, n, x.row(ti).to_vec()));
                assert_eq!(
                    solo.row(0),
                    batched.row(ti),
                    "{scheme} row {ti} depends on batch composition"
                );
                let vec_out = fm.matvec(x.row(ti));
                assert_eq!(&vec_out[..], batched.row(ti), "{scheme} matvec diverged");
            }
        });
    }

    #[test]
    fn decode_kernel_counters_tick() {
        let mut rng = Pcg64::new(35, 1);
        let (_cm, fm) = random_compressed(&mut rng, "uniform", 12, 10, 2, 4, 8);
        let x = Matrix::randn(1, 10, 1.0, &mut rng);
        let d0 = decode_kernel_calls();
        let p0 = panel_kernel_calls();
        fm.decode_matmul_t(&x);
        fm.matvec(x.row(0));
        fm.matmul_t(&x);
        assert!(decode_kernel_calls() >= d0 + 2, "decode counter stuck");
        assert!(panel_kernel_calls() >= p0 + 1, "panel counter stuck");
    }

    #[test]
    fn large_blocked_path_matches_reference() {
        // Big enough to cross the threading threshold so the parallel
        // block assembly is exercised — with and without rotation.
        let mut rng = Pcg64::new(32, 1);
        let (_cm, fm) = random_compressed(&mut rng, "uniform", 320, 256, 8, 4, 64);
        let x = Matrix::randn(256, 32, 1.0, &mut rng);
        let dense = fm.reconstruct();
        assert!(fm.matmul(&x).rel_err(&dense.dot(&x)) < 1e-4);
        let xt = Matrix::randn(48, 256, 1.0, &mut rng);
        assert!(fm.matmul_t(&xt).rel_err(&matmul_nt(&xt, &dense)) < 1e-4);

        let inc = Incoherence::new(320, 256, &mut rng);
        let w = Matrix::randn(320, 256, 1.0, &mut rng);
        let qout = UniformQuantizer::new(4, 64).quantize(&inc.apply(&w));
        let packed = qout
            .packed
            .with_rotation(inc.left_signs.clone(), inc.right_signs.clone());
        let fm = FusedQlrMatrix::new(packed, LrPair::zeros(320, 256, 0)).unwrap();
        let dense = fm.reconstruct();
        assert!(fm.matmul(&x).rel_err(&dense.dot(&x)) < 1e-4);
        assert!(fm.matmul_t(&xt).rel_err(&matmul_nt(&xt, &dense)) < 1e-4);
    }

    #[test]
    fn fused_matrix_serialization_roundtrip_per_scheme() {
        let mut rng = Pcg64::new(33, 1);
        for (scheme, bits, group) in [("mxint", 3, 16), ("e8", 2, 8), ("uniform", 4, 16)] {
            let (_cm, fm) = random_compressed(&mut rng, scheme, 20, 28, 4, bits, group);
            let mut buf = Vec::new();
            fm.write_to(&mut buf).unwrap();
            assert_eq!(&buf[..4], b"ODQ2");
            let back = FusedQlrMatrix::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(fm, back, "{scheme}");
            assert_eq!(buf.len(), fm.byte_size(), "{scheme} byte_size drifted");
            assert!(fm.bits_per_weight() > 0.0);
        }
    }

    #[test]
    fn reads_legacy_v1_fused_matrix() {
        // A v1 stream (ODQ1 + ODP1 uniform payload) still loads into the
        // identical matrix.
        let mut rng = Pcg64::new(34, 1);
        let w = Matrix::randn(12, 20, 1.0, &mut rng);
        let packed = PackedMatrix::pack(&w, 4, 8);
        let lr = LrPair {
            l: Matrix::randn(12, 3, 0.1, &mut rng),
            r: Matrix::randn(3, 20, 0.1, &mut rng),
        };
        let fm = FusedQlrMatrix::new(packed.clone(), lr.clone()).unwrap();
        let mut v1 = Vec::new();
        v1.extend_from_slice(b"ODQ1");
        packed.write_to_v1(&mut v1).unwrap();
        fm.l.write_to(&mut v1).unwrap();
        fm.r.write_to(&mut v1).unwrap();
        let back = FusedQlrMatrix::read_from(&mut v1.as_slice()).unwrap();
        assert_eq!(back, fm);
    }

    #[test]
    fn fused_model_forward_matches_repacked_dense() {
        // pack_dense at 8 bits, then compare the packed-kernel forward with
        // a dense forward over the *reconstructed* weights: identical math,
        // different kernels.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 21);
        let fm = FusedModel::pack_dense(&params, "uniform", 8, 32).unwrap();
        let mut dense_params = params.clone();
        for name in &fam.projections {
            dense_params
                .set_matrix(name, &fm.mats[name].reconstruct())
                .unwrap();
        }
        let (b, s) = (2usize, 6usize);
        let mut rng = Pcg64::new(22, 2);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(fam.vocab) as i32).collect();
        let fused_logits = fm.forward(&tokens, b, s).unwrap();
        let view = ParamView::from_params(&dense_params).unwrap();
        let dense_logits = forward_with(
            &fam,
            &view,
            &crate::runtime::native::DenseProj { view: &view },
            &tokens,
            b,
            s,
            None,
        )
        .unwrap();
        assert!(
            fused_logits.rel_err(&dense_logits) < 1e-4,
            "rel err {}",
            fused_logits.rel_err(&dense_logits)
        );
        // 8-bit codes + scales + per-matrix headers (the micro matrices are
        // tiny, so header overhead is a large fraction).
        assert!(fm.avg_bits() > 8.0 && fm.avg_bits() < 40.0, "{}", fm.avg_bits());
        assert_eq!(fm.scheme_summary(), "uniform×7");
    }

    #[test]
    fn fused_generation_matches_dense_engine_property() {
        // Fused-vs-dense generation equivalence: pack a model at 8 bits,
        // rebuild dense params from the *reconstructed* weights (identical
        // math, different kernels), and greedy-generate through both
        // engines — token streams must agree and per-step logits must stay
        // within kernel summation tolerance.
        use crate::engine::{generate, NativeEngine, Sampling};
        testing::quick("fused-vs-dense-generation", |rng| {
            let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
            let params = ModelParams::init(&fam, 40 + rng.below(1000) as u64);
            let fm = FusedModel::pack_dense(&params, "uniform", 8, 32)
                .unwrap()
                .with_shape(2, 8);
            let mut dense_params = params.clone();
            for name in &fam.projections {
                dense_params
                    .set_matrix(name, &fm.mats[name].reconstruct())
                    .unwrap();
            }
            let dense = NativeEngine::new(&dense_params, 2, 8).unwrap();
            let prompt_len = 2 + rng.below(4);
            let prompt: Vec<i32> = (0..prompt_len)
                .map(|_| rng.below(fam.vocab) as i32)
                .collect();
            let steps = 3 + rng.below(4);
            let a = generate(&fm, &prompt, steps, Sampling::Greedy).unwrap();
            let b = generate(&dense, &prompt, steps, Sampling::Greedy).unwrap();
            if a.tokens != b.tokens {
                // The only legitimate divergence is a near-tie between the
                // top-2 logits, where kernel summation order may flip the
                // argmax; anything else is a real equivalence bug.
                let j = a
                    .tokens
                    .iter()
                    .zip(&b.tokens)
                    .position(|(x, y)| x != y)
                    .expect("equal-length streams that differ have a first divergence");
                let mut hist = prompt.clone();
                hist.extend(&a.tokens[..j]);
                let ld = dense.forward_batch(&hist, 1, hist.len()).unwrap();
                let mut top: Vec<f32> = ld.row(hist.len() - 1).to_vec();
                top.sort_by(|x, y| y.total_cmp(x));
                assert!(
                    top[0] - top[1] < 1e-3,
                    "greedy streams diverged at step {j} with top-2 gap {}",
                    top[0] - top[1]
                );
            }
            // Logit-level agreement after replaying one engine's history.
            let mut history = prompt.clone();
            history.extend(&a.tokens);
            let lf = fm.forward_batch(&history, 1, history.len()).unwrap();
            let ld = dense.forward_batch(&history, 1, history.len()).unwrap();
            assert!(
                lf.rel_err(&ld) < 1e-4,
                "fused vs dense logits rel err {}",
                lf.rel_err(&ld)
            );
        });
    }

    #[test]
    fn fused_incremental_decode_matches_fused_full_forward() {
        // Prefill at the same row count replays the identical kernel, so
        // it stays bit-exact against the full forward. Decode steps route
        // through the specialized fused dequant-dot kernel, whose
        // summation order differs from the panel kernel the full forward
        // uses — per-step logits agree to f32 rounding, and the sampled
        // greedy stream is checked exactly by the generation tests.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 41);
        let fm = FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(2, 8);
        let mut rng = Pcg64::new(42, 2);
        let tokens: Vec<i32> = (0..9).map(|_| rng.below(fam.vocab) as i32).collect();
        let (mut session, pre) = fm.prefill(&tokens[..4]).unwrap();
        let full4 = fm.forward(&tokens[..4], 1, 4).unwrap();
        assert_eq!(pre.max_abs_diff(&full4), 0.0, "fused prefill diverged");
        for t in 4..tokens.len() {
            let step = {
                let mut refs: [&mut Session; 1] = [&mut session];
                fm.decode_step(&mut refs, &tokens[t..t + 1]).unwrap()
            };
            let full = fm.forward(&tokens[..t + 1], 1, t + 1).unwrap();
            for j in 0..fam.vocab {
                let (got, want) = (step.at(0, j), full.at(t, j));
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "step {t} col {j}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn fused_chunked_prefill_matches_one_shot_bit_exactly() {
        // The chunked-prefill contract on the packed path: any chunking —
        // including ragged final chunks small enough to fall into the
        // decode-kernel regime, which ChunkProj pins back to the one-shot
        // kernel — produces the same final-row logits and byte-identical
        // decode continuations as one-shot prefill.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 47);
        let fm = FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(2, 8);
        let mut rng = Pcg64::new(51, 3);
        for plen in [9usize, 2] {
            // 9 > batch (panel regime one-shot); 2 ≤ batch (decode regime).
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(fam.vocab) as i32).collect();
            let (mut one, logits) = fm.prefill(&prompt).unwrap();
            let splits: Vec<Vec<usize>> = if plen == 9 {
                vec![vec![4, 5], vec![2, 2, 2, 3], vec![8, 1], vec![9]]
            } else {
                vec![vec![1, 1], vec![2]]
            };
            for split in splits {
                let mut state = None;
                let mut done = 0usize;
                let mut last = None;
                for &m in &split {
                    last = Some(fm.prefill_chunk(&prompt, &mut state, done + m).unwrap());
                    done += m;
                }
                let last = last.unwrap();
                assert_eq!(
                    last.row(last.rows() - 1),
                    logits.row(logits.rows() - 1),
                    "plen {plen} split {split:?}: final-row logits diverged"
                );
                let mut chunked = Session::new(prompt.clone(), state.take().unwrap());
                let next = crate::engine::argmax(logits.row(logits.rows() - 1)) as i32;
                let a = fm.decode_step(&mut [&mut one], &[next]).unwrap();
                let b = fm.decode_step(&mut [&mut chunked], &[next]).unwrap();
                assert_eq!(
                    a.row(0),
                    b.row(0),
                    "plen {plen} split {split:?}: decode diverged"
                );
                let (fresh, _) = fm.prefill(&prompt).unwrap();
                one = fresh;
            }
        }
    }

    #[test]
    fn fused_model_serialization_roundtrip() {
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 23);
        let fm = FusedModel::pack_dense(&params, "mxint", 4, 16)
            .unwrap()
            .with_shape(2, 6);
        let dir = std::env::temp_dir().join("odlri_test_odf");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("micro.odf");
        fm.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..4], b"ODF3");
        let back = FusedModel::load(&fam, &path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.batch, 2);
        assert_eq!(back.seq, 6);
        assert_eq!(back.mats.len(), fm.mats.len());
        for (name, m) in &fm.mats {
            assert_eq!(m, &back.mats[name], "{name}");
        }
        // Plan metadata round-trips exactly.
        assert_eq!(back.plans, fm.plans);
        let mut rng = Pcg64::new(24, 2);
        let tokens: Vec<i32> = (0..12).map(|_| rng.below(fam.vocab) as i32).collect();
        let a = fm.forward(&tokens, 2, 6).unwrap();
        let b = back.forward(&tokens, 2, 6).unwrap();
        assert!(a.max_abs_diff(&b) == 0.0);
    }

    /// Corrupt length fields must surface as ranged errors *before* they
    /// size an allocation or a read loop: an oversized name length, an
    /// oversized dim count, and truncated streams all refuse to load.
    #[test]
    fn corrupt_container_counts_are_ranged_errors() {
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 23);
        let fm = FusedModel::pack_dense(&params, "mxint", 4, 16).unwrap();
        let mut buf = Vec::new();
        fm.write_to(&mut buf).unwrap();

        // Family-name length (bytes 4..8) blown up to ~4 GiB: a ranged
        // refusal, not an allocation attempt.
        let mut bad = buf.clone();
        bad[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = FusedModel::read_from(&fam, &mut bad.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("cap"), "unexpected error: {err:#}");

        // One past the cap is refused too (boundary).
        let mut bad = buf.clone();
        bad[4..8].copy_from_slice(&(MAX_NAME_BYTES as u32 + 1).to_le_bytes());
        let err = FusedModel::read_from(&fam, &mut bad.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("cap"), "unexpected error: {err:#}");

        // First dense param's ndim field blown up: the dim-read loop must
        // refuse instead of spinning for 4 billion reads. Layout: magic(4)
        // + nlen(4) + name + batch(4) + seq(4) + ndense(4) + pnlen(4) +
        // pname + ndim.
        let name_len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        let ndense_off = 8 + name_len + 8;
        let ndense = u32::from_le_bytes(buf[ndense_off..ndense_off + 4].try_into().unwrap());
        assert!(ndense > 0, "test needs at least one stored dense param");
        let pn_off = ndense_off + 4;
        let pn_len = u32::from_le_bytes(buf[pn_off..pn_off + 4].try_into().unwrap()) as usize;
        let ndim_off = pn_off + 4 + pn_len;
        let mut bad = buf.clone();
        bad[ndim_off..ndim_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = FusedModel::read_from(&fam, &mut bad.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("dims"), "unexpected error: {err:#}");

        // Truncated streams fail cleanly at any cut point.
        for cut in [3, 7, buf.len() / 2, buf.len() - 1] {
            assert!(
                FusedModel::read_from(&fam, &mut &buf[..cut]).is_err(),
                "cut at {cut} loaded"
            );
        }
    }

    /// A heterogeneous compressed model (different rank/scheme/bits per
    /// projection) round-trips through the ODF3 container, plans included.
    #[test]
    fn heterogeneous_plan_container_roundtrip() {
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 29);
        let mut rng = Pcg64::new(30, 1);
        let mut matrices = BTreeMap::new();
        for (i, name) in fam.projections.iter().enumerate() {
            let shape = fam.param_shape(name).unwrap();
            let (m, n) = (shape[0], shape[1]);
            let w = testing::gen_matrix(&mut rng, m, n);
            let (scheme, bits, group) = [("uniform", 3, 4), ("e8", 2, 8), ("mxint", 4, 4)]
                [i % 3];
            let rank = i % 3;
            let quant = make_quantizer(scheme, bits, group).unwrap();
            let qout = quant.quantize(&w);
            let lr = if rank == 0 {
                LrPair::zeros(m, n, 0)
            } else {
                svd_lr(&w.sub(&qout.deq), rank, &mut rng)
            };
            matrices.insert(
                name.clone(),
                CompressedMatrix {
                    q: qout.deq,
                    q_packed: qout.packed,
                    lr,
                    quant_scale: qout.scale,
                    final_act_err: 0.0,
                    plan: test_plan(scheme, rank, bits, group, false),
                    q_bits_overhead: quant.bits_with_overhead(m, n),
                },
            );
        }
        let model = CompressedModel {
            family: fam.clone(),
            matrices,
        };
        let fm = model.to_fused(&params).unwrap().with_shape(1, 4);
        assert!(fm.plans.values().any(|p| p.q_scheme == "e8"));
        assert!(fm.plans.values().any(|p| p.q_scheme == "mxint"));
        let mut buf = Vec::new();
        fm.write_to(&mut buf).unwrap();
        let back = FusedModel::read_from(&fam, &mut buf.as_slice()).unwrap();
        assert_eq!(back.plans, fm.plans);
        for (name, m) in &fm.mats {
            assert_eq!(m, &back.mats[name], "{name}");
            assert_eq!(
                back.mats[name].byte_size(),
                m.byte_size(),
                "{name}: reported footprint changed through the container"
            );
        }
        // Plan metadata contradicting the stored codes is rejected, not
        // silently accepted — basis, scheme/bits, and rank alike.
        let first = fam.projections[0].clone();
        for corrupt in [
            (|p: &mut MatrixPlan| p.hadamard = true) as fn(&mut MatrixPlan),
            |p| {
                p.q_scheme = "mxint".into();
                p.q_bits = 4;
            },
            |p| p.rank += 1,
        ] {
            let mut bad = FusedModel::read_from(&fam, &mut buf.as_slice()).unwrap();
            corrupt(bad.plans.get_mut(&first).unwrap());
            let mut bad_buf = Vec::new();
            bad.write_to(&mut bad_buf).unwrap();
            assert!(FusedModel::read_from(&fam, &mut bad_buf.as_slice()).is_err());
        }
    }

    /// Golden bytes for the v3 container framing: magic, header, dense
    /// section, and the per-matrix `name + plan metadata + ODQ2` record
    /// must not silently drift. The inner ODP2/ODQ2 payloads are pinned by
    /// their own golden tests, so this test hand-assembles the container
    /// around `write_to` outputs of the component matrices.
    #[test]
    fn serialized_golden_bytes_odf3() {
        // Two-projection toy family with a single dense param.
        let fam = FamilySpec {
            name: "g".into(),
            params: vec![
                ("embed".into(), vec![2, 2]),
                ("p.wq".into(), vec![2, 2]),
                ("p.wup".into(), vec![3, 2]),
            ],
            projections: vec!["p.wq".into(), "p.wup".into()],
            vocab: 2,
            d_model: 2,
            n_layers: 1,
            d_ff: 3,
            n_heads: 1,
            n_kv_heads: 1,
            mlp: "swiglu".into(),
            rope_theta: 10000.0,
        };
        let embed = vec![1.0f32, 2.0, 3.0, 4.0];
        let params = ModelParams {
            family: fam.clone(),
            values: vec![
                Value::from_vec_f32(vec![2, 2], embed.clone()),
                Value::from_vec_f32(vec![2, 2], vec![0.0; 4]),
                Value::from_vec_f32(vec![3, 2], vec![0.0; 6]),
            ],
        };
        // Heterogeneous recipes: wq 3-bit rank-0, wup 2-bit rank-1.
        let wq = Matrix::from_vec(2, 2, vec![3.0, -1.0, 2.0, 0.0]);
        let wq_packed = PackedMatrix::pack(&wq, 3, 2);
        let wup = Matrix::from_vec(3, 2, vec![1.0, -1.0, 1.0, 0.0, -1.0, 1.0]);
        let wup_packed = PackedMatrix::pack(&wup, 2, 2);
        let l = Matrix::from_vec(3, 1, vec![0.5, -0.5, 0.25]);
        let r = Matrix::from_vec(1, 2, vec![2.0, -2.0]);
        let mut matrices = BTreeMap::new();
        matrices.insert(
            "p.wq".into(),
            CompressedMatrix {
                q: wq_packed.unpack(),
                q_packed: wq_packed.clone(),
                lr: LrPair::zeros(2, 2, 0),
                quant_scale: 1.0,
                final_act_err: 0.0,
                plan: test_plan("uniform", 0, 3, 2, false),
                q_bits_overhead: 3.0,
            },
        );
        matrices.insert(
            "p.wup".into(),
            CompressedMatrix {
                q: wup_packed.unpack(),
                q_packed: wup_packed.clone(),
                lr: LrPair {
                    l: l.clone(),
                    r: r.clone(),
                },
                quant_scale: 1.0,
                final_act_err: 0.0,
                plan: test_plan("uniform", 1, 2, 2, false),
                q_bits_overhead: 2.0,
            },
        );
        let model = CompressedModel {
            family: fam.clone(),
            matrices,
        };
        let fm = model.to_fused(&params).unwrap().with_shape(1, 4);
        let mut got = Vec::new();
        fm.write_to(&mut got).unwrap();

        // Hand-assemble the expected stream from the format spec.
        let mut expect: Vec<u8> = Vec::new();
        let push_u32 = |v: u32, out: &mut Vec<u8>| out.extend_from_slice(&v.to_le_bytes());
        let push_str = |s: &str, out: &mut Vec<u8>| {
            push_u32(s.len() as u32, out);
            out.extend_from_slice(s.as_bytes());
        };
        expect.extend_from_slice(b"ODF3");
        push_str("g", &mut expect); // family name
        push_u32(1, &mut expect); // batch
        push_u32(4, &mut expect); // seq
        // dense section: 1 param (embed), dims [2,2], f32 data
        push_u32(1, &mut expect);
        push_str("embed", &mut expect);
        push_u32(2, &mut expect);
        push_u32(2, &mut expect);
        push_u32(2, &mut expect);
        for v in &embed {
            expect.extend_from_slice(&v.to_le_bytes());
        }
        // packed section: 2 matrices, BTreeMap order (p.wq < p.wup)
        push_u32(2, &mut expect);
        for (name, plan, packed, lrank) in [
            ("p.wq", test_plan("uniform", 0, 3, 2, false), &wq_packed, None),
            (
                "p.wup",
                MatrixPlan {
                    // from_compressed records the REALIZED rank
                    rank: 1,
                    ..test_plan("uniform", 1, 2, 2, false)
                },
                &wup_packed,
                Some((l.clone(), r.clone())),
            ),
        ] {
            push_str(name, &mut expect);
            // plan metadata block: init, rank, lr_bits, scheme, bits,
            // group, hadamard flag
            push_str("caldera", &mut expect);
            push_u32(plan.rank as u32, &mut expect);
            push_u32(plan.lr_bits, &mut expect);
            push_str("uniform", &mut expect);
            push_u32(plan.q_bits, &mut expect);
            push_u32(plan.q_group as u32, &mut expect);
            expect.push(0u8); // hadamard = false
            // fused matrix: ODQ2 + packed + L + R (pinned by their own
            // golden tests; reuse the component writers here)
            expect.extend_from_slice(b"ODQ2");
            packed.write_to(&mut expect).unwrap();
            match &lrank {
                Some((lm, rm)) => {
                    lm.write_to(&mut expect).unwrap();
                    rm.write_to(&mut expect).unwrap();
                }
                None => {
                    Matrix::zeros(2, 0).write_to(&mut expect).unwrap();
                    Matrix::zeros(0, 2).write_to(&mut expect).unwrap();
                }
            }
        }
        assert_eq!(got, expect, "ODF3 container framing drifted");
        // And the golden stream loads back to the same model.
        let back = FusedModel::read_from(&fam, &mut got.as_slice()).unwrap();
        assert_eq!(back.plans, fm.plans);
        assert_eq!(back.mats, fm.mats);
    }

    /// Regression: an ODF2 stream (no plan metadata) still reads, its
    /// matrices are byte-identical, per-matrix footprint reporting is
    /// unchanged, and each matrix maps to a synthesized uniform-style plan.
    #[test]
    fn odf2_stream_reads_with_synthesized_plans_and_same_bits() {
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 31);
        let fm = FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(2, 6);
        let mut v2 = Vec::new();
        fm.write_to_v2(&mut v2).unwrap();
        assert_eq!(&v2[..4], b"ODF2");
        let back = FusedModel::read_from(&fam, &mut v2.as_slice()).unwrap();
        assert_eq!(back.mats.len(), fm.mats.len());
        for (name, m) in &fm.mats {
            assert_eq!(m, &back.mats[name], "{name}");
            assert_eq!(
                back.mats[name].byte_size(),
                m.byte_size(),
                "{name}: v2 read changed the reported per-matrix bytes"
            );
            assert_eq!(
                back.mats[name].bits_per_weight(),
                m.bits_per_weight(),
                "{name}: v2 read changed the reported per-matrix bits"
            );
            let plan = &back.plans[name];
            assert_eq!(plan.q_scheme, "uniform");
            assert_eq!(plan.q_bits, 4);
            assert_eq!(plan.q_group, 16);
            assert_eq!(plan.rank, 0);
            assert!(!plan.hadamard);
        }
        assert_eq!(back.avg_bits(), fm.avg_bits());
        // Whole-model footprint reporting is unchanged for v2 streams too.
        let mut rng = Pcg64::new(32, 2);
        let tokens: Vec<i32> = (0..12).map(|_| rng.below(fam.vocab) as i32).collect();
        let a = fm.forward(&tokens, 2, 6).unwrap();
        let b = back.forward(&tokens, 2, 6).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn loads_v1_magic_container() {
        // ODF1 containers (whose inner matrices self-describe their own
        // version) still load; like ODF2 they carry no plan metadata.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 25);
        let fm = FusedModel::pack_dense(&params, "uniform", 4, 16).unwrap();
        let mut bytes = Vec::new();
        fm.write_to_v2(&mut bytes).unwrap();
        bytes[..4].copy_from_slice(b"ODF1");
        let back = FusedModel::read_from(&fam, &mut bytes.as_slice()).unwrap();
        assert_eq!(back.mats.len(), fm.mats.len());
        assert_eq!(back.plans.len(), fm.mats.len());
    }
}
