//! Property-testing kit (std-only `proptest` replacement).
//!
//! Runs a closure over `cases` seeded random inputs; on failure it reports
//! the failing case index and seed so the exact input can be replayed with
//! `replay(seed, case)`. No shrinking — our generators take explicit size
//! parameters, so failures are already small.

use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // `ODLRI_PROP_SEED` reseeds every property run — CI exercises the
        // suite under a second seed so bit-format/kernel regressions can't
        // hide behind one lucky stream.
        let seed = std::env::var("ODLRI_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xD15EA5E);
        PropConfig { cases: 32, seed }
    }
}

/// Run `prop` for `cfg.cases` cases. `prop` gets a per-case RNG and should
/// panic (assert) on violation.
pub fn check(cfg: PropConfig, name: &str, prop: impl Fn(&mut Pcg64)) {
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed, case as u64 + 1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay: Pcg64::new({:#x}, {})): {msg}",
                cfg.seed,
                case + 1
            );
        }
    }
}

/// Shorthand with default config.
pub fn quick(name: &str, prop: impl Fn(&mut Pcg64)) {
    check(PropConfig::default(), name, prop);
}

// ---- generators ----

/// Random dimension in [lo, hi].
pub fn gen_dim(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Random Gaussian matrix with random scale in [0.1, 10].
pub fn gen_matrix(rng: &mut Pcg64, rows: usize, cols: usize) -> Matrix {
    let sigma = rng.uniform_in(0.1, 10.0);
    Matrix::randn(rows, cols, sigma, rng)
}

/// Random matrix with planted low-rank structure plus noise.
pub fn gen_lowrank_plus_noise(
    rng: &mut Pcg64,
    rows: usize,
    cols: usize,
    rank: usize,
    noise: f32,
) -> Matrix {
    let l = Matrix::randn(rows, rank, 1.0, rng);
    let r = Matrix::randn(rank, cols, 1.0, rng);
    let mut w = l.dot(&r);
    let n = Matrix::randn(rows, cols, noise, rng);
    w.add_assign(&n);
    w
}

/// Random SPD matrix (Gram of a slightly-overcomplete Gaussian).
pub fn gen_spd(rng: &mut Pcg64, n: usize) -> Matrix {
    let a = Matrix::randn(n, n + 8, 1.0, rng);
    let mut h = a.dot_t(&a);
    let jit = 0.01 * (n as f32).max(1.0);
    for i in 0..n {
        *h.at_mut(i, i) += jit;
    }
    h
}

/// Activations with planted outlier channels: `n` channels × `d` samples,
/// with `n_outliers` channels scaled by a factor in [10, 50]. Returns
/// (X, outlier_indices). This is the synthetic stand-in for LLM activation
/// outliers (see DESIGN.md §2).
pub fn gen_outlier_acts(
    rng: &mut Pcg64,
    n: usize,
    d: usize,
    n_outliers: usize,
) -> (Matrix, Vec<usize>) {
    let mut x = Matrix::randn(n, d, 1.0, rng);
    let idx = rng.sample_indices(n, n_outliers);
    for &i in &idx {
        let boost = rng.uniform_in(10.0, 50.0);
        x.scale_row(i, boost);
    }
    let mut sorted = idx.clone();
    sorted.sort_unstable();
    (x, sorted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        quick("sum-commutes", |rng| {
            let a = rng.normal();
            let b = rng.normal();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_reports_failure() {
        check(
            PropConfig { cases: 3, seed: 1 },
            "always-fails",
            |_| panic!("boom"),
        );
    }

    #[test]
    fn outlier_acts_have_dominant_rows() {
        let mut rng = Pcg64::new(70, 1);
        let (x, idx) = gen_outlier_acts(&mut rng, 32, 64, 3);
        assert_eq!(idx.len(), 3);
        // Outlier rows must dominate the row-norm ranking.
        let norms: Vec<f32> = (0..32)
            .map(|i| x.row(i).iter().map(|v| v * v).sum::<f32>())
            .collect();
        let mut order: Vec<usize> = (0..32).collect();
        order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
        let top3: Vec<usize> = {
            let mut t = order[..3].to_vec();
            t.sort_unstable();
            t
        };
        assert_eq!(top3, idx);
    }

    #[test]
    fn gen_spd_is_pd() {
        let mut rng = Pcg64::new(71, 1);
        let h = gen_spd(&mut rng, 20);
        assert!(crate::linalg::cholesky(&h).is_ok());
    }
}
