//! Model parameter containers: flat parameter lists matching the AOT
//! manifest layout, the function-preserving **outlier injection** transform
//! (DESIGN.md §2), and the compressed-model container.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::MatrixPlan;
use crate::decompose::avg_bits;
use crate::lowrank::LrPair;
use crate::runtime::{FamilySpec, Value};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Flat model parameters in manifest order (the exact layout every
/// `fwd_*`/`train_*`/`capture_*` artifact expects).
#[derive(Clone, Debug)]
pub struct ModelParams {
    pub family: FamilySpec,
    pub values: Vec<Value>,
}

impl ModelParams {
    /// Scaled-normal initialization (norm gains = 1), mirroring
    /// `model.init_params` on the Python side.
    pub fn init(family: &FamilySpec, seed: u64) -> ModelParams {
        let mut rng = Pcg64::new(seed, 0x0D11);
        let values = family
            .params
            .iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                if FamilySpec::is_norm(name) {
                    Value::from_vec_f32(shape.clone(), vec![1.0; n])
                } else {
                    let fan_in = *shape.last().unwrap() as f32;
                    let sigma = 1.0 / fan_in.sqrt();
                    let mut data = vec![0f32; n];
                    rng.fill_normal(&mut data, sigma);
                    Value::from_vec_f32(shape.clone(), data)
                }
            })
            .collect();
        ModelParams {
            family: family.clone(),
            values,
        }
    }

    pub fn get_matrix(&self, name: &str) -> Result<Matrix> {
        let idx = self.family.param_index(name)?;
        self.values[idx].to_matrix()
    }

    pub fn set_matrix(&mut self, name: &str, m: &Matrix) -> Result<()> {
        let idx = self.family.param_index(name)?;
        let shape = self.family.param_shape(name)?.to_vec();
        let expect: usize = shape.iter().product();
        if m.rows() * m.cols() != expect {
            bail!("set_matrix('{name}'): size mismatch");
        }
        self.values[idx] = Value::from_vec_f32(shape, m.as_slice().to_vec());
        Ok(())
    }

    pub fn param_count(&self) -> usize {
        self.values
            .iter()
            .map(|v| v.shape().iter().product::<usize>())
            .sum()
    }

    /// Write the `.odw` weight-store format to any writer.
    pub fn write_to(&self, f: &mut impl Write) -> Result<()> {
        f.write_all(b"ODW1")?;
        f.write_all(&(self.values.len() as u32).to_le_bytes())?;
        for ((name, shape), v) in self.family.params.iter().zip(&self.values) {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            for &x in v.f32_data()? {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Write to the `.odw` weight-store format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        self.write_to(&mut f)
    }

    /// Read the `.odw` format from any reader, validating against the
    /// family layout.
    pub fn read_from(family: &FamilySpec, f: &mut impl Read) -> Result<ModelParams> {
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"ODW1" {
            bail!("bad weight-store magic");
        }
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let count = u32::from_le_bytes(b4) as usize;
        if count != family.params.len() {
            bail!(
                "weight store has {count} params, family {} wants {}",
                family.name,
                family.params.len()
            );
        }
        let mut values = Vec::with_capacity(count);
        for (name, shape) in &family.params {
            f.read_exact(&mut b4)?;
            let nlen = u32::from_le_bytes(b4) as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let got = String::from_utf8(nb)?;
            if &got != name {
                bail!("weight store order mismatch: got '{got}', want '{name}'");
            }
            f.read_exact(&mut b4)?;
            let ndim = u32::from_le_bytes(b4) as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                f.read_exact(&mut b4)?;
                dims.push(u32::from_le_bytes(b4) as usize);
            }
            if &dims != shape {
                bail!("weight store shape mismatch for '{name}'");
            }
            let n: usize = dims.iter().product();
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            values.push(Value::from_vec_f32(dims, data));
        }
        Ok(ModelParams {
            family: family.clone(),
            values,
        })
    }

    /// Load from `.odw`, validating against the family layout.
    pub fn load(family: &FamilySpec, path: &Path) -> Result<ModelParams> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        ModelParams::read_from(family, &mut f)
    }
}

/// Function-preserving outlier injection (DESIGN.md §2).
///
/// LLMs at 7B+ develop a few activation channels with norms 10–100× the
/// rest (SpQR, AWQ); our tiny trained models do not. This transform plants
/// the same structure WITHOUT changing the network function: for each
/// chosen channel `c` of a norm's gain vector, multiply `γ_c` by `boost`
/// and divide column `c` of every weight matrix consuming that normed
/// activation by `boost`. The products `W·x` are unchanged, but the
/// consuming weights now have small-magnitude *salient* columns whose
/// quantization error is amplified by outlier activations — exactly the
/// phenomenon ODLRI targets.
pub fn inject_outliers(
    params: &mut ModelParams,
    per_layer: usize,
    boost: f32,
    seed: u64,
) -> Result<Vec<(String, Vec<usize>)>> {
    let mut rng = Pcg64::new(seed, 0x0A11);
    let n_layers = params.family.n_layers;
    let d = params.family.d_model;
    let mut planted = Vec::new();
    for layer in 0..n_layers {
        for (norm, consumers) in [
            (format!("layer{layer}.ln1"), vec![
                format!("layer{layer}.wq"),
                format!("layer{layer}.wk"),
                format!("layer{layer}.wv"),
            ]),
            (format!("layer{layer}.ln2"), vec![
                format!("layer{layer}.wgate"),
                format!("layer{layer}.wup"),
            ]),
        ] {
            let channels = rng.sample_indices(d, per_layer.min(d));
            // Scale the gain up…
            let mut g = params.get_matrix(&norm)?;
            for &c in &channels {
                *g.at_mut(0, c) *= boost;
            }
            params.set_matrix(&norm, &g)?;
            // …and the consuming columns down.
            for w_name in &consumers {
                let mut w = params.get_matrix(w_name)?;
                for &c in &channels {
                    w.scale_col(c, 1.0 / boost);
                }
                params.set_matrix(w_name, &w)?;
            }
            let mut sorted = channels.clone();
            sorted.sort_unstable();
            planted.push((norm, sorted));
        }
    }
    Ok(planted)
}

/// A compressed projection: Ŵ = Q + L·R plus bookkeeping.
///
/// Invariant: `q == q_packed.unpack()` bit-for-bit — the packed codes are
/// the quantizer's own output, not a re-quantization, so the fused serving
/// path evaluates exactly the decomposition the pipeline optimized.
///
/// Every matrix carries its own per-projection recipe and bit bookkeeping
/// ([`MatrixPlan`], realized rank, Q bits with overhead): model-level
/// numbers are parameter-weighted aggregates over these, never globals —
/// plans may differ per projection.
#[derive(Clone, Debug)]
pub struct CompressedMatrix {
    /// Dense quantize-dequantized `Q` (original basis).
    pub q: Matrix,
    /// The same `Q` as scheme-native packed codes (uniform / E8 / MXINT,
    /// plus Hadamard rotation metadata for incoherence-processed runs).
    pub q_packed: crate::quant::PackedMatrix,
    pub lr: LrPair,
    pub quant_scale: f32,
    pub final_act_err: f64,
    /// The recipe this projection was compressed under. `plan.rank` is the
    /// *requested* rank; [`CompressedMatrix::rank`] reports the realized
    /// factor width (clamped to the matrix dimensions).
    pub plan: MatrixPlan,
    /// This projection's Q bits/weight including scale-metadata overhead
    /// for its shape and scheme.
    pub q_bits_overhead: f64,
}

impl CompressedMatrix {
    /// Densify `Q + L·R`. Offline/debug only — the inference path uses
    /// [`CompressedMatrix::to_fused`] and never materializes this.
    pub fn reconstruct(&self) -> Matrix {
        self.q.add(&self.lr.product())
    }

    /// Realized factor rank.
    pub fn rank(&self) -> usize {
        self.lr.rank()
    }

    /// Factor precision this matrix was optimized with.
    pub fn lr_bits(&self) -> u32 {
        self.plan.lr_bits
    }

    /// Paper-style average bits/weight of this projection (realized rank,
    /// own quantizer overhead).
    pub fn avg_bits(&self) -> f64 {
        avg_bits(
            self.q_packed.rows,
            self.q_packed.cols,
            self.rank(),
            self.q_bits_overhead,
            self.plan.lr_bits,
        )
    }

    /// Deployment form: the quantizer's native packed codes plus the skinny
    /// factors. No re-quantization happens here — the fused kernels decode
    /// the exact `Q` this matrix was optimized with and compute
    /// `Q·x + L·(R·x)` without densifying.
    pub fn to_fused(&self) -> Result<crate::fused::FusedQlrMatrix> {
        crate::fused::FusedQlrMatrix::new(self.q_packed.clone(), self.lr.clone())
    }
}

/// Whole-model compression result. Rank/bit bookkeeping lives on each
/// [`CompressedMatrix`]; the model only derives parameter-weighted
/// aggregates.
#[derive(Clone, Debug)]
pub struct CompressedModel {
    pub family: FamilySpec,
    pub matrices: BTreeMap<String, CompressedMatrix>,
}

impl CompressedModel {
    /// Deployment form: every projection's native packed codes wired into
    /// the fused `(Q+LR)·x` engine, dense params carried alongside for
    /// embed/norms/unembed.
    pub fn to_fused(&self, base: &ModelParams) -> Result<crate::fused::FusedModel> {
        crate::fused::FusedModel::from_compressed(self, base)
    }

    /// Model parameters with every projection replaced by its
    /// reconstruction (weight-only compression ⇒ numerically identical to
    /// running the decomposed form). Offline export path — serving should
    /// prefer [`CompressedModel::to_fused`].
    pub fn apply_to(&self, base: &ModelParams) -> Result<ModelParams> {
        let mut out = base.clone();
        for (name, cm) in &self.matrices {
            out.set_matrix(name, &cm.reconstruct())?;
        }
        Ok(out)
    }

    /// Parameter-weighted mean over `f` of the compressed projections.
    fn weighted_mean(&self, f: impl Fn(&CompressedMatrix) -> f64) -> f64 {
        let mut weighted = 0.0;
        let mut total = 0.0;
        for cm in self.matrices.values() {
            let count = (cm.q_packed.rows * cm.q_packed.cols) as f64;
            weighted += f(cm) * count;
            total += count;
        }
        if total == 0.0 {
            0.0
        } else {
            weighted / total
        }
    }

    /// Paper-style average bits/weight over the compressed projections —
    /// the parameter-weighted mean of each matrix's own
    /// [`CompressedMatrix::avg_bits`] (plans may differ per projection).
    pub fn avg_bits(&self) -> f64 {
        self.weighted_mean(CompressedMatrix::avg_bits)
    }

    /// Parameter-weighted mean Q bits/weight including per-scheme scale
    /// overhead.
    pub fn q_bits_overhead(&self) -> f64 {
        self.weighted_mean(|cm| cm.q_bits_overhead)
    }

    /// Mean final activation-aware error across matrices.
    pub fn mean_act_err(&self) -> f64 {
        if self.matrices.is_empty() {
            return 0.0;
        }
        self.matrices.values().map(|m| m.final_act_err).sum::<f64>()
            / self.matrices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn toy_family() -> FamilySpec {
        FamilySpec {
            name: "toy".into(),
            params: vec![
                ("embed".into(), vec![32, 16]),
                ("layer0.ln1".into(), vec![16]),
                ("layer0.wq".into(), vec![16, 16]),
                ("layer0.wk".into(), vec![16, 16]),
                ("layer0.wv".into(), vec![16, 16]),
                ("layer0.wo".into(), vec![16, 16]),
                ("layer0.ln2".into(), vec![16]),
                ("layer0.wgate".into(), vec![24, 16]),
                ("layer0.wup".into(), vec![24, 16]),
                ("layer0.wdown".into(), vec![16, 24]),
                ("ln_f".into(), vec![16]),
                ("unembed".into(), vec![32, 16]),
            ],
            projections: vec![
                "layer0.wq".into(),
                "layer0.wk".into(),
                "layer0.wv".into(),
                "layer0.wo".into(),
                "layer0.wgate".into(),
                "layer0.wup".into(),
                "layer0.wdown".into(),
            ],
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            d_ff: 24,
            n_heads: 4,
            n_kv_heads: 4,
            mlp: "swiglu".into(),
            rope_theta: 10000.0,
        }
    }

    #[test]
    fn init_norms_are_ones() {
        let fam = toy_family();
        let p = ModelParams::init(&fam, 1);
        let g = p.get_matrix("layer0.ln1").unwrap();
        assert!(g.as_slice().iter().all(|&v| v == 1.0));
        let w = p.get_matrix("layer0.wq").unwrap();
        assert!(w.frob_norm() > 0.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let fam = toy_family();
        let p = ModelParams::init(&fam, 2);
        let dir = std::env::temp_dir().join("odlri_test_odw");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.odw");
        p.save(&path).unwrap();
        let q = ModelParams::load(&fam, &path).unwrap();
        for (a, b) in p.values.iter().zip(&q.values) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn outlier_injection_preserves_product() {
        // γ ⊙ x through W must be invariant: (boosted γ, shrunk W) gives
        // the same W @ diag(γ) action.
        let fam = toy_family();
        let mut p = ModelParams::init(&fam, 3);
        let g0 = p.get_matrix("layer0.ln1").unwrap();
        let w0 = p.get_matrix("layer0.wq").unwrap();
        let planted = inject_outliers(&mut p, 2, 16.0, 7).unwrap();
        let g1 = p.get_matrix("layer0.ln1").unwrap();
        let w1 = p.get_matrix("layer0.wq").unwrap();
        // Function-preservation: W1 @ diag(g1) == W0 @ diag(g0).
        let before = w0.mul_diag_right(g0.as_slice());
        let after = w1.mul_diag_right(g1.as_slice());
        assert!(after.rel_err(&before) < 1e-5);
        // And outliers really exist now.
        let (_, channels) = &planted[0];
        assert_eq!(channels.len(), 2);
        for &c in channels {
            assert!(g1.at(0, c) > 8.0);
        }
    }

    #[test]
    fn compressed_model_applies_and_counts_bits() {
        let fam = toy_family();
        let base = ModelParams::init(&fam, 4);
        let mut rng = Pcg64::new(5, 5);
        let mut matrices = BTreeMap::new();
        let plan = MatrixPlan {
            init: crate::coordinator::InitKind::Caldera,
            rank: 4,
            lr_bits: 4,
            q_scheme: "uniform".into(),
            q_bits: 8,
            q_group: 16,
            hadamard: false,
        };
        for name in &fam.projections {
            let shape = fam.param_shape(name).unwrap();
            let w = Matrix::randn(shape[0], shape[1], 0.1, &mut rng);
            use crate::quant::Quantizer as _;
            let out = crate::quant::UniformQuantizer::new(8, 16).quantize(&w);
            let lr = LrPair::zeros(shape[0], shape[1], 4);
            matrices.insert(
                name.clone(),
                CompressedMatrix {
                    q: out.deq,
                    q_packed: out.packed,
                    lr,
                    quant_scale: 0.1,
                    final_act_err: 0.05,
                    plan: plan.clone(),
                    q_bits_overhead: 2.0,
                },
            );
        }
        let cm = CompressedModel {
            family: fam.clone(),
            matrices,
        };
        let applied = cm.apply_to(&base).unwrap();
        // Projections changed, embed untouched.
        assert_ne!(
            applied.get_matrix("layer0.wq").unwrap(),
            base.get_matrix("layer0.wq").unwrap()
        );
        assert_eq!(
            applied.get_matrix("embed").unwrap(),
            base.get_matrix("embed").unwrap()
        );
        let bits = cm.avg_bits();
        assert!(bits > 2.0 && bits < 4.0, "bits={bits}");
        assert!((cm.mean_act_err() - 0.05).abs() < 1e-9);
    }
}
