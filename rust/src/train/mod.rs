//! Training driver: runs the AOT `train_<family>` artifact (Layer-2 AdamW
//! step lowered to HLO) over synthetic-corpus batches, entirely from Rust.
//!
//! This is the end-to-end proof that the three layers compose: Python only
//! authored the computation; the leader process here owns the loop, the
//! data, the optimizer state, and the checkpoints.

use anyhow::{anyhow, Result};

use crate::corpus::{self, Split};
use crate::model::ModelParams;
use crate::runtime::{Value, XlaRuntime};
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub family: String,
    pub steps: usize,
    pub corpus_tokens: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            family: "tl-7s".into(),
            steps: 300,
            corpus_tokens: 400_000,
            seed: 0,
            log_every: 25,
        }
    }
}

/// Result: trained params + the loss curve [(step, loss)].
pub struct TrainResult {
    pub params: ModelParams,
    pub losses: Vec<(usize, f32)>,
}

/// Train a family from scratch. Loss curve is recorded every step (logged
/// every `log_every`).
pub fn train(rt: &XlaRuntime, cfg: &TrainConfig) -> Result<TrainResult> {
    let fam = rt.manifest.family(&cfg.family)?.clone();
    let artifact = format!("train_{}", cfg.family);
    rt.warm(&artifact)?;

    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let data = corpus::generate(Split::Train, cfg.corpus_tokens, cfg.seed);
    let mut rng = Pcg64::new(cfg.seed, 0x7124);

    let params = ModelParams::init(&fam, cfg.seed);
    let n = params.values.len();
    let zeros: Vec<Value> = params
        .values
        .iter()
        .map(|v| {
            let shape = v.shape().to_vec();
            let count = shape.iter().product::<usize>();
            Value::from_vec_f32(shape, vec![0.0; count])
        })
        .collect();

    let mut p = params.values;
    let mut m = zeros.clone();
    let mut v = zeros;
    let mut losses = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        let tokens = corpus::sample_batch(&data, batch, seq + 1, &mut rng);
        let mut inputs = Vec::with_capacity(3 * n + 2);
        inputs.extend(p.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(Value::scalar_f32(step as f32));
        inputs.push(Value::from_vec_i32(vec![batch, seq + 1], tokens));
        let outs = rt.exec(&artifact, &inputs)?;
        if outs.len() != 3 * n + 1 {
            return Err(anyhow!("train artifact arity mismatch"));
        }
        let mut it = outs.into_iter();
        p = (&mut it).take(n).collect();
        m = (&mut it).take(n).collect();
        v = (&mut it).take(n).collect();
        let loss = it.next().unwrap().f32_data()?[0];
        losses.push((step, loss));
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            eprintln!("  [train {}] step {step:4}  loss {loss:.4}", cfg.family);
        }
        if !loss.is_finite() {
            return Err(anyhow!("training diverged at step {step} (loss={loss})"));
        }
    }

    Ok(TrainResult {
        params: ModelParams {
            family: fam,
            values: p,
        },
        losses,
    })
}

#[cfg(test)]
mod tests {
    // Training integration tests live in rust/tests/integration.rs (they
    // need the artifacts directory); this module keeps config sanity only.
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = TrainConfig::default();
        assert!(c.steps > 0 && c.corpus_tokens > 10_000);
    }
}
