//! Generation-first inference API: the [`Engine`] / [`Session`]
//! abstraction every serving, eval, bench, and CLI path runs through.
//!
//! This replaces the PR-1 `eval::Forward` trait (one fixed-shape
//! `batch × seq` scoring entry point) with a request-typed API built for
//! the workload that actually dominates production serving: token-by-token
//! generation over variable-length, per-request sessions.
//!
//! ## Request lifecycle
//!
//! Callers speak typed [`Request`] / [`Response`] values:
//!
//! * [`Request::Score`] — the PR-1 NLL workload. The sequence is run once
//!   through [`Engine::forward_batch`] and answered with the per-position
//!   next-token NLLs ([`Response::Score`]). Equal-length score requests are
//!   batched together by [`score_many`] (real variable batch assembly —
//!   never padded by repeating another request's rows).
//! * [`Request::Generate`] — KV-cached incremental decoding. The prompt is
//!   run once through [`Engine::prefill`], which opens a [`Session`] whose
//!   per-layer K/V history lives in a [`KvCache`]; each subsequent token
//!   costs one [`Engine::decode_step`] over the cache (O(len) per token,
//!   not the O(len²) full re-forward). Sampling is [`Sampling::Greedy`]
//!   (deterministic argmax) or [`Sampling::TopK`] (seeded, reproducible).
//!
//! Generate requests additionally carry a [`Priority`] class
//! (`Interactive` or `Batch`) the scheduler orders admission, preemption,
//! and resume by — see [`crate::serve`] for the fairness spec.
//!
//! ## The trait
//!
//! [`Engine`] is the narrow SPI an inference backend implements:
//! `forward_batch` (uniform-length batched scoring), `prefill` (open a
//! session), `prefill_chunk` (incremental prefill — see below) and
//! `decode_step` (advance a *batch* of sessions by one token each —
//! sessions may sit at different lengths). Three backends ship:
//!
//! * [`NativeEngine`] — dense weights through the pure-Rust transformer in
//!   [`crate::runtime::native`] (the artifact-free path).
//! * [`crate::fused::FusedModel`] — the packed `(Q+LR)·x` deployment form:
//!   every projection of prefill *and* decode goes through the
//!   dequant-on-the-fly fused kernels, so generation serving never
//!   materializes a dense weight matrix.
//! * [`replicas::Replicas`] — N cloned packed models, each with a private
//!   KV pool; sessions are routed to the least-loaded shard and decode
//!   batches run shard-parallel (cheap because low-bit packed weights make
//!   replication nearly free — the paper's deployment regime).
//!
//! ## Chunked prefill
//!
//! [`Engine::prefill`] runs a whole prompt in one call, which would let a
//! long prompt stall every in-flight decode stream for the duration.
//! [`Engine::prefill_chunk`] is the incremental form: each call extends a
//! building [`KvCache`] by a slice of the prompt (`state` threads the
//! cache between calls; progress = `cache.len()`), so the scheduler can
//! interleave decode steps between chunks. The contract is **bit-
//! exactness**: any chunking of a prompt yields the same cache contents,
//! the same final-row logits, and therefore byte-identical greedy streams
//! as the one-shot path. Engines that cannot chunk report
//! `supports_chunked_prefill() == false` and only accept the degenerate
//! whole-prompt call.
//!
//! Both real backends give the guarantee the continuous-batching scheduler
//! in [`crate::serve`] relies on: a session's decode output is independent
//! of which other sessions share the step (all cross-row ops are
//! row-local), and on the native path prefill+decode logits are
//! **bit-identical** to a full-sequence forward.
//!
//! ## Speculative decoding
//!
//! [`speculative::SpeculativeEngine`] wraps a cheap low-bit *draft* engine
//! and an expensive *target* engine over the same tokenizer/family (the
//! paper's regime: a 2-bit aggressive ODLRI plan drafting for a 4-bit
//! budget plan). Per round the draft greedily proposes up to k tokens,
//! [`Engine::verify_step`] scores the pending token plus all proposals in
//! **one** batched causal forward over the target session's cache, the
//! longest agreeing prefix is accepted, and the target's own argmax at the
//! first disagreement becomes the bonus token. Rejected positions are
//! rolled back with [`Session::truncate`] / `KvCache::truncate` on *both*
//! engines, so after any accept/reject sequence the session state — token
//! history and cache bits — is identical to a plain target-only greedy
//! stream. `verify_step`'s contract is therefore bit-exactness with
//! sequential [`Engine::decode_step`] calls (row `i` of its logits equals
//! the decode logits after feeding `tokens[..i]`), and atomicity: on a
//! typed error the session is unchanged. The default implementation *is*
//! the sequential loop (with rollback on error); `NativeEngine` and
//! `FusedModel` override it with a single chunked forward whose per-row
//! arithmetic is exactly the decode step's.
//!
//! Session KV storage is *paged*: both engines draw every session's cache
//! from a process-wide budgeted [`KvPool`] (fixed-size pages, hash-based
//! cross-session prefix sharing, copy-on-write — see
//! [`crate::runtime::kvpool`]). `prefill` adopts any registered identical
//! prompt prefix and registers its own pages afterwards; the budget
//! ([`EngineSpec::kv_budget`], pinned via `with_kv_budget`) surfaces as
//! typed pool-exhaustion errors the scheduler answers with preemption.

pub mod replicas;
pub mod speculative;

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::model::ModelParams;
use crate::runtime::kvpool::{KvPool, PoolStats};
use crate::runtime::native::{
    forward_with, fwd_decode, fwd_prefill, fwd_prefill_chunk, DenseProj, KvCache, ParamView,
};
use crate::runtime::FamilySpec;
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Engine limits the schedulers plan around.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineSpec {
    pub vocab: usize,
    /// Cap on concurrent decode sessions / rows per scoring forward.
    pub max_batch: usize,
    /// Natural scoring window (mirrors the artifact `seq`).
    pub seq: usize,
    /// Hard cap on prompt + generated length per session.
    pub max_context: usize,
    /// KV pool byte budget backing all sessions (0 = unpaged/unbudgeted —
    /// only engines without a paged pool, e.g. test doubles, report 0).
    pub kv_budget: usize,
}

/// One in-flight generation stream: the accepted token history plus the
/// per-layer K/V cache backing incremental decode.
#[derive(Clone, Debug)]
pub struct Session {
    /// Prompt + accepted tokens, in order.
    pub tokens: Vec<i32>,
    pub cache: KvCache,
}

impl Session {
    pub fn new(tokens: Vec<i32>, cache: KvCache) -> Session {
        Session { tokens, cache }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Roll the stream back to its first `n` tokens: the token history
    /// and every KV row past `n` are discarded (pages past the new
    /// length are released). No-op at or below `n` already. Because K
    /// rows are cached post-RoPE at absolute positions, truncate +
    /// re-extend is bit-identical to never having decoded the dropped
    /// suffix — the rollback primitive speculative decoding rests on.
    pub fn truncate(&mut self, n: usize) {
        self.tokens.truncate(n);
        self.cache.truncate(n);
    }
}

/// An inference backend serving scoring forwards and KV-cached sessions.
pub trait Engine: Send + Sync {
    fn spec(&self) -> EngineSpec;

    /// Uniform-length batched scoring forward: `tokens` is a row-major
    /// (batch, seq) block → (batch·seq, vocab) logits.
    fn forward_batch(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<Matrix>;

    /// Open a session: run the prompt once, filling the session's KV
    /// cache; returns the session plus the full (prompt_len, vocab) logits.
    fn prefill(&self, tokens: &[i32]) -> Result<(Session, Matrix)>;

    /// Whether [`prefill_chunk`](Engine::prefill_chunk) accepts partial
    /// prompts. Engines answering `false` (the default) only serve the
    /// degenerate whole-prompt chunk, and the scheduler falls back to
    /// one-shot prefill for them.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Incrementally prefill `prompt[..upto]`: extend the building cache in
    /// `state` (created, with prefix adoption, on the first call) from its
    /// current `len()` to `upto` positions, returning the new slice's
    /// logits. When `upto == prompt.len()` the prompt's pages are
    /// registered for prefix sharing and `state` holds a cache
    /// interchangeable with [`prefill`](Engine::prefill)'s — **bit-exactly**,
    /// for any chunking. On a typed error (pool exhausted) the cache keeps
    /// its pre-call extent and the chunk can be retried.
    ///
    /// The default implementation serves only the degenerate whole-prompt
    /// call by delegating to one-shot `prefill`.
    fn prefill_chunk(
        &self,
        prompt: &[i32],
        state: &mut Option<KvCache>,
        upto: usize,
    ) -> Result<Matrix> {
        if state.is_some() || upto != prompt.len() {
            bail!("engine does not support incremental prefill chunks");
        }
        let (session, logits) = self.prefill(prompt)?;
        *state = Some(session.cache);
        Ok(logits)
    }

    /// Advance a batch of sessions by one token each: `tokens[i]` is
    /// appended to `sessions[i]`; row `i` of the returned (n, vocab) matrix
    /// holds that session's next-token logits. Sessions may sit at
    /// different lengths.
    fn decode_step(&self, sessions: &mut [&mut Session], tokens: &[i32]) -> Result<Matrix>;

    /// Score a whole candidate chunk against one session in a single
    /// causal forward: `tokens` are appended to the session and row `i`
    /// of the returned (n, vocab) matrix holds the next-token logits
    /// after `tokens[..=i]` — **bit-identical** to feeding the tokens
    /// through [`decode_step`](Engine::decode_step) one at a time. This
    /// is speculative decoding's verify primitive: one batched target
    /// step scores the pending token plus every draft proposal, and the
    /// caller rolls rejected rows back via [`Session::truncate`].
    ///
    /// Atomicity: on a typed error (pool exhausted / context overflow)
    /// the session is left at its pre-call extent.
    ///
    /// The default implementation is the sequential decode loop itself
    /// (trivially exact); real backends override it with one chunked
    /// forward sharing the decode path's per-row arithmetic.
    fn verify_step(&self, session: &mut Session, tokens: &[i32]) -> Result<Matrix> {
        if tokens.is_empty() {
            bail!("verify step needs at least one token");
        }
        let start = session.tokens.len();
        let mut out = Matrix::zeros(tokens.len(), self.spec().vocab);
        for (i, &t) in tokens.iter().enumerate() {
            match self.decode_step(&mut [&mut *session], &[t]) {
                Ok(lg) => out.row_mut(i).copy_from_slice(lg.row(0)),
                Err(e) => {
                    session.truncate(start);
                    return Err(e);
                }
            }
        }
        Ok(out)
    }

    /// Packed weight bytes the backend streams per decode step (the
    /// dequant-on-the-fly working set) — `Some` only for engines serving
    /// packed weights. Drives the CLI's decode weight-throughput (packed
    /// GB/s) report, which turns per-token latencies into a number that is
    /// comparable across bit-widths and schemes.
    fn decode_weight_bytes(&self) -> Option<usize> {
        None
    }

    /// Occupancy/sharing snapshot of the paged KV pool — `Some` for
    /// engines whose sessions draw from a [`KvPool`]. Drives the
    /// scheduler's admission sanity checks and the CLI pool-stats line.
    fn pool_stats(&self) -> Option<PoolStats> {
        None
    }

    /// Take one live shard out of service (failover drill / fault
    /// injection), returning its index. Multi-shard engines pick a victim
    /// from `selector` and refuse to quarantine their last surviving
    /// shard; single-shard backends (the default) have nothing to
    /// quarantine and answer `None`.
    fn quarantine_one_shard(&self, _selector: u64) -> Option<usize> {
        None
    }

    /// Whether `cache` draws from a quarantined shard's pool. Orphaned
    /// sessions must not decode again until the scheduler migrates them
    /// (re-prefills their token history on a surviving shard); a decode
    /// attempt surfaces a typed
    /// [`KvError::ReplicaFailed`](crate::runtime::kvpool::KvError).
    fn cache_orphaned(&self, _cache: &KvCache) -> bool {
        false
    }

    /// Pools of quarantined shards (empty for single-pool engines). The
    /// scheduler's debug auditor asserts each drains to zero referenced
    /// pages once its sessions have migrated.
    fn quarantined_pools(&self) -> Vec<KvPool> {
        Vec::new()
    }
}

// ------------------------------------------------------------ requests

/// Token selection policy for generation.
#[derive(Clone, Debug, PartialEq)]
pub enum Sampling {
    /// Deterministic argmax (ties break to the lowest token id).
    Greedy,
    /// Sample from the renormalized top-k logits at `temperature`,
    /// reproducibly seeded.
    TopK { k: usize, temperature: f32, seed: u64 },
}

/// Scheduling class of a generate request. Declaration order is urgency
/// order: the scheduler admits and resumes `Interactive` work before
/// `Batch`, and preempts `Batch` work first, while staying FIFO *within*
/// each class (see [`crate::serve`] for the full fairness spec).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic (the default).
    #[default]
    Interactive,
    /// Throughput traffic that tolerates queueing and preemption.
    Batch,
}

impl Priority {
    pub const COUNT: usize = 2;

    /// Dense index (0 = most urgent), for per-class tables.
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "Interactive",
            Priority::Batch => "Batch",
        }
    }

    pub fn from_index(i: usize) -> Priority {
        match i {
            0 => Priority::Interactive,
            _ => Priority::Batch,
        }
    }
}

/// A typed serving request.
#[derive(Clone, Debug)]
pub enum Request {
    /// Score a full sequence: answered with per-position next-token NLLs.
    Score { tokens: Vec<i32> },
    /// Generate up to `max_new_tokens` continuation tokens from `prompt`
    /// via KV-cached incremental decoding, scheduled under `priority`.
    Generate {
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: Sampling,
        priority: Priority,
        /// Scheduler-tick deadline: a request still unfinished this many
        /// ticks after it was enqueued is answered with
        /// [`Response::TimedOut`] and its pages are released. `0` = no
        /// deadline (the historical behavior).
        deadline_ticks: usize,
    },
}

/// The matching response.
#[derive(Clone, Debug)]
pub enum Response {
    /// `nlls[t]` = −log p(tokens[t+1] | tokens[..=t]); length = len − 1.
    Score { nlls: Vec<f64> },
    /// Generated continuation (prompt excluded) plus per-decode-step wall
    /// latencies (empty when the engine answered from prefill alone).
    Generated {
        prompt_len: usize,
        tokens: Vec<i32>,
        step_latencies_s: Vec<f64>,
    },
    /// The request itself was invalid or can never be served (empty
    /// prompt, context overflow, a prompt larger than the whole KV pool).
    /// A per-request refusal, not a server failure: the scheduler answers
    /// the offending request and keeps serving everyone else. Where the
    /// cause is a typed [`KvError`](crate::runtime::kvpool::KvError), the
    /// message leads with its stable tag so `KvError::is_*` classification
    /// works on it.
    Rejected { error: String },
    /// The request's `deadline_ticks` elapsed before it finished. Its
    /// session state (queue slot, partial prefill, KV pages) has been
    /// released; partial output is discarded.
    TimedOut,
    /// The bounded admission queue was full and this request was shed to
    /// protect latency — always the youngest `Batch`-class work first;
    /// `Interactive` work is only shed when no `Batch` victim exists.
    Shed,
    /// The client went away (responder dropped, or an injected abort) and
    /// the stream was retired mid-flight; its pages are released.
    Aborted,
}

// ------------------------------------------------------------- sampling

/// Index of the largest logit; ties break to the lowest index.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Stateful token sampler (owns the RNG stream for top-k).
pub struct Sampler {
    sampling: Sampling,
    rng: Pcg64,
}

impl Sampler {
    pub fn new(sampling: Sampling) -> Sampler {
        let seed = match &sampling {
            Sampling::TopK { seed, .. } => *seed,
            Sampling::Greedy => 0,
        };
        Sampler {
            sampling,
            rng: Pcg64::new(seed, 0x5A11),
        }
    }

    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        match &self.sampling {
            Sampling::Greedy => argmax(logits) as i32,
            Sampling::TopK { k, temperature, .. } => {
                let k = (*k).clamp(1, logits.len());
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                // Descending by logit, ascending index on ties (stable pick).
                idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
                idx.truncate(k);
                let t = temperature.max(1e-6);
                let mx = logits[idx[0]];
                let ps: Vec<f64> = idx
                    .iter()
                    .map(|&i| (((logits[i] - mx) / t) as f64).exp())
                    .collect();
                let total: f64 = ps.iter().sum();
                let mut u = self.rng.uniform() * total;
                for (j, &i) in idx.iter().enumerate() {
                    u -= ps[j];
                    if u <= 0.0 {
                        return i as i32;
                    }
                }
                idx[k - 1] as i32
            }
        }
    }
}

// ------------------------------------------------------------- scoring

/// Log-softmax NLL of `target` under a logits row (f64 for stability).
pub fn nll_of(logits_row: &[f32], target: usize) -> f64 {
    let mx = logits_row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse: f64 = logits_row
        .iter()
        .map(|&v| ((v as f64) - mx).exp())
        .sum::<f64>()
        .ln()
        + mx;
    lse - logits_row[target] as f64
}

/// Score many sequences with real variable batch assembly: equal-length
/// sequences share one forward (up to `max_batch` rows), ragged lengths
/// each get their own — nothing is ever padded by repeating another
/// request. Returns per-sequence next-token NLL vectors (length len − 1).
pub fn score_many(engine: &dyn Engine, seqs: &[Vec<i32>]) -> Result<Vec<Vec<f64>>> {
    let max_batch = engine.spec().max_batch.max(1);
    let mut out: Vec<Vec<f64>> = vec![Vec::new(); seqs.len()];
    let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, s) in seqs.iter().enumerate() {
        if s.len() > 1 {
            groups.entry(s.len()).or_default().push(i);
        }
    }
    for (len, idxs) in groups {
        for chunk in idxs.chunks(max_batch) {
            let mut toks = Vec::with_capacity(chunk.len() * len);
            for &i in chunk {
                toks.extend_from_slice(&seqs[i]);
            }
            let logits = engine.forward_batch(&toks, chunk.len(), len)?;
            if logits.rows() != chunk.len() * len {
                bail!(
                    "engine returned {} logit rows for {} tokens",
                    logits.rows(),
                    chunk.len() * len
                );
            }
            for (bi, &i) in chunk.iter().enumerate() {
                let mut nlls = Vec::with_capacity(len - 1);
                for t in 0..len - 1 {
                    nlls.push(nll_of(logits.row(bi * len + t), seqs[i][t + 1] as usize));
                }
                out[i] = nlls;
            }
        }
    }
    Ok(out)
}

// ----------------------------------------------------------- generation

/// Result of a single generation run.
#[derive(Clone, Debug)]
pub struct GenOutput {
    pub prompt_len: usize,
    /// Generated tokens (prompt excluded).
    pub tokens: Vec<i32>,
    /// Prompt prefill wall time.
    pub prefill_s: f64,
    /// Wall time of each incremental decode step.
    pub step_latencies_s: Vec<f64>,
}

/// Drive one session end to end: prefill the prompt, then decode
/// token-by-token against the KV cache until `max_new_tokens` (clamped to
/// the engine's context budget) tokens exist.
pub fn generate(
    engine: &dyn Engine,
    prompt: &[i32],
    max_new_tokens: usize,
    sampling: Sampling,
) -> Result<GenOutput> {
    let spec = engine.spec();
    if prompt.is_empty() {
        bail!("generate needs a non-empty prompt");
    }
    if prompt.len() >= spec.max_context {
        bail!(
            "prompt length {} exceeds the engine context budget {}",
            prompt.len(),
            spec.max_context
        );
    }
    let budget = max_new_tokens.min(spec.max_context - prompt.len());
    let t0 = Instant::now();
    let (mut session, logits) = engine.prefill(prompt)?;
    let prefill_s = t0.elapsed().as_secs_f64();
    let mut sampler = Sampler::new(sampling);
    let mut tokens = Vec::with_capacity(budget);
    let mut steps = Vec::new();
    if budget > 0 {
        let mut next = sampler.sample(logits.row(logits.rows() - 1));
        tokens.push(next);
        while tokens.len() < budget {
            let ts = Instant::now();
            let lg = engine.decode_step(&mut [&mut session], &[next])?;
            steps.push(ts.elapsed().as_secs_f64());
            next = sampler.sample(lg.row(0));
            tokens.push(next);
        }
    }
    Ok(GenOutput {
        prompt_len: prompt.len(),
        tokens,
        prefill_s,
        step_latencies_s: steps,
    })
}

/// Answer one typed request (the single-request path; the continuous
/// batching scheduler in [`crate::serve`] multiplexes many).
pub fn process(engine: &dyn Engine, req: &Request) -> Result<Response> {
    match req {
        Request::Score { tokens } => {
            let nlls = score_many(engine, std::slice::from_ref(tokens))?
                .pop()
                .unwrap_or_default();
            Ok(Response::Score { nlls })
        }
        Request::Generate {
            prompt,
            max_new_tokens,
            sampling,
            ..
        } => {
            let g = generate(engine, prompt, *max_new_tokens, sampling.clone())?;
            Ok(Response::Generated {
                prompt_len: g.prompt_len,
                tokens: g.tokens,
                step_latencies_s: g.step_latencies_s,
            })
        }
    }
}

// -------------------------------------------------------- native engine

/// Dense-weight engine over the pure-Rust native transformer: parameters
/// are resolved to matrices once at construction, every call borrows them
/// (no per-request parameter copies).
pub struct NativeEngine {
    fam: FamilySpec,
    mats: Vec<Matrix>,
    max_batch: usize,
    seq: usize,
    max_context: usize,
    /// Paged KV pool all sessions draw from (prefix sharing + budget).
    pool: KvPool,
    /// True once `with_kv_budget` pinned an explicit budget (context
    /// changes then keep it instead of re-deriving a default).
    explicit_budget: bool,
}

impl NativeEngine {
    /// `max_batch`/`seq` mirror the runtime manifest's block shape (they
    /// bound scheduler batches, not individual sequence lengths).
    pub fn new(params: &ModelParams, max_batch: usize, seq: usize) -> Result<NativeEngine> {
        let mats = params
            .values
            .iter()
            .map(|v| v.to_matrix())
            .collect::<Result<Vec<_>>>()?;
        let seq = seq.max(2);
        let max_batch = max_batch.max(1);
        let max_context = 4 * seq;
        let fam = params.family.clone();
        let pool = KvPool::with_default_budget(fam.n_layers, fam.kv_dim(), max_context, max_batch);
        Ok(NativeEngine {
            fam,
            mats,
            max_batch,
            seq,
            max_context,
            pool,
            explicit_budget: false,
        })
    }

    /// Override the per-session context budget (re-derives the default
    /// pool budget for the new context unless one was pinned explicitly).
    pub fn with_max_context(mut self, n: usize) -> NativeEngine {
        self.max_context = n.max(self.seq);
        if !self.explicit_budget {
            self.pool = KvPool::with_default_budget(
                self.fam.n_layers,
                self.fam.kv_dim(),
                self.max_context,
                self.max_batch,
            );
        }
        self
    }

    /// Pin a hard KV pool byte budget (the `--kv-budget` knob). Sessions
    /// beyond the budget are preempted by the serving scheduler rather
    /// than allocated. Errors if the budget holds less than one page.
    pub fn with_kv_budget(mut self, bytes: usize) -> Result<NativeEngine> {
        self.pool = KvPool::new(
            self.fam.n_layers,
            self.fam.kv_dim(),
            crate::runtime::kvpool::DEFAULT_PAGE_TOKENS,
            bytes,
        )?;
        self.explicit_budget = true;
        Ok(self)
    }

    fn view(&self) -> Result<ParamView<'_>> {
        ParamView::from_slice(&self.fam, &self.mats)
    }
}

impl Engine for NativeEngine {
    fn spec(&self) -> EngineSpec {
        EngineSpec {
            vocab: self.fam.vocab,
            max_batch: self.max_batch,
            seq: self.seq,
            max_context: self.max_context,
            kv_budget: self.pool.budget_bytes(),
        }
    }

    fn forward_batch(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<Matrix> {
        let view = self.view()?;
        forward_with(&self.fam, &view, &DenseProj { view: &view }, tokens, batch, seq, None)
    }

    fn prefill(&self, tokens: &[i32]) -> Result<(Session, Matrix)> {
        let view = self.view()?;
        // Paged session: adopt any registered identical prefix (storage
        // only — the forward still computes every position, so the
        // returned logits keep the full-forward bit-identity), then
        // publish this prompt's pages for later sessions.
        let mut cache = KvCache::paged(&self.pool, self.max_context);
        cache.adopt_prefix(tokens);
        let logits =
            fwd_prefill(&self.fam, &view, &DenseProj { view: &view }, tokens, &mut cache)?;
        cache.register_prefix(tokens);
        Ok((Session::new(tokens.to_vec(), cache), logits))
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(
        &self,
        prompt: &[i32],
        state: &mut Option<KvCache>,
        upto: usize,
    ) -> Result<Matrix> {
        let view = self.view()?;
        let cache = state.get_or_insert_with(|| {
            let mut c = KvCache::paged(&self.pool, self.max_context);
            c.adopt_prefix(prompt);
            c
        });
        let done = cache.len();
        if upto <= done || upto > prompt.len() {
            bail!(
                "prefill chunk target {upto} outside ({done}, {}]",
                prompt.len()
            );
        }
        let logits = fwd_prefill_chunk(
            &self.fam,
            &view,
            &DenseProj { view: &view },
            &prompt[done..upto],
            cache,
        )?;
        if upto == prompt.len() {
            cache.register_prefix(prompt);
        }
        Ok(logits)
    }

    fn decode_step(&self, sessions: &mut [&mut Session], tokens: &[i32]) -> Result<Matrix> {
        if sessions.len() != tokens.len() {
            bail!(
                "decode step: {} tokens for {} sessions",
                tokens.len(),
                sessions.len()
            );
        }
        let view = self.view()?;
        let logits = {
            let mut caches: Vec<&mut KvCache> =
                sessions.iter_mut().map(|s| &mut s.cache).collect();
            fwd_decode(
                &self.fam,
                &view,
                &DenseProj { view: &view },
                tokens,
                &mut caches,
            )?
        };
        for (s, &t) in sessions.iter_mut().zip(tokens) {
            s.tokens.push(t);
        }
        Ok(logits)
    }

    fn verify_step(&self, session: &mut Session, tokens: &[i32]) -> Result<Matrix> {
        if tokens.is_empty() {
            bail!("verify step needs at least one token");
        }
        let view = self.view()?;
        // One chunked causal forward over the session's cache. Its per-row
        // arithmetic (RoPE at absolute positions, causal softmax op order,
        // row-local dense projections) is exactly fwd_decode's, so each
        // row is bit-identical to a sequential decode step; capacity is
        // reserved before compute, so a typed failure leaves the session
        // untouched.
        let logits = fwd_prefill_chunk(
            &self.fam,
            &view,
            &DenseProj { view: &view },
            tokens,
            &mut session.cache,
        )?;
        session.tokens.extend_from_slice(tokens);
        Ok(logits)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.pool.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_engine(seed: u64) -> NativeEngine {
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, seed);
        NativeEngine::new(&params, 3, 8).unwrap()
    }

    fn micro_tokens(vocab: usize, len: usize, seed: u64) -> Vec<i32> {
        let mut rng = Pcg64::new(seed, 77);
        (0..len).map(|_| rng.below(vocab) as i32).collect()
    }

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.0, 2.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-1.0, -3.0]), 0);
    }

    #[test]
    fn greedy_sampler_is_argmax() {
        let mut s = Sampler::new(Sampling::Greedy);
        assert_eq!(s.sample(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(s.sample(&[3.0, 0.9, 0.5]), 0);
    }

    #[test]
    fn topk_sampler_stays_in_top_k_and_is_seeded() {
        let logits = vec![0.0f32, 5.0, 4.5, -2.0, 4.9, 0.2];
        let allowed = [1usize, 2, 4];
        let mut a = Sampler::new(Sampling::TopK {
            k: 3,
            temperature: 1.0,
            seed: 7,
        });
        let mut b = Sampler::new(Sampling::TopK {
            k: 3,
            temperature: 1.0,
            seed: 7,
        });
        for _ in 0..50 {
            let ta = a.sample(&logits);
            let tb = b.sample(&logits);
            assert_eq!(ta, tb, "same seed must replay the same stream");
            assert!(allowed.contains(&(ta as usize)), "token {ta} not in top-3");
        }
        // k = 1 degenerates to greedy.
        let mut g = Sampler::new(Sampling::TopK {
            k: 1,
            temperature: 0.5,
            seed: 1,
        });
        assert_eq!(g.sample(&logits), 1);
    }

    #[test]
    fn score_many_matches_direct_forward_nll() {
        let engine = micro_engine(3);
        let vocab = engine.spec().vocab;
        // Mixed lengths: 5, 5, 3 — the two 5s share one forward.
        let seqs = vec![
            micro_tokens(vocab, 5, 1),
            micro_tokens(vocab, 5, 2),
            micro_tokens(vocab, 3, 3),
        ];
        let nlls = score_many(&engine, &seqs).unwrap();
        assert_eq!(nlls[0].len(), 4);
        assert_eq!(nlls[2].len(), 2);
        for (s, n) in seqs.iter().zip(&nlls) {
            let logits = engine.forward_batch(s, 1, s.len()).unwrap();
            for (t, &got) in n.iter().enumerate() {
                let want = nll_of(logits.row(t), s[t + 1] as usize);
                assert!((got - want).abs() < 1e-12, "t={t}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn generate_greedy_matches_manual_argmax_rollout() {
        let engine = micro_engine(4);
        let vocab = engine.spec().vocab;
        let prompt = micro_tokens(vocab, 4, 9);
        let out = generate(&engine, &prompt, 5, Sampling::Greedy).unwrap();
        assert_eq!(out.tokens.len(), 5);
        assert_eq!(out.prompt_len, 4);
        assert_eq!(out.step_latencies_s.len(), 4);
        // Manual rollout through full-sequence forwards must agree (the
        // KV path is bit-identical to the full forward).
        let mut history = prompt.clone();
        for &tok in &out.tokens {
            let logits = engine
                .forward_batch(&history, 1, history.len())
                .unwrap();
            let want = argmax(logits.row(history.len() - 1)) as i32;
            assert_eq!(tok, want, "divergence at position {}", history.len());
            history.push(tok);
        }
    }

    #[test]
    fn generate_respects_context_budget() {
        let engine = micro_engine(5).with_max_context(10);
        let prompt = micro_tokens(11, 6, 1);
        let out = generate(&engine, &prompt, 100, Sampling::Greedy).unwrap();
        assert_eq!(out.tokens.len(), 4, "budget = max_context - prompt_len");
        assert!(generate(&engine, &[1i32; 10], 1, Sampling::Greedy).is_err());
        assert!(generate(&engine, &[], 1, Sampling::Greedy).is_err());
    }

    #[test]
    fn paged_pool_budget_is_enforced_and_preserves_generation() {
        use crate::runtime::kvpool::KvError;
        // micro family: 1 layer × kv_dim 4 × 16-token pages = 512 B/page.
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, 7);
        let reference = NativeEngine::new(&params, 3, 8).unwrap();
        let tight = NativeEngine::new(&params, 3, 8)
            .unwrap()
            .with_kv_budget(512)
            .unwrap();
        assert_eq!(tight.spec().kv_budget, 512);
        assert!(reference.spec().kv_budget > 512, "default budget too small");
        let prompt = micro_tokens(11, 6, 3);
        // One page (16 positions) fits prompt 6 + 4 new: identical stream.
        let a = generate(&reference, &prompt, 4, Sampling::Greedy).unwrap();
        let b = generate(&tight, &prompt, 4, Sampling::Greedy).unwrap();
        assert_eq!(a.tokens, b.tokens, "budget changed the decoded stream");
        // A 20-token prompt needs 2 pages — typed pool exhaustion, not a
        // panic or over-allocation.
        let long = micro_tokens(11, 20, 4);
        let err = generate(&tight, &long, 1, Sampling::Greedy).unwrap_err();
        assert!(KvError::is_pool_exhausted(&err), "got: {err:#}");
        let stats = tight.pool_stats().unwrap();
        assert!(stats.resident_pages <= stats.max_pages, "over-allocated");
        assert_eq!(stats.max_pages, 1);
    }

    #[test]
    fn priority_orders_interactive_before_batch() {
        assert!(Priority::Interactive < Priority::Batch);
        assert_eq!(Priority::default(), Priority::Interactive);
        assert_eq!(Priority::Interactive.index(), 0);
        assert_eq!(Priority::Batch.index(), 1);
        for i in 0..Priority::COUNT {
            assert_eq!(Priority::from_index(i).index(), i);
        }
    }

    #[test]
    fn engine_prefill_chunks_match_one_shot_prefill() {
        // The trait-level chunk API: chunked prefill through NativeEngine
        // must hand back a session cache whose greedy continuation is
        // byte-identical to the one-shot path, and the final chunk's last
        // row must equal the one-shot last-row logits bit-for-bit.
        let engine = micro_engine(8);
        let vocab = engine.spec().vocab;
        let prompt = micro_tokens(vocab, 9, 31);
        let (mut one_session, one_logits) = engine.prefill(&prompt).unwrap();
        for split in [vec![4usize, 5], vec![2, 2, 2, 3], vec![9]] {
            let mut state = None;
            let mut done = 0usize;
            let mut last = None;
            for &m in &split {
                last = Some(engine.prefill_chunk(&prompt, &mut state, done + m).unwrap());
                done += m;
            }
            let last = last.unwrap();
            let lrow = last.row(last.rows() - 1);
            let orow = one_logits.row(one_logits.rows() - 1);
            assert_eq!(lrow, orow, "split {split:?} final-row logits diverged");
            let mut session = Session::new(prompt.clone(), state.take().unwrap());
            assert_eq!(session.cache.len(), prompt.len());
            let next = argmax(orow) as i32;
            let a = engine.decode_step(&mut [&mut one_session], &[next]).unwrap();
            let b = engine.decode_step(&mut [&mut session], &[next]).unwrap();
            assert_eq!(a.row(0), b.row(0), "split {split:?} decode diverged");
            // Rewind the one-shot session for the next split: re-prefill.
            let (s, _) = engine.prefill(&prompt).unwrap();
            one_session = s;
        }
        // Out-of-range targets are refused without touching the cache.
        let mut state = None;
        engine.prefill_chunk(&prompt, &mut state, 4).unwrap();
        assert!(engine.prefill_chunk(&prompt, &mut state, 4).is_err());
        assert!(engine.prefill_chunk(&prompt, &mut state, prompt.len() + 1).is_err());
        assert_eq!(state.as_ref().unwrap().len(), 4);
    }

    #[test]
    fn process_answers_typed_requests() {
        let engine = micro_engine(6);
        let toks = micro_tokens(11, 6, 2);
        match process(&engine, &Request::Score { tokens: toks.clone() }).unwrap() {
            Response::Score { nlls } => {
                assert_eq!(nlls.len(), 5);
                assert!(nlls.iter().all(|v| v.is_finite() && *v > 0.0));
            }
            other => panic!("wrong response: {other:?}"),
        }
        let req = Request::Generate {
            prompt: toks[..3].to_vec(),
            max_new_tokens: 4,
            sampling: Sampling::Greedy,
            priority: Priority::default(),
            deadline_ticks: 0,
        };
        match process(&engine, &req).unwrap() {
            Response::Generated {
                prompt_len, tokens, ..
            } => {
                assert_eq!(prompt_len, 3);
                assert_eq!(tokens.len(), 4);
                assert!(tokens.iter().all(|&t| (t as usize) < 11));
            }
            other => panic!("wrong response: {other:?}"),
        }
    }
}
