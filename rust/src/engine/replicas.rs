//! Multi-replica serving engine: N clones of one packed [`FusedModel`],
//! each with a **private KV pool**, behind the single [`Engine`] SPI the
//! scheduler already speaks.
//!
//! ## Why replicas
//!
//! In the paper's regime the deployed artifact is 2–4-bit `Q` plus a
//! skinny `L·R` correction — replicating the weights is nearly free, so
//! the way to scale serving is N cheap replicas rather than one big
//! engine. What is *not* free is KV memory: each shard owns an
//! independent budgeted pool ([`FusedModel::fork_replica`]), so shards
//! never contend on pages and prefix sharing stays shard-local.
//!
//! ## Invariants
//!
//! * **Shard-independence**: all shards hold bit-identical weights, and a
//!   session's [`KvCache`] carries its own pool handle — so any shard's
//!   kernels can serve any session's compute, and a session's output is
//!   independent of which shard hosts it (tested below).
//! * **Routing**: a *new* session (one-shot prefill or the first chunk of
//!   an incremental prefill) goes to the shard with the fewest resident
//!   pages — least-loaded-first keeps the per-shard pools balanced.
//!   Continuation chunks and decode steps read the shard choice out of
//!   the cache itself.
//! * **Decode batching**: a decode batch is split into contiguous
//!   sub-batches of at most one shard's `max_batch` rows, dispatched to
//!   worker threads (one per shard), and the logits are stitched back in
//!   order. Sub-batch size never exceeds the decode-kernel dispatch
//!   threshold, so the specialized fused dequant-dot path keeps running.
//!   Capacity for the *whole* batch is reserved before any dispatch
//!   ([`ensure_decode_capacity`]) — a typed pool error surfaces with no
//!   session mutated, exactly like the single-engine step.
//! * **Aggregation**: [`Engine::pool_stats`] sums occupancy and sharing
//!   counters across live shards (geometry from the first live one), so
//!   the serve-bench pool line reports fleet totals.
//! * **Failover**: a shard can be **quarantined**
//!   ([`Engine::quarantine_one_shard`]) — it stops taking new sessions
//!   and new compute, and any decode touching a session whose cache draws
//!   from its pool surfaces a typed
//!   [`KvError::ReplicaFailed`] *before* any capacity is reserved or any
//!   session mutated. The scheduler answers by migrating orphans through
//!   the ordinary preempt/resume path (re-prefill from token history on a
//!   surviving shard — bit-exact, because weights are identical
//!   everywhere). The last live shard can never be quarantined.

use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{anyhow, bail, Result};

use crate::fused::FusedModel;
use crate::runtime::kvpool::{KvError, KvPool, PoolStats};
use crate::runtime::native::{ensure_decode_capacity, KvCache};
use crate::tensor::Matrix;

use super::{Engine, EngineSpec, Session};

/// N packed replicas behind one [`Engine`].
pub struct Replicas {
    shards: Vec<FusedModel>,
    /// Quarantine flags, index = shard id. Relaxed ordering is enough:
    /// flags only ever flip false → true, and every consumer treats a
    /// stale read as "still live", which at worst delays the typed
    /// failover by one consult.
    down: Vec<AtomicBool>,
}

impl Replicas {
    /// Shard 0 is `base` itself (keeping its pool); shards 1..n are
    /// [`FusedModel::fork_replica`] clones with fresh pools of the same
    /// geometry. `n` is clamped to at least 1.
    pub fn new(base: FusedModel, n: usize) -> Replicas {
        let n = n.max(1);
        let mut shards = Vec::with_capacity(n);
        for _ in 1..n {
            shards.push(base.fork_replica());
        }
        shards.insert(0, base);
        let down = (0..shards.len()).map(|_| AtomicBool::new(false)).collect();
        Replicas { shards, down }
    }

    fn is_down(&self, shard: usize) -> bool {
        self.down[shard].load(Ordering::Relaxed)
    }

    /// Indices of live (non-quarantined) shards, in order. Never empty:
    /// `quarantine_one_shard` refuses to take down the last survivor.
    fn live(&self) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| !self.is_down(i))
            .collect()
    }

    /// First live shard (for continuation compute that only needs the
    /// shared weights); falls back to shard 0 if somehow none is live.
    fn first_live(&self) -> &FusedModel {
        (0..self.shards.len())
            .find(|&i| !self.is_down(i))
            .map(|i| &self.shards[i])
            .unwrap_or(&self.shards[0])
    }

    /// Which shard's pool backs `cache`, if any (flat caches and foreign
    /// pools answer `None`).
    fn shard_of(&self, cache: &KvCache) -> Option<usize> {
        let (pool, _) = cache.pool_and_table()?;
        self.shards.iter().position(|s| s.pool().ptr_eq(pool))
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard pool snapshots (index = shard id), for load reporting.
    pub fn shard_stats(&self) -> Vec<PoolStats> {
        self.shards
            .iter()
            // lint:allow(hot-path-panic) every shard is a FusedModel, whose pool_stats() is always Some
            .map(|s| s.pool_stats().expect("fused shards always have a pool"))
            .collect()
    }

    /// Least-loaded routing among **live** shards: the one with the
    /// fewest resident pages (ties to the lowest index).
    fn route(&self) -> &FusedModel {
        self.live()
            .into_iter()
            .map(|i| &self.shards[i])
            .min_by_key(|s| {
                s.pool_stats()
                    .map(|p| p.resident_pages)
                    .unwrap_or(usize::MAX)
            })
            // lint:allow(hot-path-panic) quarantine_one_shard never takes down the last live shard, so live() is never empty
            .expect("at least one live shard")
    }
}

impl Engine for Replicas {
    fn spec(&self) -> EngineSpec {
        let one = self.shards[0].spec();
        EngineSpec {
            vocab: one.vocab,
            max_batch: one.max_batch * self.shards.len(),
            seq: one.seq,
            max_context: one.max_context,
            kv_budget: one.kv_budget * self.shards.len(),
        }
    }

    fn forward_batch(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<Matrix> {
        self.shards[0].forward_batch(tokens, batch, seq)
    }

    fn decode_weight_bytes(&self) -> Option<usize> {
        self.shards[0].decode_weight_bytes()
    }

    fn prefill(&self, tokens: &[i32]) -> Result<(Session, Matrix)> {
        self.route().prefill(tokens)
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(
        &self,
        prompt: &[i32],
        state: &mut Option<KvCache>,
        upto: usize,
    ) -> Result<Matrix> {
        // The first chunk picks the session's shard (its cache draws from
        // that shard's pool); continuation chunks only need weights, which
        // are bit-identical everywhere, so any live shard serves them.
        let shard = if state.is_none() {
            self.route()
        } else {
            self.first_live()
        };
        shard.prefill_chunk(prompt, state, upto)
    }

    fn decode_step(&self, sessions: &mut [&mut Session], tokens: &[i32]) -> Result<Matrix> {
        let n = sessions.len();
        if n != tokens.len() {
            bail!("decode step: {} tokens for {} sessions", tokens.len(), n);
        }
        if n == 0 {
            bail!("decode step needs at least one session");
        }
        let vocab = self.shards[0].spec().vocab;
        let sub = self.shards[0].spec().max_batch.max(1);
        // Sessions hosted by a quarantined shard surface the typed
        // failover error before anything is reserved or mutated — the
        // scheduler migrates them and retries on a survivor.
        for s in sessions.iter() {
            if let Some(shard) = self.shard_of(&s.cache) {
                if self.is_down(shard) {
                    return Err(KvError::ReplicaFailed { shard }.into());
                }
            }
        }
        // All-or-nothing capacity across the whole batch before any shard
        // runs: a typed pool/context error here mutates nothing.
        {
            let mut caches: Vec<&mut KvCache> =
                sessions.iter_mut().map(|s| &mut s.cache).collect();
            ensure_decode_capacity(&mut caches)?;
        }
        let live = self.live();
        let groups: Vec<(&mut [&mut Session], &[i32])> = sessions
            .chunks_mut(sub)
            .zip(tokens.chunks(sub))
            .collect();
        let results: Vec<Result<Matrix>> = std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .into_iter()
                .enumerate()
                .map(|(gi, (group, toks))| {
                    let shard = &self.shards[live[gi % live.len()]];
                    scope.spawn(move || shard.decode_step(group, toks))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("decode worker thread panicked")))
                })
                .collect()
        });
        let mut logits = Matrix::zeros(n, vocab);
        let mut row = 0usize;
        for r in results {
            let part = r?;
            for i in 0..part.rows() {
                logits.row_mut(row).copy_from_slice(part.row(i));
                row += 1;
            }
        }
        debug_assert_eq!(row, n, "stitched logits row count");
        Ok(logits)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        // Quarantined shards no longer contribute capacity: admission
        // sizing (max_pages) must reflect what survivors can actually
        // hold, or a failed-over prompt could be admitted unservably.
        let mut agg = PoolStats::default();
        let mut first = true;
        for (i, s) in self.shard_stats().into_iter().enumerate() {
            if self.is_down(i) {
                continue;
            }
            if first {
                agg.page_tokens = s.page_tokens;
                agg.page_bytes = s.page_bytes;
                first = false;
            }
            agg.budget_bytes += s.budget_bytes;
            agg.max_pages += s.max_pages;
            agg.resident_pages += s.resident_pages;
            agg.peak_resident_pages += s.peak_resident_pages;
            agg.allocated_pages += s.allocated_pages;
            agg.shared_adoptions += s.shared_adoptions;
            agg.cow_copies += s.cow_copies;
            agg.reclaimed_pages += s.reclaimed_pages;
        }
        Some(agg)
    }

    fn quarantine_one_shard(&self, selector: u64) -> Option<usize> {
        let live = self.live();
        if live.len() <= 1 {
            return None; // never quarantine the last surviving shard
        }
        let victim = live[(selector % live.len() as u64) as usize];
        self.down[victim].store(true, Ordering::Relaxed);
        Some(victim)
    }

    fn cache_orphaned(&self, cache: &KvCache) -> bool {
        self.shard_of(cache).is_some_and(|s| self.is_down(s))
    }

    fn quarantined_pools(&self) -> Vec<KvPool> {
        (0..self.shards.len())
            .filter(|&i| self.is_down(i))
            .map(|i| self.shards[i].pool().clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{argmax, generate, Sampling};
    use crate::model::ModelParams;
    use crate::runtime::FamilySpec;
    use crate::util::rng::Pcg64;

    fn micro_fused(seed: u64) -> FusedModel {
        let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
        let params = ModelParams::init(&fam, seed);
        FusedModel::pack_dense(&params, "uniform", 4, 16)
            .unwrap()
            .with_shape(2, 8)
    }

    fn micro_tokens(vocab: usize, len: usize, seed: u64) -> Vec<i32> {
        let mut rng = Pcg64::new(seed, 77);
        (0..len).map(|_| rng.below(vocab) as i32).collect()
    }

    #[test]
    fn replica_spec_scales_batch_and_budget() {
        let solo = micro_fused(61);
        let one = solo.spec();
        let reps = Replicas::new(solo, 3);
        let spec = reps.spec();
        assert_eq!(reps.n_shards(), 3);
        assert_eq!(spec.max_batch, 3 * one.max_batch);
        assert_eq!(spec.kv_budget, 3 * one.kv_budget);
        assert_eq!(spec.max_context, one.max_context);
        assert_eq!(reps.shard_stats().len(), 3);
    }

    #[test]
    fn generation_is_independent_of_shard_count() {
        // The same prompt must decode to byte-identical greedy streams on
        // the solo engine and through any replica fleet — shard routing
        // and fork_replica change nothing observable.
        let solo = micro_fused(62);
        let prompt = micro_tokens(11, 6, 5);
        let want = generate(&solo, &prompt, 8, Sampling::Greedy).unwrap();
        for n in [1usize, 2, 3] {
            let reps = Replicas::new(micro_fused(62), n);
            let got = generate(&reps, &prompt, 8, Sampling::Greedy).unwrap();
            assert_eq!(got.tokens, want.tokens, "{n} replicas diverged");
        }
    }

    #[test]
    fn sharded_decode_matches_solo_decode_per_session() {
        // Batch-composition independence across the shard boundary: a
        // 3-session batch splits into sub-batches of 2 + 1 on different
        // shards; every row must equal the session's solo decode.
        let reps = Replicas::new(micro_fused(63), 2);
        let solo = micro_fused(63);
        let prompts: Vec<Vec<i32>> = (0..3).map(|i| micro_tokens(11, 4 + i, 20 + i as u64)).collect();
        let mut batch: Vec<Session> = Vec::new();
        let mut solos: Vec<Session> = Vec::new();
        for p in &prompts {
            batch.push(reps.prefill(p).unwrap().0);
            solos.push(solo.prefill(p).unwrap().0);
        }
        let next = [1i32, 2, 3];
        let stitched = {
            let mut refs: Vec<&mut Session> = batch.iter_mut().collect();
            reps.decode_step(&mut refs, &next).unwrap()
        };
        assert_eq!(stitched.rows(), 3);
        for (i, s) in solos.iter_mut().enumerate() {
            let want = solo.decode_step(&mut [s], &next[i..i + 1]).unwrap();
            assert_eq!(stitched.row(i), want.row(0), "session {i} diverged");
        }
        for (i, s) in batch.iter().enumerate() {
            assert_eq!(s.tokens.len(), prompts[i].len() + 1, "token history drift");
        }
    }

    #[test]
    fn routing_spreads_sessions_and_stats_aggregate() {
        let reps = Replicas::new(micro_fused(64), 2);
        let mut held = Vec::new();
        for i in 0..4 {
            let p = micro_tokens(11, 6, 40 + i);
            held.push(reps.prefill(&p).unwrap().0);
        }
        let per = reps.shard_stats();
        assert!(per.iter().all(|s| s.resident_pages > 0), "a shard sat idle");
        let agg = reps.pool_stats().unwrap();
        assert_eq!(
            agg.resident_pages,
            per.iter().map(|s| s.resident_pages).sum::<usize>()
        );
        assert_eq!(agg.max_pages, per.iter().map(|s| s.max_pages).sum::<usize>());
    }

    #[test]
    fn quarantine_never_takes_the_last_shard() {
        let solo = Replicas::new(micro_fused(70), 1);
        assert_eq!(solo.quarantine_one_shard(0), None, "solo shard died");
        let reps = Replicas::new(micro_fused(70), 3);
        let first = reps.quarantine_one_shard(5).unwrap();
        let second = reps.quarantine_one_shard(5).unwrap();
        assert_ne!(first, second, "quarantined the same shard twice");
        assert_eq!(reps.quarantine_one_shard(5), None, "last survivor died");
        assert_eq!(reps.quarantined_pools().len(), 2);
        // Fleet capacity shrank to the one surviving shard.
        let one = micro_fused(70).spec().kv_budget;
        assert_eq!(reps.pool_stats().unwrap().budget_bytes, one);
    }

    #[test]
    fn orphaned_decode_is_typed_and_migration_is_bit_exact() {
        // Two shards, one session on each (least-loaded routing
        // alternates). Quarantining a session's shard makes its decode a
        // typed ReplicaFailed with nothing mutated; re-prefilling the
        // same history lands on the survivor and continues bit-exactly.
        let reps = Replicas::new(micro_fused(71), 2);
        let pa = micro_tokens(11, 6, 80);
        let pb = micro_tokens(11, 6, 81);
        let (mut sa, _) = reps.prefill(&pa).unwrap();
        let (mut sb, _) = reps.prefill(&pb).unwrap();
        let shard_a = reps.shard_of(&sa.cache).unwrap();
        let shard_b = reps.shard_of(&sb.cache).unwrap();
        assert_ne!(shard_a, shard_b, "routing parked both sessions together");
        // Selector chosen so shard_a is the victim.
        let victim = reps.quarantine_one_shard(shard_a as u64).unwrap();
        assert_eq!(victim, shard_a);
        let before = sa.tokens.clone();
        let err = reps.decode_step(&mut [&mut sa], &[3]).unwrap_err();
        assert!(KvError::is_replica_failed(&err), "got: {err:#}");
        assert_eq!(sa.tokens, before, "failed decode mutated the session");
        assert!(reps.cache_orphaned(&sa.cache));
        assert!(!reps.cache_orphaned(&sb.cache));
        // Migration: drop the orphaned cache, re-prefill history on the
        // fleet (routes to the survivor), continue. Must match the solo
        // engine bit-for-bit.
        drop(sa);
        let (mut moved, _) = reps.prefill(&before).unwrap();
        assert_eq!(reps.shard_of(&moved.cache), Some(shard_b));
        let got = reps.decode_step(&mut [&mut moved], &[3]).unwrap();
        let solo = micro_fused(71);
        let (mut want_s, _) = solo.prefill(&before).unwrap();
        let want = solo.decode_step(&mut [&mut want_s], &[3]).unwrap();
        assert_eq!(got.row(0), want.row(0), "failover decode diverged");
        // The quarantined pool holds no referenced pages once its
        // sessions are gone.
        for pool in reps.quarantined_pools() {
            pool.audit_tables(&[]).unwrap();
        }
        // The survivor still serves the untouched session.
        reps.decode_step(&mut [&mut sb], &[4]).unwrap();
    }

    #[test]
    fn chunked_prefill_routes_and_matches_one_shot() {
        let reps = Replicas::new(micro_fused(65), 2);
        let prompt = micro_tokens(11, 9, 50);
        let (mut one, logits) = reps.prefill(&prompt).unwrap();
        let mut state = None;
        reps.prefill_chunk(&prompt, &mut state, 4).unwrap();
        let last = reps.prefill_chunk(&prompt, &mut state, prompt.len()).unwrap();
        assert_eq!(last.row(last.rows() - 1), logits.row(logits.rows() - 1));
        let mut chunked = Session::new(prompt.clone(), state.take().unwrap());
        let next = argmax(logits.row(logits.rows() - 1)) as i32;
        let a = reps.decode_step(&mut [&mut one], &[next]).unwrap();
        let b = reps.decode_step(&mut [&mut chunked], &[next]).unwrap();
        assert_eq!(a.row(0), b.row(0), "chunked replica session diverged");
    }
}
