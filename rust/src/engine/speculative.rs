//! Speculative decoding: a low-bit ODLRI *draft* proposes tokens, the
//! full-precision-budget *target* verifies them in one batched step.
//!
//! ## Why this fits the paper
//!
//! ODLRI's claim is that assigning distinct roles to `Q` and `L·R` keeps
//! aggressive low-bit quantization accurate — which is exactly what a
//! speculative draft model needs: cheap enough that k extra forward
//! passes cost less than one saved target step, accurate enough that most
//! proposals survive verification. One compression run emits both halves
//! (e.g. a 2-bit aggressive plan as the draft, a 4-bit budget plan as the
//! target), and scheme-exact decode makes the draft/target comparison
//! deterministic.
//!
//! ## The round protocol
//!
//! [`SpeculativeEngine::generate`] maintains one *pending* token `next`
//! (sampled but not yet fed to the target) and per round:
//!
//! 1. **Catch up** the draft session to the target's accepted history
//!    (after a fully-accepted round the draft trails by one token).
//! 2. **Draft**: feed `next` and greedily extend `m = min(k, remaining−1)`
//!    proposals `d₁..d_m` with the draft engine (`m` clamps so a round
//!    never emits past the token budget; `m = 0` degenerates to a plain
//!    decode step through the verify path).
//! 3. **Verify**: one [`Engine::verify_step`] over `[next, d₁..d_m]` —
//!    a single batched causal forward whose row `i` is bit-identical to
//!    the sequential decode logits after `chunk[..=i]`.
//! 4. **Accept** the longest prefix with `dᵢ == argmax(row i)`; the
//!    argmax of the first disagreeing row (or of the last row on full
//!    acceptance) is the free *bonus* token — so every round emits at
//!    least one token that is exactly what plain greedy decoding would
//!    have produced.
//! 5. **Roll back** both sessions with [`Session::truncate`]: rejected
//!    rows leave no trace in token history or KV bits (paged backings
//!    release the dropped pages).
//!
//! The headline invariant — property-tested across both engine families —
//! is that the emitted stream is **bit-identical** to a plain target-only
//! greedy stream for any prompt and any k, because verification rows are
//! bit-identical to decode steps and rollback is bit-exact (K rows are
//! cached post-RoPE at absolute positions).
//!
//! Only greedy streams can be verified this way: accepting a draft token
//! requires it to be *the* token the target would have chosen, which is
//! well-defined for argmax but not for a sampled policy.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::engine::{argmax, Engine, EngineSpec, GenOutput, Session};
use crate::tensor::Matrix;

/// Consecutive draft-round failures that open the speculation circuit
/// breaker (drafting disabled, rounds degrade to plain decode steps).
pub const BREAKER_THRESHOLD: usize = 3;

/// How long the breaker stays open once tripped — rounds here in the
/// engine combinator, scheduler ticks in [`crate::serve`]. The first
/// drafting attempt after cooldown is the probe: success closes the
/// breaker, failure re-trips it.
pub const BREAKER_COOLDOWN_ROUNDS: usize = 8;

/// Acceptance accounting for a speculative run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecCounters {
    /// Draft/verify rounds executed.
    pub rounds: usize,
    /// Draft proposals offered for verification.
    pub drafted: usize,
    /// Proposals the target agreed with (emitted for free).
    pub accepted: usize,
    /// Proposals discarded at the first disagreement.
    pub rejected: usize,
    /// Single-token draft decode calls (catch-up + proposal steps).
    pub draft_steps: usize,
    /// Batched target verify calls.
    pub verify_steps: usize,
    /// Draft rounds that failed (draft engine errored mid-round); the
    /// round degraded to a plain decode step, nothing was emitted wrong.
    pub draft_failures: usize,
    /// Times [`BREAKER_THRESHOLD`] consecutive failures opened the
    /// circuit breaker.
    pub breaker_trips: usize,
    /// Rounds that skipped drafting while the breaker was open.
    pub breaker_skipped: usize,
}

impl SpecCounters {
    /// Fraction of drafted tokens the target accepted (0 when nothing
    /// was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// One generation run plus its acceptance accounting. `gen.step_latencies_s`
/// holds one entry per *round* (each round emits ≥ 1 token), so per-token
/// cost is `steps_total / tokens`, not the per-entry mean.
#[derive(Clone, Debug)]
pub struct SpecOutput {
    pub gen: GenOutput,
    pub counters: SpecCounters,
}

/// Longest accepted prefix of `drafts` under the verify logits, plus the
/// bonus token. `logits` must have `drafts.len() + 1` rows: row `i` holds
/// the target's next-token logits after the pending token and `drafts[..i]`.
/// Returns `(accepted, bonus)` where `bonus` is the target's argmax at the
/// first disagreement (or after the last draft token on full acceptance).
pub fn verify_accept(drafts: &[i32], logits: &Matrix) -> (usize, i32) {
    debug_assert_eq!(logits.rows(), drafts.len() + 1, "verify row count");
    let mut acc = 0usize;
    while acc < drafts.len() && drafts[acc] == argmax(logits.row(acc)) as i32 {
        acc += 1;
    }
    (acc, argmax(logits.row(acc)) as i32)
}

/// Draft and target must speak the same token space for draft proposals
/// to be meaningful (and for `verify_accept`'s argmax comparison to be
/// well-typed).
pub fn check_pair(draft: &EngineSpec, target: &EngineSpec) -> Result<()> {
    if draft.vocab != target.vocab {
        bail!(
            "draft vocab {} does not match target vocab {}",
            draft.vocab,
            target.vocab
        );
    }
    Ok(())
}

/// The combinator: a cheap draft [`Engine`] speculating for an expensive
/// target [`Engine`]. See the module docs for the round protocol and the
/// bit-exactness invariant.
pub struct SpeculativeEngine {
    draft: Box<dyn Engine>,
    target: Box<dyn Engine>,
    k: usize,
}

impl SpeculativeEngine {
    /// Wrap `draft` speculating `k ≥ 1` tokens per round for `target`.
    pub fn new(
        draft: Box<dyn Engine>,
        target: Box<dyn Engine>,
        k: usize,
    ) -> Result<SpeculativeEngine> {
        if k == 0 {
            bail!("speculation depth k must be at least 1");
        }
        check_pair(&draft.spec(), &target.spec())?;
        Ok(SpeculativeEngine { draft, target, k })
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn target(&self) -> &dyn Engine {
        self.target.as_ref()
    }

    pub fn draft(&self) -> &dyn Engine {
        self.draft.as_ref()
    }

    /// Greedy speculative generation: bit-identical tokens to
    /// [`crate::engine::generate`] on the target alone, in fewer target
    /// steps whenever the draft earns acceptances. The context budget is
    /// the smaller of the two engines' (the draft session must hold the
    /// same positions the target's does).
    pub fn generate(&self, prompt: &[i32], max_new_tokens: usize) -> Result<SpecOutput> {
        let tspec = self.target.spec();
        let dspec = self.draft.spec();
        if prompt.is_empty() {
            bail!("generate needs a non-empty prompt");
        }
        let max_context = tspec.max_context.min(dspec.max_context);
        if prompt.len() >= max_context {
            bail!(
                "prompt length {} exceeds the engine context budget {}",
                prompt.len(),
                max_context
            );
        }
        let budget = max_new_tokens.min(max_context - prompt.len());
        let mut c = SpecCounters::default();
        let t0 = Instant::now();
        let (mut tsession, logits) = self.target.prefill(prompt)?;
        let (mut dsession, _) = self.draft.prefill(prompt)?;
        let prefill_s = t0.elapsed().as_secs_f64();
        let mut tokens: Vec<i32> = Vec::with_capacity(budget);
        let mut steps = Vec::new();
        if budget > 0 {
            let mut next = argmax(logits.row(logits.rows() - 1)) as i32;
            tokens.push(next);
            // The draft is strictly advisory: a draft-side error degrades
            // the round to a plain decode step (m = 0 through the verify
            // path) instead of failing generation. BREAKER_THRESHOLD
            // consecutive failures open the circuit breaker for
            // BREAKER_COOLDOWN_ROUNDS rounds; the first drafting attempt
            // afterwards is the probe.
            let mut consec_failures = 0usize;
            let mut open_until = 0usize; // round index the breaker re-arms at
            while tokens.len() < budget {
                let ts = Instant::now();
                let remaining = budget - tokens.len();
                let round = c.rounds;
                let breaker_open = round < open_until;
                // A round emits at most m + 1 tokens; clamp so the last
                // round never drafts past the budget (k larger than the
                // remaining budget degenerates gracefully, m = 0 being a
                // plain decode step through the verify path).
                let m = if breaker_open {
                    c.breaker_skipped += 1;
                    0
                } else {
                    self.k.min(remaining - 1)
                };
                let mut drafts: Vec<i32> = Vec::with_capacity(m);
                let mut draft_failed = false;
                if m > 0 {
                    'draft: {
                        // Catch the draft up to the target's accepted
                        // history (it trails by one after a full accept).
                        while dsession.tokens.len() < tsession.tokens.len() {
                            let t = tsession.tokens[dsession.tokens.len()];
                            if self.draft.decode_step(&mut [&mut dsession], &[t]).is_err() {
                                draft_failed = true;
                                break 'draft;
                            }
                            c.draft_steps += 1;
                        }
                        let mut cur = next;
                        for _ in 0..m {
                            let lg = match self.draft.decode_step(&mut [&mut dsession], &[cur]) {
                                Ok(lg) => lg,
                                Err(_) => {
                                    draft_failed = true;
                                    break 'draft;
                                }
                            };
                            cur = argmax(lg.row(0)) as i32;
                            drafts.push(cur);
                            c.draft_steps += 1;
                        }
                    }
                }
                if draft_failed {
                    c.draft_failures += 1;
                    consec_failures += 1;
                    if consec_failures >= BREAKER_THRESHOLD {
                        c.breaker_trips += 1;
                        open_until = round + 1 + BREAKER_COOLDOWN_ROUNDS;
                        consec_failures = 0;
                    }
                } else if m > 0 {
                    consec_failures = 0;
                }
                c.drafted += drafts.len();
                // One batched target step over pending + proposals.
                let start = tsession.tokens.len();
                let mut chunk = Vec::with_capacity(drafts.len() + 1);
                chunk.push(next);
                chunk.extend_from_slice(&drafts);
                let vl = self.target.verify_step(&mut tsession, &chunk)?;
                c.verify_steps += 1;
                c.rounds += 1;
                let (acc, bonus) = verify_accept(&drafts, &vl);
                c.accepted += acc;
                c.rejected += drafts.len() - acc;
                // Roll both sessions back to the accepted extent (a no-op
                // on the draft after a full accept — it trails instead).
                tsession.truncate(start + 1 + acc);
                dsession.truncate(start + 1 + acc);
                tokens.extend_from_slice(&drafts[..acc]);
                tokens.push(bonus);
                next = bonus;
                steps.push(ts.elapsed().as_secs_f64());
            }
        }
        Ok(SpecOutput {
            gen: GenOutput {
                prompt_len: prompt.len(),
                tokens,
                prefill_s,
                step_latencies_s: steps,
            },
            counters: c,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{generate, NativeEngine, Sampling};
    use crate::fused::FusedModel;
    use crate::model::ModelParams;
    use crate::runtime::FamilySpec;
    use crate::util::rng::Pcg64;

    fn micro_family() -> FamilySpec {
        FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu")
    }

    fn micro_engine(seed: u64) -> NativeEngine {
        NativeEngine::new(&ModelParams::init(&micro_family(), seed), 3, 8).unwrap()
    }

    fn micro_tokens(vocab: usize, len: usize, seed: u64) -> Vec<i32> {
        let mut rng = Pcg64::new(seed, 77);
        (0..len).map(|_| rng.below(vocab) as i32).collect()
    }

    #[test]
    fn verify_accept_takes_longest_prefix_and_bonus() {
        // 3 drafts over vocab 4; target argmaxes are [2, 1, 3, 0].
        let mut logits = Matrix::zeros(4, 4);
        for (r, &am) in [2usize, 1, 3, 0].iter().enumerate() {
            logits.row_mut(r)[am] = 1.0;
        }
        // Full agreement: all 3 accepted, bonus from the last row.
        assert_eq!(verify_accept(&[2, 1, 3], &logits), (3, 0));
        // Disagreement at row 1: one accepted, bonus is row 1's argmax.
        let l2 = {
            let mut l = Matrix::zeros(3, 4);
            for (r, &am) in [2usize, 1, 3].iter().enumerate() {
                l.row_mut(r)[am] = 1.0;
            }
            l
        };
        assert_eq!(verify_accept(&[2, 0], &l2), (1, 1));
        // Immediate disagreement: nothing accepted, bonus = target's own
        // choice for the pending position.
        assert_eq!(verify_accept(&[0, 1], &l2), (0, 2));
        // No drafts (m = 0): a plain decode step.
        let one = {
            let mut l = Matrix::zeros(1, 4);
            l.row_mut(0)[3] = 1.0;
            l
        };
        assert_eq!(verify_accept(&[], &one), (0, 3));
    }

    #[test]
    fn new_validates_k_and_vocab() {
        let a = Box::new(micro_engine(1));
        let b = Box::new(micro_engine(2));
        assert!(SpeculativeEngine::new(a, b, 0).is_err(), "k = 0 accepted");
        let other_fam = FamilySpec::build("micro13", 13, 8, 1, 2, 1, 12, "swiglu");
        let other = Box::new(NativeEngine::new(&ModelParams::init(&other_fam, 3), 3, 8).unwrap());
        let err = SpeculativeEngine::new(other, Box::new(micro_engine(4)), 2).unwrap_err();
        assert!(err.to_string().contains("vocab"), "got: {err:#}");
        assert!(
            SpeculativeEngine::new(Box::new(micro_engine(5)), Box::new(micro_engine(6)), 4)
                .is_ok()
        );
    }

    #[test]
    fn native_verify_step_matches_sequential_decode_bitwise() {
        // The override's whole contract: row i of one batched verify call
        // equals the logits of the i-th sequential decode step, and the
        // session ends in the identical state.
        let engine = micro_engine(11);
        let vocab = engine.spec().vocab;
        let prompt = micro_tokens(vocab, 5, 41);
        let chunk = micro_tokens(vocab, 4, 42);
        let (mut a, _) = engine.prefill(&prompt).unwrap();
        let (mut b, _) = engine.prefill(&prompt).unwrap();
        let batched = engine.verify_step(&mut a, &chunk).unwrap();
        assert_eq!(batched.shape(), (chunk.len(), vocab));
        for (i, &t) in chunk.iter().enumerate() {
            let lg = engine.decode_step(&mut [&mut b], &[t]).unwrap();
            assert_eq!(batched.row(i), lg.row(0), "verify row {i} diverged");
        }
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.cache.len(), b.cache.len());
        // Continuations from both sessions agree bit-for-bit.
        let x = engine.decode_step(&mut [&mut a], &[1]).unwrap();
        let y = engine.decode_step(&mut [&mut b], &[1]).unwrap();
        assert_eq!(x.row(0), y.row(0));
        assert!(engine.verify_step(&mut a, &[]).is_err(), "empty chunk accepted");
    }

    #[test]
    fn fused_verify_step_matches_sequential_decode_bitwise() {
        // Same contract on the packed engine: the verify chunk must stay
        // in the decode kernel regime even when it carries more rows than
        // max_batch, or the accept comparison would see f32 drift.
        let params = ModelParams::init(&micro_family(), 21);
        let fm = FusedModel::pack_dense(&params, "uniform", 4, 32)
            .unwrap()
            .with_shape(2, 8);
        let vocab = fm.spec().vocab;
        let prompt = micro_tokens(vocab, 5, 51);
        let chunk = micro_tokens(vocab, 4, 52); // 4 rows > max_batch 2
        let (mut a, _) = fm.prefill(&prompt).unwrap();
        let (mut b, _) = fm.prefill(&prompt).unwrap();
        let batched = fm.verify_step(&mut a, &chunk).unwrap();
        for (i, &t) in chunk.iter().enumerate() {
            let lg = fm.decode_step(&mut [&mut b], &[t]).unwrap();
            assert_eq!(batched.row(i), lg.row(0), "verify row {i} diverged");
        }
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn speculative_stream_equals_plain_greedy_dense() {
        // The headline invariant on the dense family: for k ∈ {1,2,4,8}
        // and a draft that genuinely disagrees with the target (different
        // seed), the speculative stream is bit-identical to plain greedy
        // target-only generation — including k far beyond the remaining
        // budget.
        let target = micro_engine(7);
        let vocab = target.spec().vocab;
        for prompt_len in [3usize, 6] {
            let prompt = micro_tokens(vocab, prompt_len, 19 + prompt_len as u64);
            for max_new in [1usize, 3, 12] {
                let want = generate(&target, &prompt, max_new, Sampling::Greedy).unwrap();
                for k in [1usize, 2, 4, 8] {
                    let spec = SpeculativeEngine::new(
                        Box::new(micro_engine(8)), // draft: different weights
                        Box::new(micro_engine(7)),
                        k,
                    )
                    .unwrap();
                    let out = spec.generate(&prompt, max_new).unwrap();
                    assert_eq!(
                        out.gen.tokens, want.tokens,
                        "k={k} max_new={max_new} prompt_len={prompt_len}"
                    );
                    let c = out.counters;
                    assert_eq!(c.drafted, c.accepted + c.rejected);
                    assert_eq!(c.verify_steps, c.rounds);
                    assert!((0.0..=1.0).contains(&c.acceptance_rate()));
                    assert!(
                        c.rounds <= want.tokens.len(),
                        "every round must emit at least one token"
                    );
                }
            }
        }
    }

    #[test]
    fn identical_draft_accepts_everything() {
        // Draft == target: every proposal verifies, so n tokens cost
        // ceil((n-1)/(k+1)) verify rounds and the acceptance rate is 1.
        let prompt = micro_tokens(11, 4, 9);
        let spec = SpeculativeEngine::new(
            Box::new(micro_engine(12)),
            Box::new(micro_engine(12)),
            4,
        )
        .unwrap();
        let out = spec.generate(&prompt, 11).unwrap();
        let want = generate(&micro_engine(12), &prompt, 11, Sampling::Greedy).unwrap();
        assert_eq!(out.gen.tokens, want.tokens);
        let c = out.counters;
        assert_eq!(c.rejected, 0, "identical models must agree");
        assert!(c.drafted > 0 && c.accepted == c.drafted);
        assert_eq!(c.acceptance_rate(), 1.0);
        // 1 prefill token + 10 more in full-accept rounds of k + 1 = 5.
        assert_eq!(c.rounds, 2);
    }

    #[test]
    fn speculative_stream_equals_plain_greedy_fused() {
        // The paper's deployment pairing: a 2-bit aggressive pack drafts
        // for a 4-bit target packed from the same dense weights. The
        // low-bit draft disagrees sometimes (quantization noise) but the
        // emitted stream must match plain 4-bit greedy exactly.
        let params = ModelParams::init(&micro_family(), 23);
        let target = FusedModel::pack_dense(&params, "uniform", 4, 32)
            .unwrap()
            .with_shape(3, 8);
        let prompt = micro_tokens(target.spec().vocab, 5, 61);
        let want = generate(&target, &prompt, 9, Sampling::Greedy).unwrap();
        for k in [1usize, 2, 4, 8] {
            let draft = FusedModel::pack_dense(&params, "uniform", 2, 32)
                .unwrap()
                .with_shape(3, 8);
            let tgt = FusedModel::pack_dense(&params, "uniform", 4, 32)
                .unwrap()
                .with_shape(3, 8);
            let spec = SpeculativeEngine::new(Box::new(draft), Box::new(tgt), k).unwrap();
            let out = spec.generate(&prompt, 9).unwrap();
            assert_eq!(out.gen.tokens, want.tokens, "k={k}");
            assert_eq!(out.counters.drafted, out.counters.accepted + out.counters.rejected);
        }
    }

    /// A draft whose decode always errors — prefill works (the session
    /// opens), every drafting round fails.
    struct FailingDraft(NativeEngine);

    impl Engine for FailingDraft {
        fn spec(&self) -> EngineSpec {
            self.0.spec()
        }

        fn forward_batch(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<Matrix> {
            self.0.forward_batch(tokens, batch, seq)
        }

        fn prefill(&self, tokens: &[i32]) -> Result<(Session, Matrix)> {
            self.0.prefill(tokens)
        }

        fn decode_step(&self, _sessions: &mut [&mut Session], _tokens: &[i32]) -> Result<Matrix> {
            bail!("injected draft failure")
        }
    }

    #[test]
    fn failing_draft_trips_the_breaker_and_stream_stays_exact() {
        // Every drafting round fails → rounds degrade to plain decode
        // steps, the breaker opens after BREAKER_THRESHOLD consecutive
        // failures, and the emitted stream is still bit-identical to
        // plain greedy on the target. One token per round (no accepted
        // drafts), so the counters are fully deterministic.
        let prompt = micro_tokens(11, 4, 31);
        let want = generate(&micro_engine(16), &prompt, 12, Sampling::Greedy).unwrap();
        let spec = SpeculativeEngine::new(
            Box::new(FailingDraft(micro_engine(17))),
            Box::new(micro_engine(16)),
            4,
        )
        .unwrap();
        let out = spec.generate(&prompt, 12).unwrap();
        assert_eq!(out.gen.tokens, want.tokens, "degraded stream diverged");
        let c = out.counters;
        assert_eq!(c.rounds, 11, "one token per round after the prefill token");
        assert_eq!(c.draft_failures, BREAKER_THRESHOLD);
        assert_eq!(c.breaker_trips, 1);
        assert_eq!(c.breaker_skipped, c.rounds - BREAKER_THRESHOLD);
        assert_eq!(c.drafted, 0, "failed rounds must offer no proposals");
        assert_eq!(c.accepted, 0);
    }

    #[test]
    fn generate_validates_prompt_and_clamps_budget() {
        let spec = SpeculativeEngine::new(
            Box::new(micro_engine(14)),
            Box::new(micro_engine(15)),
            4,
        )
        .unwrap();
        assert!(spec.generate(&[], 4).is_err(), "empty prompt accepted");
        let max_context = spec.target().spec().max_context;
        assert!(spec.generate(&vec![1i32; max_context], 1).is_err());
        // Budget clamps to the context like plain generate does.
        let prompt = micro_tokens(11, max_context - 3, 71);
        let out = spec.generate(&prompt, 100).unwrap();
        assert_eq!(out.gen.tokens.len(), 3);
    }
}
