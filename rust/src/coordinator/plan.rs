//! Per-projection compression plans and the planners that produce them.
//!
//! A [`MatrixPlan`] is the complete recipe for ONE projection (initializer,
//! low-rank budget, quantizer); a [`CompressionPlan`] is a validated map
//! from every projection name to its `MatrixPlan`. [`Planner`]s turn model
//! parameters + calibration Hessians into plans:
//!
//! * [`UniformPlanner`] — every projection gets the same recipe (the
//!   historical `PipelineConfig` behavior, bit-identically).
//! * [`BudgetPlanner`] — ranks projections by a cheap Hessian-diagonal
//!   outlier-mass probe ([`outlier_mass`]) and greedily allocates rank and
//!   quantizer bits to the most outlier-sensitive projections until the
//!   parameter-weighted model average bits reaches the target budget.
//!
//! ## Plan files
//!
//! `CompressionPlan::parse` reads the small key=value format of
//! [`crate::util::config`]: top-level keys override the base (CLI) recipe
//! for every projection, and a `[projection.name]` section overrides
//! individual projections:
//!
//! ```text
//! # defaults for every projection
//! rank = 4
//! bits = 2
//!
//! [layer0.wq]        # this projection gets more capacity
//! rank = 16
//! bits = 3
//! init = odlri-k8
//! ```
//!
//! Recognized keys: `init`, `rank`, `lr_bits`, `scheme`, `bits`, `group`,
//! `hadamard`. Unknown keys and unknown projection names are errors.
//! Resolution order: per-projection section > top-level default > base
//! config.

use std::collections::BTreeMap;
use std::io::{Read, Write};

use anyhow::{anyhow, bail, Result};

use super::{InitKind, PipelineConfig};
use crate::decompose::avg_bits;
use crate::hessian::Hessian;
use crate::model::ModelParams;
use crate::quant::{make_quantizer, Quantizer};
use crate::report::Table;
use crate::runtime::FamilySpec;
use crate::util::config::{Config, Value as CfgValue};

/// Upper bound for plan integers on deserialization — corrupt metadata must
/// not masquerade as a plausible plan.
const MAX_PLAN_DIM: usize = 1 << 26;

/// The complete compression recipe for one projection matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixPlan {
    /// Low-rank initializer (the paper's role-assignment lever).
    pub init: InitKind,
    /// Requested factor rank (clamped to the matrix dimensions downstream).
    pub rank: usize,
    /// Factor precision; 16 keeps L/R in full precision.
    pub lr_bits: u32,
    /// Quantizer scheme: `"uniform"`, `"e8"`, or `"mxint"`.
    pub q_scheme: String,
    /// Quantizer bits for `Q`.
    pub q_bits: u32,
    /// Quantizer group/block size (uniform groups, MXINT blocks).
    pub q_group: usize,
    /// Randomized-Hadamard incoherence preprocessing.
    pub hadamard: bool,
}

/// Valid `q_bits` range per scheme (the quantizer constructors assert these;
/// validating here turns a bad plan into an error instead of a panic).
fn scheme_bits_range(scheme: &str) -> Result<(u32, u32)> {
    match scheme {
        "uniform" => Ok((1, 8)),
        "e8" => Ok((2, 4)),
        "mxint" => Ok((2, 8)),
        other => bail!("unknown quantizer scheme '{other}' (uniform | e8 | mxint)"),
    }
}

impl MatrixPlan {
    /// The uniform recipe a [`PipelineConfig`] describes.
    pub fn from_config(cfg: &PipelineConfig) -> MatrixPlan {
        MatrixPlan {
            init: cfg.init.clone(),
            rank: cfg.rank,
            lr_bits: cfg.lr_bits,
            q_scheme: cfg.q_scheme.clone(),
            q_bits: cfg.q_bits,
            q_group: cfg.q_group,
            hadamard: cfg.hadamard,
        }
    }

    /// Bounds-check the recipe (scheme known, bits in the scheme's range,
    /// group ≥ 1, sane magnitudes).
    pub fn validate(&self) -> Result<()> {
        let (lo, hi) = scheme_bits_range(&self.q_scheme)?;
        if !(lo..=hi).contains(&self.q_bits) {
            bail!(
                "{} quantizer wants {lo}..={hi} bits, plan asks for {}",
                self.q_scheme,
                self.q_bits
            );
        }
        if self.q_group == 0 {
            bail!("plan group must be >= 1");
        }
        if !(1..=32).contains(&self.lr_bits) {
            bail!("plan lr_bits must be 1..=32, got {}", self.lr_bits);
        }
        if self.rank > MAX_PLAN_DIM || self.q_group > MAX_PLAN_DIM {
            bail!("plan rank/group out of range");
        }
        Ok(())
    }

    /// Build this plan's quantizer (validates first).
    pub fn quantizer(&self) -> Result<Box<dyn Quantizer>> {
        self.validate()?;
        make_quantizer(&self.q_scheme, self.q_bits, self.q_group)
    }

    /// Paper-style average bits/weight this recipe costs on an m×n matrix
    /// (Q bits with scale overhead + factor storage) — the
    /// [`BudgetPlanner`] cost model, shared with
    /// [`crate::model::CompressedMatrix::avg_bits`].
    pub fn avg_bits(&self, rows: usize, cols: usize) -> Result<f64> {
        let q = self.quantizer()?;
        Ok(avg_bits(
            rows,
            cols,
            self.rank,
            q.bits_with_overhead(rows, cols),
            self.lr_bits,
        ))
    }

    /// Compact human-readable recipe, e.g. `odlri r16 e8x2b/g64+rot lr4b`.
    pub fn summary(&self) -> String {
        format!(
            "{} r{} {}x{}b/g{}{} lr{}b",
            self.init.name(),
            self.rank,
            self.q_scheme,
            self.q_bits,
            self.q_group,
            if self.hadamard { "+rot" } else { "" },
            self.lr_bits
        )
    }

    // ---- serialization (ODF3 per-matrix plan metadata) ----

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        write_str(w, &self.init.name())?;
        w.write_all(&(self.rank as u32).to_le_bytes())?;
        w.write_all(&self.lr_bits.to_le_bytes())?;
        write_str(w, &self.q_scheme)?;
        w.write_all(&self.q_bits.to_le_bytes())?;
        w.write_all(&(self.q_group as u32).to_le_bytes())?;
        w.write_all(&[self.hadamard as u8])?;
        Ok(())
    }

    pub fn read_from(r: &mut impl Read) -> Result<MatrixPlan> {
        let init = InitKind::parse(&read_str(r)?)?;
        let rank = read_u32(r)? as usize;
        let lr_bits = read_u32(r)?;
        let q_scheme = read_str(r)?;
        let q_bits = read_u32(r)?;
        let q_group = read_u32(r)? as usize;
        let mut flag = [0u8; 1];
        r.read_exact(&mut flag)?;
        let hadamard = match flag[0] {
            0 => false,
            1 => true,
            other => bail!("bad plan hadamard flag {other}"),
        };
        let plan = MatrixPlan {
            init,
            rank,
            lr_bits,
            q_scheme,
            q_bits,
            q_group,
            hadamard,
        };
        plan.validate()?;
        Ok(plan)
    }
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    let b = s.as_bytes();
    w.write_all(&(b.len() as u32).to_le_bytes())?;
    w.write_all(b)?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    Ok(u32::from_le_bytes(b4))
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u32(r)? as usize;
    if len > 4096 {
        bail!("plan string length {len} out of range");
    }
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

/// A validated whole-model plan: exactly one [`MatrixPlan`] per projection.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressionPlan {
    matrices: BTreeMap<String, MatrixPlan>,
}

impl CompressionPlan {
    /// Wrap a per-projection map, checking it covers the family's
    /// projections exactly (no missing, no unknown) and every recipe is
    /// in-bounds.
    pub fn new(
        matrices: BTreeMap<String, MatrixPlan>,
        family: &FamilySpec,
    ) -> Result<CompressionPlan> {
        for name in &family.projections {
            if !matrices.contains_key(name) {
                bail!("plan is missing projection '{name}'");
            }
        }
        for (name, mp) in &matrices {
            if !family.projections.contains(name) {
                bail!("plan names unknown projection '{name}'");
            }
            mp.validate()
                .map_err(|e| anyhow!("plan for '{name}': {e}"))?;
        }
        Ok(CompressionPlan { matrices })
    }

    /// The uniform plan a [`PipelineConfig`] historically meant: every
    /// projection gets the identical recipe. Running this plan is
    /// bit-identical to the pre-plan pipeline (tested in `coordinator`).
    pub fn uniform(family: &FamilySpec, cfg: &PipelineConfig) -> CompressionPlan {
        let mp = MatrixPlan::from_config(cfg);
        CompressionPlan {
            matrices: family
                .projections
                .iter()
                .map(|n| (n.clone(), mp.clone()))
                .collect(),
        }
    }

    pub fn get(&self, name: &str) -> Option<&MatrixPlan> {
        self.matrices.get(name)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &MatrixPlan)> {
        self.matrices.iter()
    }

    pub fn len(&self) -> usize {
        self.matrices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.matrices.is_empty()
    }

    /// True when every projection shares one recipe.
    pub fn is_uniform(&self) -> bool {
        let mut it = self.matrices.values();
        match it.next() {
            None => true,
            Some(first) => it.all(|mp| mp == first),
        }
    }

    /// (min, max) requested rank across projections.
    pub fn rank_spread(&self) -> (usize, usize) {
        let ranks = self.matrices.values().map(|mp| mp.rank);
        (
            ranks.clone().min().unwrap_or(0),
            ranks.max().unwrap_or(0),
        )
    }

    /// (min, max) quantizer bits across projections.
    pub fn bits_spread(&self) -> (u32, u32) {
        let bits = self.matrices.values().map(|mp| mp.q_bits);
        (bits.clone().min().unwrap_or(0), bits.max().unwrap_or(0))
    }

    /// (min, max) factor precision across projections.
    pub fn lr_bits_spread(&self) -> (u32, u32) {
        let bits = self.matrices.values().map(|mp| mp.lr_bits);
        (bits.clone().min().unwrap_or(0), bits.max().unwrap_or(0))
    }

    /// Display form of the rank spread: `"8"` when uniform, `"4-16"` when
    /// not — shared by the CLI summary, output paths, and report tables.
    pub fn rank_label(&self) -> String {
        let (lo, hi) = self.rank_spread();
        spread_label(lo, hi)
    }

    /// Display form of the quantizer-bits spread.
    pub fn bits_label(&self) -> String {
        let (lo, hi) = self.bits_spread();
        spread_label(lo, hi)
    }

    /// Display form of the factor-precision spread.
    pub fn lr_bits_label(&self) -> String {
        let (lo, hi) = self.lr_bits_spread();
        spread_label(lo, hi)
    }

    /// Re-validate against a family (used when a plan arrives from a
    /// container or file rather than [`CompressionPlan::new`]).
    pub fn validate(&self, family: &FamilySpec) -> Result<()> {
        CompressionPlan::new(self.matrices.clone(), family).map(|_| ())
    }

    /// The plan's parameter-weighted model average bits/weight — the budget
    /// cost model, and exactly what the compressed model will report.
    pub fn avg_bits(&self, family: &FamilySpec) -> Result<f64> {
        let mut weighted = 0.0;
        let mut total = 0.0;
        for (name, mp) in &self.matrices {
            let shape = family.param_shape(name)?;
            let count = (shape[0] * shape[1]) as f64;
            weighted += mp.avg_bits(shape[0], shape[1])? * count;
            total += count;
        }
        Ok(if total == 0.0 { 0.0 } else { weighted / total })
    }

    /// Per-projection plan table for reports and the CLI.
    pub fn table(&self, family: &FamilySpec) -> Result<Table> {
        let mut t = Table::new(
            "Compression plan (per projection)",
            &[
                "Projection", "Shape", "Init", "Rank", "LR bits", "Scheme", "Q bits",
                "Group", "Had", "AvgBits",
            ],
        );
        for (name, mp) in &self.matrices {
            let shape = family.param_shape(name)?;
            t.row(vec![
                name.clone(),
                format!("{}x{}", shape[0], shape[1]),
                mp.init.name(),
                mp.rank.to_string(),
                mp.lr_bits.to_string(),
                mp.q_scheme.clone(),
                mp.q_bits.to_string(),
                mp.q_group.to_string(),
                if mp.hadamard { "yes" } else { "no" }.to_string(),
                format!("{:.3}", mp.avg_bits(shape[0], shape[1])?),
            ]);
        }
        Ok(t)
    }

    /// Emit the plan-file form ([`CompressionPlan::parse`] round-trips it).
    pub fn to_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("# odlri compression plan (one section per projection)\n");
        for (name, mp) in &self.matrices {
            let _ = writeln!(out, "\n[{name}]");
            let _ = writeln!(out, "init = {}", mp.init.name());
            let _ = writeln!(out, "rank = {}", mp.rank);
            let _ = writeln!(out, "lr_bits = {}", mp.lr_bits);
            let _ = writeln!(out, "scheme = {}", mp.q_scheme);
            let _ = writeln!(out, "bits = {}", mp.q_bits);
            let _ = writeln!(out, "group = {}", mp.q_group);
            let _ = writeln!(out, "hadamard = {}", mp.hadamard);
        }
        out
    }

    /// Parse a plan file (see the module header for the format). `base`
    /// supplies every field not set by the file.
    pub fn parse(
        text: &str,
        family: &FamilySpec,
        base: &PipelineConfig,
    ) -> Result<CompressionPlan> {
        let cfg = Config::parse(text)?;
        const FIELDS: [&str; 7] =
            ["init", "rank", "lr_bits", "scheme", "bits", "group", "hadamard"];
        // Reject typos up front: every key must be a bare field (default
        // recipe) or `<projection>.<field>` for a known projection.
        for key in cfg.keys() {
            let ok = FIELDS.contains(&key.as_str())
                || key.rsplit_once('.').is_some_and(|(proj, field)| {
                    FIELDS.contains(&field)
                        && family.projections.iter().any(|p| p == proj)
                });
            if !ok {
                bail!(
                    "plan file: unknown key '{key}' (fields: {}; sections must name a \
                     projection of family {})",
                    FIELDS.join(", "),
                    family.name
                );
            }
        }
        let default = apply_overrides(&cfg, "", &MatrixPlan::from_config(base))?;
        let mut matrices = BTreeMap::new();
        for name in &family.projections {
            matrices.insert(
                name.clone(),
                apply_overrides(&cfg, &format!("{name}."), &default)?,
            );
        }
        CompressionPlan::new(matrices, family)
    }
}

fn spread_label<T: PartialEq + std::fmt::Display>(lo: T, hi: T) -> String {
    if lo == hi {
        lo.to_string()
    } else {
        format!("{lo}-{hi}")
    }
}

/// Overlay `prefix`-scoped plan keys from a parsed config onto `base`.
fn apply_overrides(cfg: &Config, prefix: &str, base: &MatrixPlan) -> Result<MatrixPlan> {
    let mut mp = base.clone();
    let key = |field: &str| format!("{prefix}{field}");
    if let Some(v) = cfg.get(&key("init")) {
        mp.init = InitKind::parse(&want_str(v, &key("init"))?)?;
    }
    if let Some(v) = cfg.get(&key("rank")) {
        mp.rank = want_int(v, &key("rank"), MAX_PLAN_DIM as i64)? as usize;
    }
    if let Some(v) = cfg.get(&key("lr_bits")) {
        mp.lr_bits = want_int(v, &key("lr_bits"), 32)? as u32;
    }
    if let Some(v) = cfg.get(&key("scheme")) {
        mp.q_scheme = want_str(v, &key("scheme"))?;
    }
    if let Some(v) = cfg.get(&key("bits")) {
        mp.q_bits = want_int(v, &key("bits"), 8)? as u32;
    }
    if let Some(v) = cfg.get(&key("group")) {
        mp.q_group = want_int(v, &key("group"), MAX_PLAN_DIM as i64)? as usize;
    }
    if let Some(v) = cfg.get(&key("hadamard")) {
        mp.hadamard = match v {
            CfgValue::Bool(b) => *b,
            other => bail!("plan key '{}' wants true/false, got {other:?}", key("hadamard")),
        };
    }
    Ok(mp)
}

/// Extract an integer in `0..=max` — the bound is checked BEFORE any
/// narrowing cast, so out-of-range values error instead of wrapping into
/// valid-looking recipes.
fn want_int(v: &CfgValue, key: &str, max: i64) -> Result<i64> {
    match v {
        CfgValue::Int(i) if (0..=max).contains(i) => Ok(*i),
        other => bail!("plan key '{key}' wants an integer in 0..={max}, got {other:?}"),
    }
}

fn want_str(v: &CfgValue, key: &str) -> Result<String> {
    match v {
        CfgValue::Str(s) => Ok(s.clone()),
        other => bail!("plan key '{key}' wants a string, got {other:?}"),
    }
}

/// Produces a [`CompressionPlan`] from model parameters and calibration
/// Hessians.
pub trait Planner {
    fn name(&self) -> String;

    fn plan(
        &self,
        params: &ModelParams,
        hessians: &BTreeMap<String, Hessian>,
    ) -> Result<CompressionPlan>;
}

/// One recipe for every projection — exactly the historical
/// `PipelineConfig` behavior.
pub struct UniformPlanner {
    pub config: PipelineConfig,
}

impl Planner for UniformPlanner {
    fn name(&self) -> String {
        "uniform".into()
    }

    fn plan(
        &self,
        params: &ModelParams,
        _hessians: &BTreeMap<String, Hessian>,
    ) -> Result<CompressionPlan> {
        Ok(CompressionPlan::uniform(&params.family, &self.config))
    }
}

/// Outlier threshold of the mass probe: a channel counts as an outlier
/// when its Hessian-diagonal energy exceeds `PROBE_TAU ×` the median
/// channel's. LLM activation outliers sit 10–100× above the bulk (SpQR,
/// AWQ), so 4× cleanly separates them from ordinary spread.
const PROBE_TAU: f64 = 4.0;

/// Cheap outlier-sensitivity probe: the fraction of total Hessian-diagonal
/// energy carried by channels whose diagonal exceeds `tau ×` the median
/// diagonal. `H = X Xᵀ`, so `H_ii` is channel `i`'s activation energy — a
/// few dominant diagonal entries are exactly the activation-outlier
/// structure ODLRI keys on, and the projections where low-rank capacity
/// pays off most. Scale-free (thresholds against the projection's own
/// median) and monotone in how much outlier structure a projection
/// carries; ≈ 0 for an outlier-free projection.
pub fn outlier_mass(h: &Hessian, tau: f64) -> f64 {
    let n = h.dim();
    if n == 0 {
        return 0.0;
    }
    let diag: Vec<f64> = (0..n).map(|i| h.matrix().at(i, i) as f64).collect();
    let total: f64 = diag.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut sorted = diag.clone();
    sorted.sort_by(f64::total_cmp);
    let median = sorted[n / 2];
    let cut = tau * median;
    diag.iter().filter(|&&d| d > cut).sum::<f64>() / total
}

/// One upgrade step the budget allocator can spend on a projection.
#[derive(Clone, Copy, Debug)]
enum Upgrade {
    /// Set the factor rank to this absolute value.
    Rank(usize),
    /// Set the quantizer bits to this absolute value.
    Bits(u32),
}

/// Sensitivity-driven budget allocation: every projection starts at a floor
/// recipe (quarter rank, base bits); rank and bit upgrades are then granted
/// greedily, most outlier-sensitive projection first, while the plan's
/// parameter-weighted average bits stays ≤ `budget`. The budget is a hard
/// ceiling: the returned plan (and therefore the compressed model's
/// reported `avg_bits`) never exceeds it, and a budget below the floor
/// plan's cost is an error.
pub struct BudgetPlanner {
    /// Target model average bits/weight (hard ceiling).
    pub budget: f64,
    /// Base recipe: scheme/group/lr_bits/init/hadamard come from here; its
    /// `rank`/`q_bits` anchor the upgrade ladders.
    pub base: PipelineConfig,
}

impl BudgetPlanner {
    pub fn new(budget: f64, base: PipelineConfig) -> BudgetPlanner {
        BudgetPlanner { budget, base }
    }

    /// Upgrade ladder anchored at the base recipe: rank r/4 → r/2 → r →
    /// bits+1 → 2r (rank is the paper's preferred lever, so it is granted
    /// first; the extra quantizer bit slots in before the final doubling).
    fn upgrades(base: &MatrixPlan, max_bits: u32) -> (MatrixPlan, Vec<Upgrade>) {
        let mut floor = base.clone();
        let mut steps = Vec::new();
        if base.rank > 0 {
            floor.rank = (base.rank / 4).max(1);
            for r in [(base.rank / 2).max(1), base.rank] {
                let dup = steps
                    .iter()
                    .any(|u| matches!(*u, Upgrade::Rank(x) if x == r));
                if r > floor.rank && !dup {
                    steps.push(Upgrade::Rank(r));
                }
            }
        }
        if base.q_bits < max_bits {
            steps.push(Upgrade::Bits(base.q_bits + 1));
        }
        if base.rank > 0 {
            steps.push(Upgrade::Rank(base.rank * 2));
        }
        (floor, steps)
    }
}

impl Planner for BudgetPlanner {
    fn name(&self) -> String {
        format!("budget{:.2}", self.budget)
    }

    fn plan(
        &self,
        params: &ModelParams,
        hessians: &BTreeMap<String, Hessian>,
    ) -> Result<CompressionPlan> {
        let fam = &params.family;
        let base = MatrixPlan::from_config(&self.base);
        base.validate()?;
        let (_, max_bits) = scheme_bits_range(&base.q_scheme)?;
        let (floor, steps) = BudgetPlanner::upgrades(&base, max_bits);

        // Rank projections by outlier sensitivity (name-tiebroken so the
        // allocation is deterministic).
        let mut scored: Vec<(String, f64)> = Vec::with_capacity(fam.projections.len());
        for name in &fam.projections {
            let h = hessians
                .get(name)
                .ok_or_else(|| anyhow!("missing Hessian for projection '{name}'"))?;
            scored.push((name.clone(), outlier_mass(h, PROBE_TAU)));
        }
        scored.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));

        let mut matrices: BTreeMap<String, MatrixPlan> = fam
            .projections
            .iter()
            .map(|n| (n.clone(), floor.clone()))
            .collect();
        // Cost bookkeeping: per-projection weighted contribution
        // `avg_bits(shape) · param_count`, so each candidate upgrade costs
        // one quantizer build instead of re-pricing the whole plan. The sum
        // is re-added in BTreeMap order every trial — the exact arithmetic
        // [`CompressionPlan::avg_bits`] performs — so the ceiling the
        // greedy enforces is precisely the value the model will report.
        let mut shapes: BTreeMap<String, (usize, usize)> = BTreeMap::new();
        let mut contrib: BTreeMap<String, f64> = BTreeMap::new();
        let mut total = 0.0f64;
        for (name, mp) in &matrices {
            let shape = fam.param_shape(name)?;
            let count = (shape[0] * shape[1]) as f64;
            shapes.insert(name.clone(), (shape[0], shape[1]));
            contrib.insert(name.clone(), mp.avg_bits(shape[0], shape[1])? * count);
            total += count;
        }
        let cost_with = |contrib: &BTreeMap<String, f64>, name: &str, new_c: f64| -> f64 {
            if total == 0.0 {
                return 0.0;
            }
            contrib
                .iter()
                .map(|(k, v)| if k == name { new_c } else { *v })
                .sum::<f64>()
                / total
        };
        // No projection is named "", so this sums the floor contributions.
        let floor_cost = cost_with(&contrib, "", 0.0);
        if floor_cost > self.budget {
            bail!(
                "budget {:.3} is below the floor plan's {:.3} avg bits \
                 ({}); lower --rank/--bits or raise the budget",
                self.budget,
                floor_cost,
                floor.summary()
            );
        }

        // Greedy allocation: repeatedly grant the most sensitive
        // projection its next upgrade if the model stays within budget.
        // Cursors only advance, so the loop terminates; a skipped upgrade
        // (over budget) simply moves on to the projection's cheaper
        // remaining steps.
        let mut cursor: BTreeMap<&str, usize> =
            scored.iter().map(|(n, _)| (n.as_str(), 0usize)).collect();
        loop {
            let mut granted = false;
            for (name, _) in &scored {
                let c = cursor.get_mut(name.as_str()).unwrap();
                while *c < steps.len() {
                    let step = steps[*c];
                    *c += 1;
                    let mut candidate = matrices[name].clone();
                    match step {
                        Upgrade::Rank(r) => candidate.rank = r,
                        Upgrade::Bits(b) => candidate.q_bits = b,
                    }
                    let (rows, cols) = shapes[name];
                    let new_c = candidate.avg_bits(rows, cols)? * (rows * cols) as f64;
                    if cost_with(&contrib, name, new_c) <= self.budget {
                        contrib.insert(name.clone(), new_c);
                        matrices.insert(name.clone(), candidate);
                        granted = true;
                        break;
                    }
                }
                if granted {
                    break;
                }
            }
            if !granted {
                break;
            }
        }
        CompressionPlan::new(matrices, fam)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::testing;
    use crate::util::rng::Pcg64;

    fn toy_family() -> FamilySpec {
        crate::runtime::FamilySpec::build("toyplan", 16, 8, 1, 2, 2, 12, "swiglu")
    }

    fn base_cfg() -> PipelineConfig {
        PipelineConfig {
            rank: 8,
            lr_bits: 4,
            ..Default::default()
        }
    }

    #[test]
    fn uniform_plan_covers_and_is_uniform() {
        let fam = toy_family();
        let plan = CompressionPlan::uniform(&fam, &base_cfg());
        assert_eq!(plan.len(), fam.projections.len());
        assert!(plan.is_uniform());
        for name in &fam.projections {
            assert_eq!(plan.get(name).unwrap().rank, 8);
        }
        assert_eq!(plan.rank_spread(), (8, 8));
        assert!(plan.validate(&fam).is_ok());
        let bits = plan.avg_bits(&fam).unwrap();
        assert!(bits > 2.0 && bits.is_finite(), "bits={bits}");
    }

    #[test]
    fn plan_validation_catches_missing_and_unknown() {
        let fam = toy_family();
        let plan = CompressionPlan::uniform(&fam, &base_cfg());
        let mut missing = plan.matrices.clone();
        missing.remove("layer0.wq");
        assert!(CompressionPlan::new(missing, &fam).is_err());
        let mut unknown = plan.matrices.clone();
        unknown.insert("layer0.bogus".into(), MatrixPlan::from_config(&base_cfg()));
        assert!(CompressionPlan::new(unknown, &fam).is_err());
        // Out-of-range bits for the scheme error instead of panicking.
        let mut bad = plan.matrices.clone();
        bad.get_mut("layer0.wq").unwrap().q_bits = 7; // e8 supports 2..=4
        assert!(CompressionPlan::new(bad, &fam).is_err());
    }

    #[test]
    fn plan_file_parse_applies_resolution_order() {
        let fam = toy_family();
        let base = base_cfg(); // rank 8, e8 2-bit
        let text = "
            rank = 4            # default for every projection
            [layer0.wq]
            rank = 16
            bits = 3
            init = odlri-k2
        ";
        let plan = CompressionPlan::parse(text, &fam, &base).unwrap();
        assert_eq!(plan.get("layer0.wq").unwrap().rank, 16);
        assert_eq!(plan.get("layer0.wq").unwrap().q_bits, 3);
        assert_eq!(
            plan.get("layer0.wq").unwrap().init,
            InitKind::OdlriK(2)
        );
        // Unmentioned projections: top-level default overrides base rank,
        // everything else stays base.
        assert_eq!(plan.get("layer0.wk").unwrap().rank, 4);
        assert_eq!(plan.get("layer0.wk").unwrap().q_bits, 2);
        assert!(!plan.is_uniform());
        assert_eq!(plan.rank_spread(), (4, 16));
        assert_eq!(plan.bits_spread(), (2, 3));
    }

    #[test]
    fn plan_file_rejects_unknown_keys_and_projections() {
        let fam = toy_family();
        let base = base_cfg();
        assert!(CompressionPlan::parse("bogus = 4", &fam, &base).is_err());
        assert!(
            CompressionPlan::parse("[layer0.nope]\nrank = 4", &fam, &base).is_err()
        );
        assert!(
            CompressionPlan::parse("[layer0.wq]\nbogus = 4", &fam, &base).is_err()
        );
        // Type errors are errors, not silent defaults.
        assert!(CompressionPlan::parse("rank = \"four\"", &fam, &base).is_err());
        assert!(
            CompressionPlan::parse("[layer0.wq]\nhadamard = 3", &fam, &base).is_err()
        );
        // Out-of-range integers error instead of wrapping through a
        // narrowing cast (4294967298 would truncate to a "valid" 2 bits).
        assert!(CompressionPlan::parse("bits = 4294967298", &fam, &base).is_err());
        assert!(CompressionPlan::parse("lr_bits = 4294967297", &fam, &base).is_err());
        assert!(CompressionPlan::parse("rank = -1", &fam, &base).is_err());
    }

    #[test]
    fn plan_text_roundtrip() {
        let fam = toy_family();
        let mut map = CompressionPlan::uniform(&fam, &base_cfg()).matrices;
        map.get_mut("layer0.wq").unwrap().rank = 16;
        map.get_mut("layer0.wq").unwrap().init = InitKind::OdlriK(3);
        map.get_mut("layer0.wup").unwrap().q_scheme = "uniform".into();
        map.get_mut("layer0.wup").unwrap().q_bits = 5;
        map.get_mut("layer0.wup").unwrap().hadamard = false;
        let plan = CompressionPlan::new(map, &fam).unwrap();
        let back = CompressionPlan::parse(&plan.to_text(), &fam, &base_cfg()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn matrix_plan_serialization_roundtrip() {
        testing::quick("matrix-plan-io", |rng| {
            let mp = MatrixPlan {
                init: [
                    InitKind::Caldera,
                    InitKind::LrFirst,
                    InitKind::Odlri,
                    InitKind::OdlriK(1 + rng.below(64)),
                ][rng.below(4)]
                .clone(),
                rank: rng.below(256),
                lr_bits: 1 + rng.below(16) as u32,
                q_scheme: "uniform".into(),
                q_bits: 1 + rng.below(8) as u32,
                q_group: 1 + rng.below(128),
                hadamard: rng.below(2) == 1,
            };
            let mut buf = Vec::new();
            mp.write_to(&mut buf).unwrap();
            let back = MatrixPlan::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(back, mp);
            // Truncated streams error instead of producing garbage.
            let cut = buf.len() / 2;
            assert!(MatrixPlan::read_from(&mut &buf[..cut]).is_err());
        });
    }

    #[test]
    fn outlier_mass_ranks_planted_outliers() {
        let mut rng = Pcg64::new(71, 1);
        let flat = Hessian::from_acts(&Matrix::randn(32, 96, 1.0, &mut rng));
        let (x1, _) = testing::gen_outlier_acts(&mut rng, 32, 96, 1);
        let (x4, _) = testing::gen_outlier_acts(&mut rng, 32, 96, 4);
        let m_flat = outlier_mass(&flat, PROBE_TAU);
        let m_one = outlier_mass(&Hessian::from_acts(&x1), PROBE_TAU);
        let m_four = outlier_mass(&Hessian::from_acts(&x4), PROBE_TAU);
        // Planted outliers dominate the energy; a flat spectrum does not.
        assert!(m_one > 0.5, "single planted outlier barely registered: {m_one}");
        assert!(m_four > 0.5, "planted outliers barely registered: {m_four}");
        assert!(
            m_flat < 0.25,
            "outlier-free Hessian scored as outlier-heavy: {m_flat}"
        );
        assert!(m_flat < m_one && m_flat < m_four);
        assert!(outlier_mass(&Hessian::zeros(8), PROBE_TAU) == 0.0);
        // Monotone in outlier count at fixed magnitude (hand-built diag:
        // k channels at 100× the unit bulk).
        let mass_k = |k: usize| {
            let n = 32;
            let m = Matrix::from_fn(n, n, |i, j| {
                if i != j {
                    0.0
                } else if i < k {
                    100.0
                } else {
                    1.0
                }
            });
            outlier_mass(&Hessian::from_matrix(m, n).unwrap(), PROBE_TAU)
        };
        assert!(mass_k(0) == 0.0);
        assert!(mass_k(1) < mass_k(2) && mass_k(2) < mass_k(6));
    }

    #[test]
    fn budget_below_floor_is_an_error() {
        let fam = toy_family();
        let params = ModelParams::init(&fam, 3);
        let mut hessians = BTreeMap::new();
        let mut rng = Pcg64::new(72, 1);
        for name in &fam.projections {
            let n = fam.param_shape(name).unwrap()[1];
            hessians.insert(
                name.clone(),
                Hessian::from_acts(&Matrix::randn(n, 2 * n, 1.0, &mut rng)),
            );
        }
        let err = BudgetPlanner::new(0.5, base_cfg())
            .plan(&params, &hessians)
            .unwrap_err();
        assert!(err.to_string().contains("floor"), "{err}");
    }
}
