//! The Layer-3 coordinator: the **planning API** for whole-model
//! compression.
//!
//! The paper's thesis is role assignment — low-rank capacity should go
//! where activation outliers are — and projections differ sharply in shape
//! and outlier sensitivity. The coordinator therefore compresses a model
//! under a per-projection [`CompressionPlan`] rather than one global
//! recipe:
//!
//! * [`MatrixPlan`] — one projection's recipe (init, rank, lr_bits,
//!   quantizer scheme/bits/group, hadamard).
//! * [`CompressionPlan`] — a validated map covering every projection.
//! * [`Planner`] — produces a plan from `ModelParams` + Hessians:
//!   [`UniformPlanner`] (one recipe everywhere) and [`BudgetPlanner`]
//!   (Hessian-diagonal outlier-mass probe + greedy rank/bit allocation
//!   under a model-wide average-bits ceiling).
//!
//! ## Plan resolution order
//!
//! 1. `--plan FILE` (per-projection section > top-level default > base
//!    CLI recipe — see [`CompressionPlan::parse`]);
//! 2. else `--budget B` → [`BudgetPlanner`] over the CLI recipe;
//! 3. else the uniform plan of the CLI recipe.
//!
//! ## Budget semantics
//!
//! `BudgetPlanner`'s budget is a **hard ceiling** on the parameter-weighted
//! model average bits/weight ([`crate::decompose::avg_bits`] per
//! projection, the same cost model the compressed model reports). Every
//! projection starts at a floor recipe; upgrades are granted greedily, most
//! outlier-sensitive projection first, while the plan stays ≤ budget. A
//! budget below the floor cost is an error, never a silent overshoot.
//!
//! ## Uniform-plan back-compat invariant
//!
//! [`CompressionPlan::uniform`] over a [`PipelineConfig`] reproduces the
//! historical global-config pipeline **bit-identically** (same Q, L, R per
//! projection — property-tested below): per-job streams are seeded from the
//! matrix name and run seed only, so results are independent of worker
//! count and of how the plan was produced.
//!
//! Jobs are scheduled over a deterministic worker pool ([`crate::exec`]);
//! while the pool is active, per-job matmuls are capped to one thread by a
//! counted RAII scope ([`crate::tensor::MatmulSingleThreadScope`]) that
//! releases even on early error returns and never clobbers the configured
//! thread budget.

mod plan;

pub use plan::{
    outlier_mass, BudgetPlanner, CompressionPlan, MatrixPlan, Planner, UniformPlanner,
};

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::decompose::{DecompMetrics, Initializer, JointConfig, JointOptimizer};
use crate::exec;
use crate::hessian::Hessian;
use crate::lowrank::LowRankConfig;
use crate::model::{CompressedMatrix, CompressedModel, ModelParams};
use crate::tensor;
use crate::util::fnv1a;

/// Which LR initializer the pipeline uses.
#[derive(Clone, Debug, PartialEq)]
pub enum InitKind {
    /// CALDERA default (zero init).
    Caldera,
    /// LRApprox(W) init.
    LrFirst,
    /// CALDERA + ODLRI with the paper's k-schedule (App. B.2).
    Odlri,
    /// ODLRI with an explicit k (ablations, Table 5).
    OdlriK(usize),
}

impl InitKind {
    pub fn name(&self) -> String {
        match self {
            InitKind::Caldera => "caldera".into(),
            InitKind::LrFirst => "lr-first".into(),
            InitKind::Odlri => "odlri".into(),
            InitKind::OdlriK(k) => format!("odlri-k{k}"),
        }
    }

    /// Parse the CLI/plan-file spelling. Round-trips with
    /// [`InitKind::name`] (property-tested below); also accepts the
    /// historical aliases `zero` (= caldera) and `lrapprox` (= lr-first).
    pub fn parse(s: &str) -> Result<InitKind> {
        Ok(match s {
            "odlri" => InitKind::Odlri,
            "caldera" | "zero" => InitKind::Caldera,
            "lr-first" | "lrapprox" => InitKind::LrFirst,
            other => match other.strip_prefix("odlri-k") {
                Some(k) => InitKind::OdlriK(k.parse().map_err(|_| {
                    anyhow!("bad ODLRI k in init '{other}' (want odlri-kN)")
                })?),
                None => bail!(
                    "unknown init '{other}' (odlri | caldera | lr-first | odlri-kN)"
                ),
            },
        })
    }

    fn initializer(&self, rank: usize, n: usize) -> Initializer {
        match self {
            InitKind::Caldera => Initializer::Zero,
            InitKind::LrFirst => Initializer::LrApproxW,
            InitKind::Odlri => Initializer::Odlri {
                k: Initializer::odlri_k(rank, n),
            },
            InitKind::OdlriK(k) => Initializer::Odlri { k: *k },
        }
    }
}

/// One compression run's configuration: the run-level execution knobs
/// (`outer_iters`, `lplr_iters`, `workers`, `seed`, `verbose`) plus the
/// **uniform recipe template** the per-projection fields describe. Pass it
/// straight to [`CompressionPipeline::run`] for the historical
/// one-recipe-everywhere behavior, or anchor a [`Planner`] /
/// [`CompressionPlan::parse`] on it for per-projection plans.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub init: InitKind,
    pub rank: usize,
    pub lr_bits: u32,
    pub q_scheme: String,
    pub q_bits: u32,
    pub q_group: usize,
    pub outer_iters: usize,
    pub lplr_iters: usize,
    pub hadamard: bool,
    pub workers: usize,
    pub seed: u64,
    /// Print per-matrix progress lines.
    pub verbose: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            init: InitKind::Odlri,
            rank: 64,
            lr_bits: 4,
            q_scheme: "e8".into(),
            q_bits: 2,
            q_group: 64,
            outer_iters: 15,
            lplr_iters: 10,
            hadamard: true,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            seed: 0,
            verbose: false,
        }
    }
}

/// Pipeline output: the compressed model, the plan it ran under, and
/// per-matrix metric traces.
pub struct PipelineResult {
    pub model: CompressedModel,
    pub plan: CompressionPlan,
    pub traces: BTreeMap<String, DecompMetrics>,
    pub wall_secs: f64,
}

/// The compression pipeline coordinator.
pub struct CompressionPipeline {
    pub config: PipelineConfig,
}

impl CompressionPipeline {
    pub fn new(config: PipelineConfig) -> CompressionPipeline {
        CompressionPipeline { config }
    }

    fn joint_config(&self, mp: &MatrixPlan, seed: u64) -> JointConfig {
        JointConfig {
            outer_iters: self.config.outer_iters,
            lowrank: LowRankConfig {
                rank: mp.rank,
                lr_bits: mp.lr_bits,
                lplr_iters: self.config.lplr_iters,
                reg: 1e-4,
            },
            hadamard: mp.hadamard,
            reg: 1e-4,
            seed,
        }
    }

    /// Compress every projection under the uniform plan of `config` — the
    /// historical `PipelineConfig` behavior, bit-identically.
    pub fn run(
        &self,
        params: &ModelParams,
        hessians: &BTreeMap<String, Hessian>,
    ) -> Result<PipelineResult> {
        let plan = CompressionPlan::uniform(&params.family, &self.config);
        self.run_plan(params, hessians, &plan)
    }

    /// Compress every projection of `params` under a per-projection plan,
    /// given per-projection Hessians. Each job gets its own quantizer and
    /// joint-optimizer configuration from its [`MatrixPlan`]; per-job RNG
    /// streams are derived from the matrix name and run seed only, so the
    /// result is bit-identical regardless of worker count.
    pub fn run_plan(
        &self,
        params: &ModelParams,
        hessians: &BTreeMap<String, Hessian>,
        plan: &CompressionPlan,
    ) -> Result<PipelineResult> {
        let t0 = Instant::now();
        let cfg = &self.config;
        let fam = params.family.clone();
        plan.validate(&fam)?;
        let names: Vec<String> = fam.projections.clone();
        for name in &names {
            if !hessians.contains_key(name) {
                return Err(anyhow!("missing Hessian for projection '{name}'"));
            }
        }

        // When the pool is wide, keep per-job matmuls single-threaded to
        // avoid oversubscription. The counted RAII scope releases on drop
        // (normal exit AND every `?` below) and composes with concurrent
        // pipelines without ever touching the configured thread budget.
        let _thread_cap = (cfg.workers > 1).then(tensor::MatmulSingleThreadScope::enter);
        let jobs: Vec<(String, crate::tensor::Matrix, &Hessian)> = names
            .iter()
            .map(|name| {
                Ok((
                    name.clone(),
                    params.get_matrix(name)?,
                    hessians.get(name).unwrap(),
                ))
            })
            .collect::<Result<_>>()?;

        let results = exec::parallel_map(jobs.len(), cfg.workers, |i| {
            let (name, w, hess) = &jobs[i];
            let mp = plan.get(name).expect("plan validated against family");
            // Quantizers are stateless value objects: building one per job
            // from the plan is deterministic and cheap.
            let quantizer = mp.quantizer().expect("plan validated");
            // Deterministic per-job stream: depends on the matrix name and
            // the run seed only — NOT on scheduling or the plan's shape.
            let job_seed = cfg.seed ^ fnv1a(name.as_bytes());
            let jc = self.joint_config(mp, job_seed);
            let init = mp.init.initializer(mp.rank, w.cols());
            let opt = JointOptimizer::new(quantizer.as_ref(), jc);
            let d = opt.run(w, hess, &init);
            if cfg.verbose {
                let last = d.metrics.last().unwrap();
                eprintln!(
                    "  [compress] {name:<16} err={:.4e} scale={:.4} [{}]",
                    last.act_err,
                    last.quant_scale,
                    mp.summary()
                );
            }
            (name.clone(), d)
        });
        drop(_thread_cap);

        let mut matrices = BTreeMap::new();
        let mut traces = BTreeMap::new();
        for (name, d) in results {
            let mp = plan.get(&name).unwrap();
            let shape = fam.param_shape(&name)?;
            // Per-quantizer bit overhead depends on the matrix shape
            // (scales amortize over more or fewer weights) and now on the
            // projection's own scheme: each matrix carries its own value;
            // model-level numbers are parameter-weighted aggregates.
            let q_bits_overhead = mp.quantizer()?.bits_with_overhead(shape[0], shape[1]);
            let last = d.metrics.last().unwrap();
            matrices.insert(
                name.clone(),
                CompressedMatrix {
                    q: d.q,
                    q_packed: d.q_packed,
                    lr: d.lr,
                    quant_scale: last.quant_scale,
                    final_act_err: last.act_err,
                    plan: mp.clone(),
                    q_bits_overhead,
                },
            );
            traces.insert(name, d.metrics);
        }

        Ok(PipelineResult {
            model: CompressedModel {
                family: fam,
                matrices,
            },
            plan: plan.clone(),
            traces,
            wall_secs: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::{synthetic_calib, synthetic_weight};
    use crate::quant::make_quantizer;
    use crate::runtime::FamilySpec;
    use crate::runtime::Value;
    use crate::testing;

    fn toy_family() -> FamilySpec {
        FamilySpec {
            name: "toy".into(),
            params: vec![
                ("embed".into(), vec![32, 24]),
                ("layer0.ln1".into(), vec![24]),
                ("layer0.wq".into(), vec![24, 24]),
                ("layer0.wk".into(), vec![24, 24]),
                ("layer0.wv".into(), vec![24, 24]),
                ("layer0.wo".into(), vec![24, 24]),
                ("layer0.ln2".into(), vec![24]),
                ("layer0.wgate".into(), vec![40, 24]),
                ("layer0.wup".into(), vec![40, 24]),
                ("layer0.wdown".into(), vec![24, 40]),
                ("ln_f".into(), vec![24]),
                ("unembed".into(), vec![32, 24]),
            ],
            projections: vec![
                "layer0.wq".into(),
                "layer0.wk".into(),
                "layer0.wv".into(),
                "layer0.wo".into(),
                "layer0.wgate".into(),
                "layer0.wup".into(),
                "layer0.wdown".into(),
            ],
            vocab: 32,
            d_model: 24,
            n_layers: 1,
            d_ff: 40,
            n_heads: 4,
            n_kv_heads: 4,
            mlp: "swiglu".into(),
            rope_theta: 10000.0,
        }
    }

    fn toy_setup() -> (ModelParams, BTreeMap<String, Hessian>) {
        // A small single-layer family with planted outliers.
        let fam = toy_family();
        let mut params = ModelParams::init(&fam, 7);
        let mut hessians = BTreeMap::new();
        for name in fam.projections.clone() {
            let shape = fam.param_shape(&name).unwrap().to_vec();
            let calib = synthetic_calib(shape[1], 3 * shape[1], 2, 20.0, fnv1a(name.as_bytes()));
            let w = synthetic_weight(shape[0], shape[1], &calib.outlier_channels, 3);
            params
                .set_matrix(&name, &w)
                .unwrap();
            hessians.insert(name, calib.hessian);
        }
        // keep embed/norms as initialized
        let _ = &params.values[0] as &Value;
        (params, hessians)
    }

    /// Like [`toy_setup`], but the planted outlier mass differs sharply per
    /// projection — the structure a sensitivity-driven planner must key on.
    fn skewed_setup() -> (ModelParams, BTreeMap<String, Hessian>) {
        let fam = toy_family();
        let mut params = ModelParams::init(&fam, 7);
        let mut hessians = BTreeMap::new();
        let counts: &[(&str, usize)] = &[
            ("layer0.wq", 6),
            ("layer0.wk", 0),
            ("layer0.wv", 0),
            ("layer0.wo", 0),
            ("layer0.wgate", 4),
            ("layer0.wup", 0),
            ("layer0.wdown", 0),
        ];
        for &(name, n_out) in counts {
            let shape = fam.param_shape(name).unwrap().to_vec();
            let calib =
                synthetic_calib(shape[1], 3 * shape[1], n_out, 25.0, fnv1a(name.as_bytes()));
            let w = synthetic_weight(shape[0], shape[1], &calib.outlier_channels, 3);
            params.set_matrix(name, &w).unwrap();
            hessians.insert(name.to_string(), calib.hessian);
        }
        (params, hessians)
    }

    fn quick_cfg(init: InitKind, workers: usize) -> PipelineConfig {
        PipelineConfig {
            init,
            rank: 6,
            lr_bits: 16,
            outer_iters: 3,
            lplr_iters: 2,
            workers,
            ..Default::default()
        }
    }

    #[test]
    fn pipeline_compresses_all_projections() {
        let (params, hessians) = toy_setup();
        let pipe = CompressionPipeline::new(quick_cfg(InitKind::Odlri, 2));
        let out = pipe.run(&params, &hessians).unwrap();
        assert_eq!(out.model.matrices.len(), 7);
        assert_eq!(out.traces.len(), 7);
        assert!(out.plan.is_uniform());
        for (name, cm) in &out.model.matrices {
            assert!(cm.final_act_err < 1.0, "{name}: err={}", cm.final_act_err);
            assert!(cm.reconstruct().is_finite());
            // Deployment invariant: the packed codes are the pipeline's Q.
            assert_eq!(
                cm.q_packed.unpack().max_abs_diff(&cm.q),
                0.0,
                "{name}: packed Q is not the pipeline's Q"
            );
            // Per-matrix bookkeeping rides along.
            assert_eq!(cm.plan.rank, 6);
            assert!(cm.q_bits_overhead > 2.0 && cm.avg_bits() > cm.q_bits_overhead);
        }
        // Reconstructions approximate the originals.
        let w = params.get_matrix("layer0.wq").unwrap();
        let rec = out.model.matrices["layer0.wq"].reconstruct();
        assert!(rec.rel_err(&w) < 0.8);
    }

    /// The back-compat invariant: a uniform plan through the plan-aware
    /// pipeline is bit-identical to the pre-redesign behavior — one shared
    /// quantizer, the global `JointConfig`, and per-name seeds. Same Q, L,
    /// R per projection, exactly.
    #[test]
    fn uniform_plan_matches_pre_redesign_pipeline_bit_exactly() {
        let (params, hessians) = toy_setup();
        let cfg = quick_cfg(InitKind::Odlri, 3);
        let out = CompressionPipeline::new(cfg.clone())
            .run(&params, &hessians)
            .unwrap();
        // Reference: the historical construction, spelled out.
        let quantizer = make_quantizer(&cfg.q_scheme, cfg.q_bits, cfg.q_group).unwrap();
        for name in &params.family.projections {
            let w = params.get_matrix(name).unwrap();
            let jc = JointConfig {
                outer_iters: cfg.outer_iters,
                lowrank: LowRankConfig {
                    rank: cfg.rank,
                    lr_bits: cfg.lr_bits,
                    lplr_iters: cfg.lplr_iters,
                    reg: 1e-4,
                },
                hadamard: cfg.hadamard,
                reg: 1e-4,
                seed: cfg.seed ^ fnv1a(name.as_bytes()),
            };
            let init = cfg.init.initializer(cfg.rank, w.cols());
            let d = JointOptimizer::new(quantizer.as_ref(), jc).run(
                &w,
                &hessians[name],
                &init,
            );
            let cm = &out.model.matrices[name];
            assert_eq!(d.q, cm.q, "{name}: Q differs from pre-redesign run");
            assert_eq!(d.lr.l, cm.lr.l, "{name}: L differs");
            assert_eq!(d.lr.r, cm.lr.r, "{name}: R differs");
            assert_eq!(
                d.q_packed.unpack().max_abs_diff(&cm.q_packed.unpack()),
                0.0,
                "{name}: packed codes differ"
            );
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let (params, hessians) = toy_setup();
        let a = CompressionPipeline::new(quick_cfg(InitKind::Odlri, 1))
            .run(&params, &hessians)
            .unwrap();
        let b = CompressionPipeline::new(quick_cfg(InitKind::Odlri, 4))
            .run(&params, &hessians)
            .unwrap();
        for name in a.model.matrices.keys() {
            let qa = &a.model.matrices[name].q;
            let qb = &b.model.matrices[name].q;
            assert_eq!(qa, qb, "{name} Q differs across worker counts");
            assert_eq!(
                a.model.matrices[name].lr.l, b.model.matrices[name].lr.l,
                "{name} L differs"
            );
        }
    }

    #[test]
    fn q_bits_overhead_is_parameter_weighted_over_all_projections() {
        // The toy family mixes 24×24 attention and 40×24 / 24×40 MLP
        // projections; the default E8 quantizer's overhead (one 32-bit
        // scale per matrix) therefore differs per shape. The model-level
        // value must be the parameter-weighted mean over ALL projections.
        let (params, hessians) = toy_setup();
        let cfg = quick_cfg(InitKind::Caldera, 2);
        let out = CompressionPipeline::new(cfg.clone())
            .run(&params, &hessians)
            .unwrap();
        let quantizer = make_quantizer(&cfg.q_scheme, cfg.q_bits, cfg.q_group).unwrap();
        let fam = &params.family;
        let mut want_num = 0.0f64;
        let mut want_den = 0.0f64;
        let mut per_matrix: Vec<f64> = Vec::new();
        for name in &fam.projections {
            let s = fam.param_shape(name).unwrap();
            let b = quantizer.bits_with_overhead(s[0], s[1]);
            per_matrix.push(b);
            want_num += b * (s[0] * s[1]) as f64;
            want_den += (s[0] * s[1]) as f64;
        }
        let want = want_num / want_den;
        assert!(
            (out.model.q_bits_overhead() - want).abs() < 1e-12,
            "got {} want {want}",
            out.model.q_bits_overhead()
        );
        // The family genuinely has differently-shaped projections, so the
        // weighted mean sits strictly between the extremes.
        let lo = per_matrix.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = per_matrix
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(lo < hi, "test family needs projections with different shapes");
        assert!(out.model.q_bits_overhead() > lo && out.model.q_bits_overhead() < hi);
        assert!(out.model.avg_bits().is_finite() && out.model.avg_bits() > 0.0);
    }

    #[test]
    fn missing_hessian_is_an_error() {
        let (params, mut hessians) = toy_setup();
        hessians.remove("layer0.wv");
        let pipe = CompressionPipeline::new(quick_cfg(InitKind::Caldera, 1));
        assert!(pipe.run(&params, &hessians).is_err());
    }

    #[test]
    fn odlri_beats_caldera_on_planted_outliers() {
        // The pipeline-level analogue of the Figure 3 claim.
        let (params, hessians) = toy_setup();
        let run = |init| {
            CompressionPipeline::new(quick_cfg(init, 2))
                .run(&params, &hessians)
                .unwrap()
                .model
                .mean_act_err()
        };
        // With only 3 quick outer iterations the gap is modest and can be
        // noisy at this scale; the strong per-iteration claims are asserted
        // in decompose::tests and reproduced at paper scale by `exp fig3`.
        let e_caldera = run(InitKind::Caldera);
        let e_odlri = run(InitKind::OdlriK(2));
        assert!(
            e_odlri < e_caldera * 1.10,
            "odlri={e_odlri:.4e} caldera={e_caldera:.4e}"
        );
    }

    /// A heterogeneous plan flows through the whole pipeline: every matrix
    /// is compressed under its own recipe and carries its own bookkeeping.
    #[test]
    fn heterogeneous_plan_runs_end_to_end() {
        let (params, hessians) = toy_setup();
        let fam = &params.family;
        let cfg = quick_cfg(InitKind::Caldera, 2);
        let mut map = std::collections::BTreeMap::new();
        for name in &fam.projections {
            map.insert(name.clone(), MatrixPlan::from_config(&cfg));
        }
        map.get_mut("layer0.wq").unwrap().rank = 12;
        map.get_mut("layer0.wq").unwrap().init = InitKind::OdlriK(2);
        map.get_mut("layer0.wk").unwrap().rank = 0;
        map.get_mut("layer0.wup").unwrap().q_scheme = "uniform".into();
        map.get_mut("layer0.wup").unwrap().q_bits = 4;
        map.get_mut("layer0.wup").unwrap().q_group = 8;
        let plan = CompressionPlan::new(map, fam).unwrap();
        assert!(!plan.is_uniform());
        let out = CompressionPipeline::new(cfg)
            .run_plan(&params, &hessians, &plan)
            .unwrap();
        let wq = &out.model.matrices["layer0.wq"];
        let wk = &out.model.matrices["layer0.wk"];
        let wup = &out.model.matrices["layer0.wup"];
        assert_eq!(wq.rank(), 12);
        assert_eq!(wk.rank(), 0);
        assert_eq!(wup.q_packed.scheme.name(), "uniform");
        assert_eq!(wq.q_packed.scheme.name(), "e8");
        // Packed exactness holds per scheme.
        for (name, cm) in &out.model.matrices {
            assert_eq!(
                cm.q_packed.unpack().max_abs_diff(&cm.q),
                0.0,
                "{name}: packed Q not bit-exact under heterogeneous plan"
            );
        }
        // Model aggregates reflect the mix: wq (more rank) is costlier than
        // wk (rank 0).
        assert!(wq.avg_bits() > wk.avg_bits());
        assert!(out.model.avg_bits().is_finite());
    }

    /// The budget planner must (a) respect the ceiling, (b) discriminate —
    /// outlier-heavy projections get the capacity — and (c) produce a model
    /// whose *reported* avg_bits also respects the ceiling.
    #[test]
    fn budget_planner_allocates_capacity_to_outlier_projections() {
        let (params, hessians) = skewed_setup();
        let fam = &params.family;
        let base = PipelineConfig {
            rank: 8,
            lr_bits: 4,
            outer_iters: 2,
            lplr_iters: 2,
            workers: 2,
            ..Default::default()
        };
        // Pick a budget strictly between the floor plan (rank 2) and the
        // full uniform plan (rank 8): enough to fund both outlier-heavy
        // projections' rank upgrades, not enough to reach the flat ones.
        let floor_cfg = PipelineConfig {
            rank: 2,
            ..base.clone()
        };
        let lo = CompressionPlan::uniform(fam, &floor_cfg)
            .avg_bits(fam)
            .unwrap();
        let hi = CompressionPlan::uniform(fam, &base).avg_bits(fam).unwrap();
        assert!(lo < hi);
        let budget = lo + 0.7 * (hi - lo);
        let plan = BudgetPlanner::new(budget, base.clone())
            .plan(&params, &hessians)
            .unwrap();
        assert!(
            plan.avg_bits(fam).unwrap() <= budget + 1e-9,
            "plan {:.4} over budget {budget:.4}",
            plan.avg_bits(fam).unwrap()
        );
        // Heterogeneous: ranks/bits are NOT all equal.
        let (rlo, rhi) = plan.rank_spread();
        assert!(rlo < rhi, "budget plan degenerated to uniform ranks");
        // Capacity follows outliers: the heaviest projection beats the
        // outlier-free ones.
        let r_wq = plan.get("layer0.wq").unwrap().rank;
        let r_wk = plan.get("layer0.wk").unwrap().rank;
        let r_wo = plan.get("layer0.wo").unwrap().rank;
        assert!(
            r_wq > r_wk && r_wq > r_wo,
            "outlier-heavy wq (r={r_wq}) must out-rank outlier-free wk (r={r_wk}) / wo (r={r_wo})"
        );
        // End to end: the compressed model's reported bits stay ≤ budget.
        let out = CompressionPipeline::new(base)
            .run_plan(&params, &hessians, &plan)
            .unwrap();
        assert!(
            out.model.avg_bits() <= budget + 1e-9,
            "reported {:.4} over budget {budget:.4}",
            out.model.avg_bits()
        );
        // And the realized per-matrix ranks mirror the plan's skew.
        assert!(
            out.model.matrices["layer0.wq"].rank()
                > out.model.matrices["layer0.wk"].rank()
        );
    }

    #[test]
    fn init_kind_k_schedule() {
        let i = InitKind::Odlri.initializer(256, 4096);
        assert_eq!(i, Initializer::Odlri { k: 16 });
        let i = InitKind::OdlriK(3).initializer(256, 4096);
        assert_eq!(i, Initializer::Odlri { k: 3 });
        assert_eq!(InitKind::Caldera.initializer(8, 8), Initializer::Zero);
    }

    #[test]
    fn init_kind_parse_roundtrips_with_name() {
        testing::quick("initkind-roundtrip", |rng| {
            let k = 1 + rng.below(512);
            for kind in [
                InitKind::Caldera,
                InitKind::LrFirst,
                InitKind::Odlri,
                InitKind::OdlriK(k),
            ] {
                assert_eq!(InitKind::parse(&kind.name()).unwrap(), kind, "{kind:?}");
            }
        });
        // Aliases and rejects.
        assert_eq!(InitKind::parse("zero").unwrap(), InitKind::Caldera);
        assert_eq!(InitKind::parse("lrapprox").unwrap(), InitKind::LrFirst);
        assert!(InitKind::parse("bogus").is_err());
        assert!(InitKind::parse("odlri-kx").is_err());
        assert!(InitKind::parse("").is_err());
    }
}
