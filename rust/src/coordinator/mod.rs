//! The Layer-3 coordinator: whole-model compression pipeline.
//!
//! Builds one `DecompositionJob` per projection matrix, schedules them over
//! a deterministic worker pool ([`crate::exec`]), and assembles the
//! [`CompressedModel`]. Per-job RNG streams are derived from the matrix
//! name, so the result is bit-identical regardless of worker count
//! (property-tested below).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::decompose::{DecompMetrics, Initializer, JointConfig, JointOptimizer};
use crate::exec;
use crate::hessian::Hessian;
use crate::lowrank::LowRankConfig;
use crate::model::{CompressedMatrix, CompressedModel, ModelParams};
use crate::quant::{make_quantizer, Quantizer};
use crate::tensor;
use crate::util::fnv1a;

/// Which LR initializer the pipeline uses.
#[derive(Clone, Debug, PartialEq)]
pub enum InitKind {
    /// CALDERA default (zero init).
    Caldera,
    /// LRApprox(W) init.
    LrFirst,
    /// CALDERA + ODLRI with the paper's k-schedule (App. B.2).
    Odlri,
    /// ODLRI with an explicit k (ablations, Table 5).
    OdlriK(usize),
}

impl InitKind {
    pub fn name(&self) -> String {
        match self {
            InitKind::Caldera => "caldera".into(),
            InitKind::LrFirst => "lr-first".into(),
            InitKind::Odlri => "odlri".into(),
            InitKind::OdlriK(k) => format!("odlri-k{k}"),
        }
    }

    fn initializer(&self, rank: usize, n: usize) -> Initializer {
        match self {
            InitKind::Caldera => Initializer::Zero,
            InitKind::LrFirst => Initializer::LrApproxW,
            InitKind::Odlri => Initializer::Odlri {
                k: Initializer::odlri_k(rank, n),
            },
            InitKind::OdlriK(k) => Initializer::Odlri { k: *k },
        }
    }
}

/// Pipeline configuration (one compression run over a model).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub init: InitKind,
    pub rank: usize,
    pub lr_bits: u32,
    pub q_scheme: String,
    pub q_bits: u32,
    pub q_group: usize,
    pub outer_iters: usize,
    pub lplr_iters: usize,
    pub hadamard: bool,
    pub workers: usize,
    pub seed: u64,
    /// Print per-matrix progress lines.
    pub verbose: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            init: InitKind::Odlri,
            rank: 64,
            lr_bits: 4,
            q_scheme: "e8".into(),
            q_bits: 2,
            q_group: 64,
            outer_iters: 15,
            lplr_iters: 10,
            hadamard: true,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            seed: 0,
            verbose: false,
        }
    }
}

/// Pipeline output: the compressed model plus per-matrix metric traces.
pub struct PipelineResult {
    pub model: CompressedModel,
    pub traces: BTreeMap<String, DecompMetrics>,
    pub wall_secs: f64,
}

/// The compression pipeline coordinator.
pub struct CompressionPipeline {
    pub config: PipelineConfig,
}

impl CompressionPipeline {
    pub fn new(config: PipelineConfig) -> CompressionPipeline {
        CompressionPipeline { config }
    }

    fn joint_config(&self, seed: u64) -> JointConfig {
        JointConfig {
            outer_iters: self.config.outer_iters,
            lowrank: LowRankConfig {
                rank: self.config.rank,
                lr_bits: self.config.lr_bits,
                lplr_iters: self.config.lplr_iters,
                reg: 1e-4,
            },
            hadamard: self.config.hadamard,
            reg: 1e-4,
            seed,
        }
    }

    /// Compress every projection of `params` given per-projection Hessians.
    pub fn run(
        &self,
        params: &ModelParams,
        hessians: &BTreeMap<String, Hessian>,
    ) -> Result<PipelineResult> {
        let t0 = Instant::now();
        let cfg = &self.config;
        let fam = params.family.clone();
        let names: Vec<String> = fam.projections.clone();
        for name in &names {
            if !hessians.contains_key(name) {
                return Err(anyhow!("missing Hessian for projection '{name}'"));
            }
        }
        let quantizer: Box<dyn Quantizer> =
            make_quantizer(&cfg.q_scheme, cfg.q_bits, cfg.q_group)?;

        // When the pool is wide, keep per-job matmuls single-threaded to
        // avoid oversubscription; restore afterwards.
        if cfg.workers > 1 {
            tensor::set_matmul_threads(1);
        }
        let jobs: Vec<(String, crate::tensor::Matrix, &Hessian)> = names
            .iter()
            .map(|name| {
                Ok((
                    name.clone(),
                    params.get_matrix(name)?,
                    hessians.get(name).unwrap(),
                ))
            })
            .collect::<Result<_>>()?;

        let results = exec::parallel_map(jobs.len(), cfg.workers, |i| {
            let (name, w, hess) = &jobs[i];
            // Deterministic per-job stream: depends on the matrix name and
            // the run seed only — NOT on scheduling.
            let job_seed = cfg.seed ^ fnv1a(name.as_bytes());
            let jc = self.joint_config(job_seed);
            let init = cfg.init.initializer(cfg.rank, w.cols());
            let opt = JointOptimizer::new(quantizer.as_ref(), jc);
            let d = opt.run(w, hess, &init);
            if cfg.verbose {
                let last = d.metrics.last().unwrap();
                eprintln!(
                    "  [compress] {name:<16} err={:.4e} scale={:.4}",
                    last.act_err, last.quant_scale
                );
            }
            (name.clone(), d)
        });
        tensor::set_matmul_threads(0);

        let mut matrices = BTreeMap::new();
        let mut traces = BTreeMap::new();
        // Per-quantizer bit overhead depends on the matrix shape (scales
        // amortize over more or fewer weights), and projections differ in
        // shape (attention vs MLP). The reported model overhead is the
        // parameter-weighted mean over ALL projections — not whichever
        // matrix happened to be processed last.
        let mut overhead_weighted = 0.0f64;
        let mut overhead_params = 0.0f64;
        for (name, d) in results {
            let shape = fam.param_shape(&name)?;
            let count = (shape[0] * shape[1]) as f64;
            overhead_weighted += quantizer.bits_with_overhead(shape[0], shape[1]) * count;
            overhead_params += count;
            let last = d.metrics.last().unwrap();
            matrices.insert(
                name.clone(),
                CompressedMatrix {
                    q: d.q,
                    q_packed: d.q_packed,
                    lr: d.lr,
                    quant_scale: last.quant_scale,
                    final_act_err: last.act_err,
                },
            );
            traces.insert(name, d.metrics);
        }

        let q_bits_overhead = if overhead_params == 0.0 {
            quantizer.bits()
        } else {
            overhead_weighted / overhead_params
        };

        Ok(PipelineResult {
            model: CompressedModel {
                family: fam,
                matrices,
                rank: cfg.rank,
                q_bits_overhead,
                lr_bits: cfg.lr_bits,
            },
            traces,
            wall_secs: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::{synthetic_calib, synthetic_weight};
    use crate::runtime::FamilySpec;
    use crate::runtime::Value;

    fn toy_setup() -> (ModelParams, BTreeMap<String, Hessian>) {
        // A small single-layer family with planted outliers.
        let fam = FamilySpec {
            name: "toy".into(),
            params: vec![
                ("embed".into(), vec![32, 24]),
                ("layer0.ln1".into(), vec![24]),
                ("layer0.wq".into(), vec![24, 24]),
                ("layer0.wk".into(), vec![24, 24]),
                ("layer0.wv".into(), vec![24, 24]),
                ("layer0.wo".into(), vec![24, 24]),
                ("layer0.ln2".into(), vec![24]),
                ("layer0.wgate".into(), vec![40, 24]),
                ("layer0.wup".into(), vec![40, 24]),
                ("layer0.wdown".into(), vec![24, 40]),
                ("ln_f".into(), vec![24]),
                ("unembed".into(), vec![32, 24]),
            ],
            projections: vec![
                "layer0.wq".into(),
                "layer0.wk".into(),
                "layer0.wv".into(),
                "layer0.wo".into(),
                "layer0.wgate".into(),
                "layer0.wup".into(),
                "layer0.wdown".into(),
            ],
            vocab: 32,
            d_model: 24,
            n_layers: 1,
            d_ff: 40,
            n_heads: 4,
            n_kv_heads: 4,
            mlp: "swiglu".into(),
            rope_theta: 10000.0,
        };
        let mut params = ModelParams::init(&fam, 7);
        let mut hessians = BTreeMap::new();
        for name in fam.projections.clone() {
            let shape = fam.param_shape(&name).unwrap().to_vec();
            let calib = synthetic_calib(shape[1], 3 * shape[1], 2, 20.0, fnv1a(name.as_bytes()));
            let w = synthetic_weight(shape[0], shape[1], &calib.outlier_channels, 3);
            params
                .set_matrix(&name, &w)
                .unwrap();
            hessians.insert(name, calib.hessian);
        }
        // keep embed/norms as initialized
        let _ = &params.values[0] as &Value;
        (params, hessians)
    }

    fn quick_cfg(init: InitKind, workers: usize) -> PipelineConfig {
        PipelineConfig {
            init,
            rank: 6,
            lr_bits: 16,
            outer_iters: 3,
            lplr_iters: 2,
            workers,
            ..Default::default()
        }
    }

    #[test]
    fn pipeline_compresses_all_projections() {
        let (params, hessians) = toy_setup();
        let pipe = CompressionPipeline::new(quick_cfg(InitKind::Odlri, 2));
        let out = pipe.run(&params, &hessians).unwrap();
        assert_eq!(out.model.matrices.len(), 7);
        assert_eq!(out.traces.len(), 7);
        for (name, cm) in &out.model.matrices {
            assert!(cm.final_act_err < 1.0, "{name}: err={}", cm.final_act_err);
            assert!(cm.reconstruct().is_finite());
            // Deployment invariant: the packed codes are the pipeline's Q.
            assert_eq!(
                cm.q_packed.unpack().max_abs_diff(&cm.q),
                0.0,
                "{name}: packed Q is not the pipeline's Q"
            );
        }
        // Reconstructions approximate the originals.
        let w = params.get_matrix("layer0.wq").unwrap();
        let rec = out.model.matrices["layer0.wq"].reconstruct();
        assert!(rec.rel_err(&w) < 0.8);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let (params, hessians) = toy_setup();
        let a = CompressionPipeline::new(quick_cfg(InitKind::Odlri, 1))
            .run(&params, &hessians)
            .unwrap();
        let b = CompressionPipeline::new(quick_cfg(InitKind::Odlri, 4))
            .run(&params, &hessians)
            .unwrap();
        for name in a.model.matrices.keys() {
            let qa = &a.model.matrices[name].q;
            let qb = &b.model.matrices[name].q;
            assert_eq!(qa, qb, "{name} Q differs across worker counts");
            assert_eq!(
                a.model.matrices[name].lr.l, b.model.matrices[name].lr.l,
                "{name} L differs"
            );
        }
    }

    #[test]
    fn q_bits_overhead_is_parameter_weighted_over_all_projections() {
        // The toy family mixes 24×24 attention and 40×24 / 24×40 MLP
        // projections; the default E8 quantizer's overhead (one 32-bit
        // scale per matrix) therefore differs per shape. The model-level
        // value must be the parameter-weighted mean over ALL projections —
        // the old code reported whichever matrix sorted last.
        let (params, hessians) = toy_setup();
        let cfg = quick_cfg(InitKind::Caldera, 2);
        let out = CompressionPipeline::new(cfg.clone())
            .run(&params, &hessians)
            .unwrap();
        let quantizer = make_quantizer(&cfg.q_scheme, cfg.q_bits, cfg.q_group).unwrap();
        let fam = &params.family;
        let mut want_num = 0.0f64;
        let mut want_den = 0.0f64;
        let mut per_matrix: Vec<f64> = Vec::new();
        for name in &fam.projections {
            let s = fam.param_shape(name).unwrap();
            let b = quantizer.bits_with_overhead(s[0], s[1]);
            per_matrix.push(b);
            want_num += b * (s[0] * s[1]) as f64;
            want_den += (s[0] * s[1]) as f64;
        }
        let want = want_num / want_den;
        assert!(
            (out.model.q_bits_overhead - want).abs() < 1e-12,
            "got {} want {want}",
            out.model.q_bits_overhead
        );
        // The family genuinely has differently-shaped projections, so the
        // weighted mean sits strictly between the extremes — the old
        // "last one wins" value (an extreme) cannot equal it.
        let lo = per_matrix.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = per_matrix
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(lo < hi, "test family needs projections with different shapes");
        assert!(out.model.q_bits_overhead > lo && out.model.q_bits_overhead < hi);
        assert!(out.model.avg_bits().is_finite() && out.model.avg_bits() > 0.0);
    }

    #[test]
    fn missing_hessian_is_an_error() {
        let (params, mut hessians) = toy_setup();
        hessians.remove("layer0.wv");
        let pipe = CompressionPipeline::new(quick_cfg(InitKind::Caldera, 1));
        assert!(pipe.run(&params, &hessians).is_err());
    }

    #[test]
    fn odlri_beats_caldera_on_planted_outliers() {
        // The pipeline-level analogue of the Figure 3 claim.
        let (params, hessians) = toy_setup();
        let run = |init| {
            CompressionPipeline::new(quick_cfg(init, 2))
                .run(&params, &hessians)
                .unwrap()
                .model
                .mean_act_err()
        };
        // With only 3 quick outer iterations the gap is modest and can be
        // noisy at this scale; the strong per-iteration claims are asserted
        // in decompose::tests and reproduced at paper scale by `exp fig3`.
        let e_caldera = run(InitKind::Caldera);
        let e_odlri = run(InitKind::OdlriK(2));
        assert!(
            e_odlri < e_caldera * 1.10,
            "odlri={e_odlri:.4e} caldera={e_caldera:.4e}"
        );
    }

    #[test]
    fn init_kind_k_schedule() {
        let i = InitKind::Odlri.initializer(256, 4096);
        assert_eq!(i, Initializer::Odlri { k: 16 });
        let i = InitKind::OdlriK(3).initializer(256, 4096);
        assert_eq!(i, Initializer::Odlri { k: 3 });
        assert_eq!(InitKind::Caldera.initializer(8, 8), Initializer::Zero);
    }
}
