//! Timing harness for `harness = false` bench targets (criterion is not in
//! the offline vendor set, so we provide the subset we need: warmup,
//! repeated timed runs, median/mean/p95, throughput, a stable one-line
//! report format consumed by EXPERIMENTS.md §Perf, and a machine-readable
//! JSON sink ([`JsonReport`] → `BENCH_<label>.json`) so the repo keeps a
//! perf trajectory across PRs. Bench binaries share one argument grammar
//! ([`BenchArgs`]): `--fast` shrinks every case's time budget (the CI
//! mode), positional args filter groups by substring.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::Json;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn line(&self) -> String {
        format!(
            "{:<44} iters={:<4} min={:>10} median={:>10} mean={:>10} p95={:>10}",
            self.name,
            self.iters,
            crate::util::human_secs(self.min_s),
            crate::util::human_secs(self.median_s),
            crate::util::human_secs(self.mean_s),
            crate::util::human_secs(self.p95_s),
        )
    }

    /// Throughput line given an item count per iteration.
    pub fn line_throughput(&self, items: f64, unit: &str) -> String {
        format!(
            "{}  [{:.3e} {unit}/s]",
            self.line(),
            items / self.median_s
        )
    }
}

/// A tiny bencher: `Bencher::new("name").run(|| work())`.
pub struct Bencher {
    name: String,
    min_iters: usize,
    max_iters: usize,
    target_secs: f64,
    warmup_iters: usize,
}

impl Bencher {
    pub fn new(name: &str) -> Bencher {
        Bencher {
            name: name.to_string(),
            min_iters: 5,
            max_iters: 200,
            target_secs: 1.0,
            warmup_iters: 2,
        }
    }

    pub fn fast(mut self) -> Bencher {
        self.target_secs = 0.3;
        self.max_iters = 50;
        self
    }

    pub fn iters(mut self, min: usize, max: usize) -> Bencher {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Override the per-case wall-time budget (the `--fast` CI mode).
    pub fn budget(mut self, target_secs: f64) -> Bencher {
        self.target_secs = target_secs;
        self
    }

    /// Run the closure repeatedly; uses the closure's return value as a
    /// black-box sink so the optimizer cannot elide the work.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::new();
        let start_all = Instant::now();
        while times.len() < self.min_iters
            || (start_all.elapsed().as_secs_f64() < self.target_secs
                && times.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        BenchStats {
            name: self.name.clone(),
            iters: n,
            mean_s: mean,
            median_s: times[n / 2],
            p95_s: times[(n as f64 * 0.95) as usize % n.max(1)],
            min_s: times[0],
        }
    }
}

/// Prevent the optimizer from removing a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Shared CLI grammar for the `harness = false` bench binaries:
/// `cargo bench --bench bench_kernels -- [--fast] [group-filter]...`.
pub struct BenchArgs {
    /// CI mode: shrink each case's time budget so a full group finishes in
    /// seconds rather than minutes.
    pub fast: bool,
    filters: Vec<String>,
}

impl BenchArgs {
    pub fn from_env() -> BenchArgs {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        BenchArgs {
            fast: argv.iter().any(|a| a == "--fast"),
            filters: argv.into_iter().filter(|a| !a.starts_with("--")).collect(),
        }
    }

    /// Should a group with this name run? (no filters ⇒ everything runs)
    pub fn want(&self, group: &str) -> bool {
        self.filters.is_empty() || self.filters.iter().any(|f| group.contains(f.as_str()))
    }

    /// A [`Bencher`] honoring the `--fast` budget.
    pub fn bencher(&self, name: &str) -> Bencher {
        if self.fast {
            Bencher::new(name).iters(2, 12).budget(0.08)
        } else {
            Bencher::new(name).fast()
        }
    }
}

/// Machine-readable bench sink: collects one entry per benchmark next to
/// the printed human-readable lines and writes `BENCH_<label>.json`, so
/// kernel work leaves a perf trajectory (CI runs the decode group in
/// `--fast` mode and uploads the file as an artifact).
pub struct JsonReport {
    label: String,
    entries: Vec<Json>,
}

impl JsonReport {
    pub fn new(label: &str) -> JsonReport {
        JsonReport {
            label: label.to_string(),
            entries: Vec::new(),
        }
    }

    pub fn record(&mut self, s: &BenchStats) {
        self.record_with(s, None);
    }

    /// Record a benchmark with an optional `(items per iteration, unit)`
    /// throughput annotation (reported at the median, like the printed
    /// lines).
    pub fn record_with(&mut self, s: &BenchStats, throughput: Option<(f64, &str)>) {
        let mut e = Json::obj();
        e.set("name", Json::Str(s.name.clone()))
            .set("iters", Json::Num(s.iters as f64))
            .set("ns_per_iter", Json::Num(s.median_s * 1e9))
            .set("mean_ns", Json::Num(s.mean_s * 1e9))
            .set("min_ns", Json::Num(s.min_s * 1e9))
            .set("p95_ns", Json::Num(s.p95_s * 1e9));
        if let Some((items, unit)) = throughput {
            let mut t = Json::obj();
            t.set("unit", Json::Str(unit.to_string()))
                .set("per_sec", Json::Num(items / s.median_s.max(1e-12)));
            e.set("throughput", t);
        }
        self.entries.push(e);
    }

    /// Record a timing measured outside a [`Bencher`] run (e.g. the
    /// per-token decode table). `throughput` has the same meaning as in
    /// [`JsonReport::record_with`] — `(items per iteration, unit)`, with
    /// the rate derived from `ns_per_iter` — so the two entry points
    /// cannot silently disagree on units.
    pub fn record_value(&mut self, name: &str, ns_per_iter: f64, throughput: Option<(f64, &str)>) {
        let mut e = Json::obj();
        e.set("name", Json::Str(name.to_string()))
            .set("ns_per_iter", Json::Num(ns_per_iter));
        if let Some((items, unit)) = throughput {
            let mut t = Json::obj();
            t.set("unit", Json::Str(unit.to_string()))
                .set("per_sec", Json::Num(items / (ns_per_iter * 1e-9).max(1e-15)));
            e.set("throughput", t);
        }
        self.entries.push(e);
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Write `BENCH_<label>.json` into `dir`, returning the path.
    pub fn write(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let generated_by = format!("cargo bench --bench bench_{}", self.label);
        let mut root = Json::obj();
        root.set("bench", Json::Str(self.label.clone()));
        root.set("schema", Json::Num(1.0));
        root.set("generated_by", Json::Str(generated_by));
        root.set("entries", Json::Arr(self.entries.clone()));
        let path = dir.join(format!("BENCH_{}.json", self.label));
        std::fs::write(&path, format!("{root}\n"))?;
        Ok(path)
    }
}

/// Group header for bench output.
pub fn group(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let stats = Bencher::new("spin")
            .iters(3, 10)
            .run(|| {
                let mut s = 0u64;
                for i in 0..10_000 {
                    s = s.wrapping_add(i);
                }
                s
            });
        assert!(stats.iters >= 3);
        assert!(stats.min_s <= stats.median_s);
        assert!(stats.median_s <= stats.p95_s + 1e-9);
        assert!(stats.mean_s > 0.0);
        assert!(stats.line().contains("spin"));
    }

    #[test]
    fn json_report_roundtrips_through_parser() {
        let stats = Bencher::new("spin").iters(2, 4).budget(0.01).run(|| 1u32);
        let mut rep = JsonReport::new("testlabel");
        rep.record_with(&stats, Some((100.0, "rows")));
        rep.record_value("custom", 1250.0, Some((1.0, "tok")));
        assert!(!rep.is_empty());
        let dir = std::env::temp_dir().join("odlri_benchkit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = rep.write(&dir).unwrap();
        assert!(path.ends_with("BENCH_testlabel.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let j = Json::parse(text.trim()).unwrap();
        assert_eq!(j.req("bench").unwrap().as_str().unwrap(), "testlabel");
        let entries = j.req("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].req("name").unwrap().as_str().unwrap(), "spin");
        assert!(entries[0].req("ns_per_iter").unwrap().as_f64().unwrap() >= 0.0);
        let thr = entries[1].req("throughput").unwrap();
        assert_eq!(thr.req("unit").unwrap().as_str().unwrap(), "tok");
        // 1 item per 1250 ns ⇒ 800k/s, derived from ns_per_iter.
        let per_sec = thr.req("per_sec").unwrap().as_f64().unwrap();
        assert!((per_sec - 8e5).abs() < 1.0, "per_sec {per_sec}");
    }

    #[test]
    fn bench_args_filters_by_substring() {
        let args = BenchArgs {
            fast: true,
            filters: vec!["decode".into()],
        };
        assert!(args.want("decode"));
        assert!(args.want("decode-specialized"));
        assert!(!args.want("matmul"));
        let all = BenchArgs {
            fast: false,
            filters: Vec::new(),
        };
        assert!(all.want("anything"));
    }
}
