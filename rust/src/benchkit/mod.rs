//! Timing harness for `harness = false` bench targets (criterion is not in
//! the offline vendor set, so we provide the subset we need: warmup,
//! repeated timed runs, median/mean/p95, throughput, and a stable one-line
//! report format consumed by EXPERIMENTS.md §Perf).

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn line(&self) -> String {
        format!(
            "{:<44} iters={:<4} min={:>10} median={:>10} mean={:>10} p95={:>10}",
            self.name,
            self.iters,
            crate::util::human_secs(self.min_s),
            crate::util::human_secs(self.median_s),
            crate::util::human_secs(self.mean_s),
            crate::util::human_secs(self.p95_s),
        )
    }

    /// Throughput line given an item count per iteration.
    pub fn line_throughput(&self, items: f64, unit: &str) -> String {
        format!(
            "{}  [{:.3e} {unit}/s]",
            self.line(),
            items / self.median_s
        )
    }
}

/// A tiny bencher: `Bencher::new("name").run(|| work())`.
pub struct Bencher {
    name: String,
    min_iters: usize,
    max_iters: usize,
    target_secs: f64,
    warmup_iters: usize,
}

impl Bencher {
    pub fn new(name: &str) -> Bencher {
        Bencher {
            name: name.to_string(),
            min_iters: 5,
            max_iters: 200,
            target_secs: 1.0,
            warmup_iters: 2,
        }
    }

    pub fn fast(mut self) -> Bencher {
        self.target_secs = 0.3;
        self.max_iters = 50;
        self
    }

    pub fn iters(mut self, min: usize, max: usize) -> Bencher {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Run the closure repeatedly; uses the closure's return value as a
    /// black-box sink so the optimizer cannot elide the work.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::new();
        let start_all = Instant::now();
        while times.len() < self.min_iters
            || (start_all.elapsed().as_secs_f64() < self.target_secs
                && times.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        BenchStats {
            name: self.name.clone(),
            iters: n,
            mean_s: mean,
            median_s: times[n / 2],
            p95_s: times[(n as f64 * 0.95) as usize % n.max(1)],
            min_s: times[0],
        }
    }
}

/// Prevent the optimizer from removing a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Group header for bench output.
pub fn group(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let stats = Bencher::new("spin")
            .iters(3, 10)
            .run(|| {
                let mut s = 0u64;
                for i in 0..10_000 {
                    s = s.wrapping_add(i);
                }
                s
            });
        assert!(stats.iters >= 3);
        assert!(stats.min_s <= stats.median_s);
        assert!(stats.median_s <= stats.p95_s + 1e-9);
        assert!(stats.mean_s > 0.0);
        assert!(stats.line().contains("spin"));
    }
}
