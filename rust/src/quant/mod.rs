//! Quantizers: uniform b-bit (per-group scales), E8 lattice blocks
//! (QuIP#-style 2-bit operating point), and MXINT shared-exponent blocks
//! (Table 11 ablation), plus the LDLQ/GPTQ error-feedback wrapper used by
//! the CALDERA `Quantize` step.
//!
//! Every quantizer reports the **quantization scale** it chose — the metric
//! Figure 2 tracks across joint-optimization iterations (ODLRI shrinks it by
//! absorbing salient weights into `LR` before quantization).

mod e8;
mod ldlq;
mod mxint;
mod packed;
mod uniform;

pub use e8::E8Lattice;
pub use ldlq::ldlq_quantize;
pub use mxint::MxInt;
pub use packed::{PackedMatrix, PackedScheme, Rotation, MX_ZERO_EXP};
pub use uniform::UniformQuantizer;

pub(crate) use packed::ByteCount;

use crate::tensor::Matrix;

/// Output of a (de)quantization pass.
#[derive(Clone, Debug)]
pub struct QuantOut {
    /// Quantize-dequantized weights (same shape as the input).
    pub deq: Matrix,
    /// The scale statistic for Figure 2: per-matrix mean of the scales the
    /// quantizer actually used (global scale for E8, mean row scale for
    /// uniform, mean 2^e for MXINT).
    pub scale: f32,
    /// The scheme's native packed codes for `deq`, encoded under the same
    /// frozen scales that produced it — `packed.unpack()` reproduces `deq`
    /// **bit-exactly** (property-tested per quantizer). This is what the
    /// fused deployment container stores; no re-quantization ever happens
    /// downstream.
    pub packed: PackedMatrix,
}

/// A weight quantizer. `quantize` is the direct (round-to-nearest) path;
/// `quantize_with_hessian` runs the activation-aware LDLQ error-feedback
/// path that CALDERA's `Quantize(W - LR)` step uses.
pub trait Quantizer: Send + Sync {
    fn name(&self) -> String;

    /// Nominal bits per weight (excluding per-group scale overhead).
    fn bits(&self) -> f64;

    /// Bits per weight including scale/metadata overhead for a matrix of
    /// the given shape (used for the paper's Avg-Bits bookkeeping).
    fn bits_with_overhead(&self, rows: usize, cols: usize) -> f64;

    /// Direct quantize-dequantize, with the scheme's native packed codes
    /// encoded under the same frozen scales that did the rounding.
    fn quantize(&self, w: &Matrix) -> QuantOut {
        let prep = self.prepare(w);
        let deq = prep.round_columns(w, 0);
        QuantOut {
            scale: prep.scale_metric(),
            packed: prep.encode(&deq),
            deq,
        }
    }

    /// Round-to-nearest without the native-code encode: `(deq, scale)`
    /// only. For inner loops (LPLR factor rounding, non-final joint
    /// iterations) whose output is consumed dense and immediately
    /// discarded — encoding there would be pure waste.
    fn quantize_dense(&self, w: &Matrix) -> (Matrix, f32) {
        let prep = self.prepare(w);
        let deq = prep.round_columns(w, 0);
        (deq, prep.scale_metric())
    }

    /// Activation-aware quantization with LDLQ error feedback against the
    /// (regularized) Hessian `h` (shape n×n for W m×n). The default
    /// implementation precomputes scales from `w`, then runs blocked LDLQ
    /// with this quantizer's column-block rounding.
    fn quantize_with_hessian(&self, w: &Matrix, h: &Matrix) -> QuantOut {
        let prep = self.prepare(w);
        let deq = ldlq_quantize(w, h, self.feedback_block(), |cols, c0| {
            prep.round_columns(cols, c0)
        });
        let packed = prep.encode(&deq);
        QuantOut {
            deq,
            scale: prep.scale_metric(),
            packed,
        }
    }

    /// The LDLQ path minus the encode — for joint-optimizer iterations
    /// whose `Q` is superseded by the next outer iteration. Only the final
    /// iteration needs [`Quantizer::quantize_with_hessian`]'s packed codes.
    fn quantize_with_hessian_dense(&self, w: &Matrix, h: &Matrix) -> (Matrix, f32) {
        let prep = self.prepare(w);
        let deq = ldlq_quantize(w, h, self.feedback_block(), |cols, c0| {
            prep.round_columns(cols, c0)
        });
        (deq, prep.scale_metric())
    }

    /// Precompute scales for `w`; the returned object rounds column blocks
    /// under those fixed scales (LDLQ adjusts columns as it goes, so scales
    /// must not chase the adjusted values).
    fn prepare<'a>(&'a self, w: &Matrix) -> Box<dyn Prepared + 'a>;

    /// Column-block width for LDLQ feedback (1 for scalar quantizers, 8 for
    /// the E8 lattice, MXINT's block for MXINT).
    fn feedback_block(&self) -> usize {
        1
    }
}

/// Scale-frozen rounding engine used inside LDLQ.
pub trait Prepared: Send + Sync {
    /// Quantize-dequantize a block of columns. `cols` is (m × b); `c0` is the
    /// absolute column offset in the original matrix (for column-dependent
    /// scale lookup).
    fn round_columns(&self, cols: &Matrix, c0: usize) -> Matrix;

    /// The Figure-2 scale statistic.
    fn scale_metric(&self) -> f32;

    /// Encode an already-rounded full-width output of [`round_columns`]
    /// (`round_columns`-shaped values under *these* frozen scales) into the
    /// scheme's native packed codes. Contract: `encode(q).unpack()` equals
    /// `q` bit-for-bit — decode performs the exact f32 operation sequence
    /// that produced each entry.
    ///
    /// [`round_columns`]: Prepared::round_columns
    fn encode(&self, deq: &Matrix) -> PackedMatrix;
}

/// Build a quantizer from a config string (`"e8"`, `"uniform"`, `"mxint"`).
pub fn make_quantizer(scheme: &str, bits: u32, group: usize) -> anyhow::Result<Box<dyn Quantizer>> {
    match scheme {
        "uniform" => Ok(Box::new(UniformQuantizer::new(bits, group))),
        "e8" => Ok(Box::new(E8Lattice::new(bits))),
        "mxint" => Ok(Box::new(MxInt::new(bits, group.max(1)))),
        other => anyhow::bail!("unknown quantizer scheme '{other}'"),
    }
}

/// Activation-aware quantization error ‖(W − Q)X‖²_F expressed through the
/// Hessian: tr((W−Q) H (W−Q)^T). Shared by tests and metrics.
pub fn hessian_error(w: &Matrix, q: &Matrix, h: &Matrix) -> f64 {
    let e = w.sub(q);
    let eh = e.dot(h);
    // tr(EH E^T) = sum_ij (EH)_ij * E_ij
    eh.as_slice()
        .iter()
        .zip(e.as_slice())
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Pcg64;

    #[test]
    fn make_quantizer_schemes() {
        assert!(make_quantizer("uniform", 4, 64).is_ok());
        assert!(make_quantizer("e8", 2, 8).is_ok());
        assert!(make_quantizer("mxint", 3, 32).is_ok());
        assert!(make_quantizer("nope", 2, 1).is_err());
    }

    #[test]
    fn hessian_error_matches_direct() {
        let mut rng = Pcg64::new(80, 1);
        let w = Matrix::randn(8, 12, 1.0, &mut rng);
        let q = Matrix::randn(8, 12, 1.0, &mut rng);
        let x = Matrix::randn(12, 30, 1.0, &mut rng);
        let h = x.dot_t(&x);
        let direct = {
            let d = w.sub(&q).dot(&x).frob_norm() as f64;
            d * d
        };
        let via_h = hessian_error(&w, &q, &h);
        assert!((direct - via_h).abs() < 1e-2 * direct.max(1.0));
    }

    /// The LDLQ error-feedback path must also emit scheme-native codes
    /// that decode bit-exactly — this is the `Q` the fused container
    /// actually serves.
    #[test]
    fn ldlq_output_encodes_bit_exactly_per_scheme() {
        testing::quick("ldlq-encode-exact", |rng| {
            let m = testing::gen_dim(rng, 2, 16);
            let n = testing::gen_dim(rng, 2, 24);
            let scheme = ["uniform", "e8", "mxint"][rng.below(3)];
            let bits = 2 + rng.below(2) as u32;
            let w = testing::gen_matrix(rng, m, n);
            let h = testing::gen_spd(rng, n);
            let quant = make_quantizer(scheme, bits, 8).unwrap();
            let out = quant.quantize_with_hessian(&w, &h);
            assert_eq!(
                out.packed.unpack().max_abs_diff(&out.deq),
                0.0,
                "{scheme}@{bits}b LDLQ codes not bit-exact"
            );
        });
    }

    /// LDLQ must not be (much) worse than round-to-nearest in
    /// activation-aware error — property over random problems.
    #[test]
    fn ldlq_beats_or_matches_rtn() {
        testing::quick("ldlq<=rtn", |rng| {
            let m = testing::gen_dim(rng, 4, 24);
            let n = testing::gen_dim(rng, 4, 24);
            let w = testing::gen_matrix(rng, m, n);
            let h = testing::gen_spd(rng, n);
            let quant = UniformQuantizer::new(2, usize::MAX);
            let rtn = quant.quantize(&w);
            let ldlq = quant.quantize_with_hessian(&w, &h);
            let e_rtn = hessian_error(&w, &rtn.deq, &h);
            let e_ldlq = hessian_error(&w, &ldlq.deq, &h);
            assert!(
                e_ldlq <= e_rtn * 1.05 + 1e-6,
                "ldlq={e_ldlq:.4e} rtn={e_rtn:.4e}"
            );
        });
    }
}
