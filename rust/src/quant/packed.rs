//! Bit-packed storage for quantized matrices.
//!
//! The compressed-model container stores `Q` as packed b-bit codes plus
//! scales so the artifact on disk actually has the advertised footprint
//! (avg-bits accounting is checked against the serialized size in tests).

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// A b-bit signed-code matrix with per-row-group scales.
/// Codes are stored offset-binary: `code = q + qmax` ∈ [0, 2^bits - 1].
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub group_size: usize,
    /// ceil(rows*cols*bits/8) bytes of packed codes, row-major.
    pub codes: Vec<u8>,
    /// Per-row per-group scales.
    pub scales: Vec<f32>,
}

impl PackedMatrix {
    /// Quantize `w` with symmetric per-group absmax scales and pack.
    pub fn pack(w: &Matrix, bits: u32, group_size: usize) -> PackedMatrix {
        assert!((1..=8).contains(&bits));
        let (rows, cols) = w.shape();
        let gw = group_size.min(cols).max(1);
        let gpr = cols.div_ceil(gw);
        let qmax = ((1i32 << (bits - 1)) - 1).max(1) as f32;
        let mut scales = vec![0f32; rows * gpr];
        let mut codes = vec![0u8; (rows * cols * bits as usize).div_ceil(8)];
        let mut bitpos = 0usize;
        for i in 0..rows {
            let row = w.row(i);
            for g in 0..gpr {
                let lo = g * gw;
                let hi = ((g + 1) * gw).min(cols);
                let absmax = row[lo..hi].iter().fold(0f32, |a, &v| a.max(v.abs()));
                scales[i * gpr + g] = if absmax > 0.0 { absmax / qmax } else { 1e-12 };
            }
            for (j, &v) in row.iter().enumerate() {
                let s = scales[i * gpr + (j / gw).min(gpr - 1)];
                let q = (v / s).round().clamp(-qmax, qmax) as i32;
                let code = (q + qmax as i32) as u32;
                write_bits(&mut codes, bitpos, bits, code);
                bitpos += bits as usize;
            }
        }
        PackedMatrix {
            rows,
            cols,
            bits,
            group_size: gw,
            codes,
            scales,
        }
    }

    /// Dequantize to dense f32.
    pub fn unpack(&self) -> Matrix {
        let qmax = ((1i32 << (self.bits - 1)) - 1).max(1);
        let gpr = self.cols.div_ceil(self.group_size);
        let mut m = Matrix::zeros(self.rows, self.cols);
        let mut bitpos = 0usize;
        for i in 0..self.rows {
            for j in 0..self.cols {
                let code = read_bits(&self.codes, bitpos, self.bits) as i32;
                bitpos += self.bits as usize;
                let q = code - qmax;
                let s = self.scales[i * gpr + (j / self.group_size).min(gpr - 1)];
                *m.at_mut(i, j) = q as f32 * s;
            }
        }
        m
    }

    /// Serialized byte size (codes + scales + header).
    pub fn byte_size(&self) -> usize {
        16 + self.codes.len() + self.scales.len() * 4
    }

    /// Effective bits per weight of the serialized form.
    pub fn bits_per_weight(&self) -> f64 {
        self.byte_size() as f64 * 8.0 / (self.rows * self.cols) as f64
    }

    pub fn write_to(&self, w: &mut impl std::io::Write) -> Result<()> {
        w.write_all(b"ODP1")?;
        for v in [
            self.rows as u32,
            self.cols as u32,
            self.bits,
            self.group_size as u32,
        ] {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&(self.codes.len() as u32).to_le_bytes())?;
        w.write_all(&self.codes)?;
        w.write_all(&(self.scales.len() as u32).to_le_bytes())?;
        for &s in &self.scales {
            w.write_all(&s.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn read_from(r: &mut impl std::io::Read) -> Result<PackedMatrix> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"ODP1" {
            bail!("bad packed-matrix magic");
        }
        let mut u = [0u8; 4];
        let mut next = || -> Result<u32> {
            r.read_exact(&mut u)?;
            Ok(u32::from_le_bytes(u))
        };
        let rows = next()? as usize;
        let cols = next()? as usize;
        let bits = next()?;
        let group_size = next()? as usize;
        let ncodes = next()? as usize;
        let mut codes = vec![0u8; ncodes];
        r.read_exact(&mut codes)?;
        let mut u4 = [0u8; 4];
        r.read_exact(&mut u4)?;
        let nscales = u32::from_le_bytes(u4) as usize;
        let mut scales = vec![0f32; nscales];
        let mut buf = vec![0u8; nscales * 4];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            scales[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(PackedMatrix {
            rows,
            cols,
            bits,
            group_size,
            codes,
            scales,
        })
    }
}

fn write_bits(buf: &mut [u8], bitpos: usize, nbits: u32, value: u32) {
    for b in 0..nbits {
        let bit = (value >> b) & 1;
        let pos = bitpos + b as usize;
        if bit != 0 {
            buf[pos / 8] |= 1 << (pos % 8);
        }
    }
}

fn read_bits(buf: &[u8], bitpos: usize, nbits: u32) -> u32 {
    let mut v = 0u32;
    for b in 0..nbits {
        let pos = bitpos + b as usize;
        if buf[pos / 8] & (1 << (pos % 8)) != 0 {
            v |= 1 << b;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Pcg64;

    #[test]
    fn pack_unpack_matches_uniform_quantizer() {
        testing::quick("pack-roundtrip", |rng| {
            let m = testing::gen_dim(rng, 1, 12);
            let n = testing::gen_dim(rng, 1, 70);
            let bits = 2 + rng.below(3) as u32;
            let w = testing::gen_matrix(rng, m, n);
            let packed = PackedMatrix::pack(&w, bits, 32);
            let deq = packed.unpack();
            // Same rounding as the uniform quantizer with group 32.
            let q = crate::quant::UniformQuantizer::new(bits, 32);
            use crate::quant::Quantizer as _;
            let direct = q.quantize(&w).deq;
            assert!(deq.max_abs_diff(&direct) < 1e-5);
        });
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Pcg64::new(130, 1);
        let w = Matrix::randn(9, 33, 1.0, &mut rng);
        let p = PackedMatrix::pack(&w, 2, 16);
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let q = PackedMatrix::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(p, q);
        assert!(p.unpack().max_abs_diff(&q.unpack()) == 0.0);
    }

    #[test]
    fn footprint_matches_advertised_bits() {
        let mut rng = Pcg64::new(131, 1);
        let w = Matrix::randn(128, 256, 1.0, &mut rng);
        let p = PackedMatrix::pack(&w, 2, 64);
        // 2 bits + 32-bit scale per 64 weights = 2.5 bits + header dust.
        let bpw = p.bits_per_weight();
        assert!(bpw < 2.6, "bits/weight = {bpw}");
        assert!(bpw >= 2.5);
    }

    #[test]
    fn bit_io_roundtrip() {
        let mut buf = vec![0u8; 16];
        let vals = [5u32, 0, 7, 3, 1, 6, 2, 4];
        for (i, &v) in vals.iter().enumerate() {
            write_bits(&mut buf, i * 3, 3, v);
        }
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(read_bits(&buf, i * 3, 3), v);
        }
    }
}
