//! Bit-packed storage for quantized matrices.
//!
//! The compressed-model container stores `Q` as packed b-bit codes plus
//! scales so the artifact on disk actually has the advertised footprint
//! (avg-bits accounting is checked against the serialized size in tests).

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// A b-bit signed-code matrix with per-row-group scales.
/// Codes are stored offset-binary: `code = q + qmax` ∈ [0, 2^bits - 1].
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub group_size: usize,
    /// ceil(rows*cols*bits/8) bytes of packed codes, row-major.
    pub codes: Vec<u8>,
    /// Per-row per-group scales.
    pub scales: Vec<f32>,
}

impl PackedMatrix {
    /// Quantize `w` with symmetric per-group absmax scales and pack.
    pub fn pack(w: &Matrix, bits: u32, group_size: usize) -> PackedMatrix {
        assert!((1..=8).contains(&bits));
        let (rows, cols) = w.shape();
        let gw = group_size.min(cols).max(1);
        let gpr = cols.div_ceil(gw);
        let qmax = ((1i32 << (bits - 1)) - 1).max(1) as f32;
        let mut scales = vec![0f32; rows * gpr];
        let mut codes = vec![0u8; (rows * cols * bits as usize).div_ceil(8)];
        let mut bitpos = 0usize;
        for i in 0..rows {
            let row = w.row(i);
            for g in 0..gpr {
                let lo = g * gw;
                let hi = ((g + 1) * gw).min(cols);
                let absmax = row[lo..hi].iter().fold(0f32, |a, &v| a.max(v.abs()));
                scales[i * gpr + g] = if absmax > 0.0 { absmax / qmax } else { 1e-12 };
            }
            for (j, &v) in row.iter().enumerate() {
                let s = scales[i * gpr + (j / gw).min(gpr - 1)];
                let q = (v / s).round().clamp(-qmax, qmax) as i32;
                let code = (q + qmax as i32) as u32;
                write_bits(&mut codes, bitpos, bits, code);
                bitpos += bits as usize;
            }
        }
        PackedMatrix {
            rows,
            cols,
            bits,
            group_size: gw,
            codes,
            scales,
        }
    }

    /// Dequantize to dense f32.
    pub fn unpack(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            self.dequant_row_into(i, m.row_mut(i));
        }
        m
    }

    /// Dequantize row `i` into `out` (length = `cols`) without touching any
    /// other row — the fused `(Q+LR)·x` kernels stream rows/panels through
    /// this so the dense matrix is never materialized. Uses a sequential
    /// bit-stream reader (one shift/mask per code instead of a per-bit
    /// loop).
    pub fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        assert!(i < self.rows, "row {i} out of range");
        assert_eq!(out.len(), self.cols, "dequant_row_into length");
        let qmax = ((1i32 << (self.bits - 1)) - 1).max(1);
        let gpr = self.cols.div_ceil(self.group_size);
        let mut reader = BitReader::at(&self.codes, i * self.cols * self.bits as usize);
        for (j, slot) in out.iter_mut().enumerate() {
            let code = reader.take(self.bits) as i32;
            let s = self.scales[i * gpr + (j / self.group_size).min(gpr - 1)];
            *slot = (code - qmax) as f32 * s;
        }
    }

    /// Serialized byte size (codes + scales + header).
    pub fn byte_size(&self) -> usize {
        16 + self.codes.len() + self.scales.len() * 4
    }

    /// Effective bits per weight of the serialized form.
    pub fn bits_per_weight(&self) -> f64 {
        self.byte_size() as f64 * 8.0 / (self.rows * self.cols) as f64
    }

    pub fn write_to(&self, w: &mut impl std::io::Write) -> Result<()> {
        w.write_all(b"ODP1")?;
        for v in [
            self.rows as u32,
            self.cols as u32,
            self.bits,
            self.group_size as u32,
        ] {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&(self.codes.len() as u32).to_le_bytes())?;
        w.write_all(&self.codes)?;
        w.write_all(&(self.scales.len() as u32).to_le_bytes())?;
        for &s in &self.scales {
            w.write_all(&s.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn read_from(r: &mut impl std::io::Read) -> Result<PackedMatrix> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"ODP1" {
            bail!("bad packed-matrix magic");
        }
        let mut u = [0u8; 4];
        let mut next = || -> Result<u32> {
            r.read_exact(&mut u)?;
            Ok(u32::from_le_bytes(u))
        };
        let rows = next()? as usize;
        let cols = next()? as usize;
        let bits = next()?;
        let group_size = next()? as usize;
        let ncodes = next()? as usize;
        let mut codes = vec![0u8; ncodes];
        r.read_exact(&mut codes)?;
        let mut u4 = [0u8; 4];
        r.read_exact(&mut u4)?;
        let nscales = u32::from_le_bytes(u4) as usize;
        let mut scales = vec![0f32; nscales];
        let mut buf = vec![0u8; nscales * 4];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            scales[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(PackedMatrix {
            rows,
            cols,
            bits,
            group_size,
            codes,
            scales,
        })
    }
}

/// Sequential LSB-first bit-stream reader over the packed code buffer.
struct BitReader<'a> {
    buf: &'a [u8],
    /// Next byte to refill from.
    byte: usize,
    /// Bit accumulator (LSB-aligned) and its fill level.
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    /// Position the reader at an absolute bit offset.
    fn at(buf: &'a [u8], bitpos: usize) -> BitReader<'a> {
        let byte = bitpos / 8;
        let skip = (bitpos % 8) as u32;
        let mut r = BitReader {
            buf,
            byte,
            acc: 0,
            nbits: 0,
        };
        if skip > 0 {
            r.refill(skip);
            r.acc >>= skip;
            r.nbits -= skip;
        }
        r
    }

    #[inline]
    fn refill(&mut self, want: u32) {
        while self.nbits < want {
            let b = if self.byte < self.buf.len() {
                self.buf[self.byte]
            } else {
                0
            };
            self.byte += 1;
            self.acc |= (b as u64) << self.nbits;
            self.nbits += 8;
        }
    }

    #[inline]
    fn take(&mut self, n: u32) -> u32 {
        self.refill(n);
        let v = (self.acc & ((1u64 << n) - 1)) as u32;
        self.acc >>= n;
        self.nbits -= n;
        v
    }
}

fn write_bits(buf: &mut [u8], bitpos: usize, nbits: u32, value: u32) {
    for b in 0..nbits {
        let bit = (value >> b) & 1;
        let pos = bitpos + b as usize;
        if bit != 0 {
            buf[pos / 8] |= 1 << (pos % 8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Pcg64;

    /// Bit-at-a-time reference reader (the original implementation) used to
    /// cross-check the streaming [`BitReader`].
    fn read_bits(buf: &[u8], bitpos: usize, nbits: u32) -> u32 {
        let mut v = 0u32;
        for b in 0..nbits {
            let pos = bitpos + b as usize;
            if buf[pos / 8] & (1 << (pos % 8)) != 0 {
                v |= 1 << b;
            }
        }
        v
    }

    #[test]
    fn pack_unpack_matches_uniform_quantizer() {
        testing::quick("pack-roundtrip", |rng| {
            let m = testing::gen_dim(rng, 1, 12);
            let n = testing::gen_dim(rng, 1, 70);
            let bits = 2 + rng.below(3) as u32;
            let w = testing::gen_matrix(rng, m, n);
            let packed = PackedMatrix::pack(&w, bits, 32);
            let deq = packed.unpack();
            // Same rounding as the uniform quantizer with group 32.
            let q = crate::quant::UniformQuantizer::new(bits, 32);
            use crate::quant::Quantizer as _;
            let direct = q.quantize(&w).deq;
            assert!(deq.max_abs_diff(&direct) < 1e-5);
        });
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Pcg64::new(130, 1);
        let w = Matrix::randn(9, 33, 1.0, &mut rng);
        let p = PackedMatrix::pack(&w, 2, 16);
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let q = PackedMatrix::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(p, q);
        assert!(p.unpack().max_abs_diff(&q.unpack()) == 0.0);
    }

    #[test]
    fn footprint_matches_advertised_bits() {
        let mut rng = Pcg64::new(131, 1);
        let w = Matrix::randn(128, 256, 1.0, &mut rng);
        let p = PackedMatrix::pack(&w, 2, 64);
        // 2 bits + 32-bit scale per 64 weights = 2.5 bits + header dust.
        let bpw = p.bits_per_weight();
        assert!(bpw < 2.6, "bits/weight = {bpw}");
        assert!(bpw >= 2.5);
    }

    #[test]
    fn bit_io_roundtrip() {
        let mut buf = vec![0u8; 16];
        let vals = [5u32, 0, 7, 3, 1, 6, 2, 4];
        for (i, &v) in vals.iter().enumerate() {
            write_bits(&mut buf, i * 3, 3, v);
        }
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(read_bits(&buf, i * 3, 3), v);
        }
    }

    #[test]
    fn bit_reader_matches_reference_at_any_offset() {
        let mut rng = Pcg64::new(200, 1);
        let buf: Vec<u8> = (0..64).map(|_| rng.below(256) as u8).collect();
        for bits in [2u32, 3, 4, 5, 7, 8] {
            for start in 0..16 {
                let mut reader = BitReader::at(&buf, start);
                let mut pos = start;
                for _ in 0..40 {
                    assert_eq!(
                        reader.take(bits),
                        read_bits(&buf, pos, bits),
                        "bits={bits} start={start} pos={pos}"
                    );
                    pos += bits as usize;
                }
            }
        }
    }

    #[test]
    fn pack_unpack_roundtrip_all_bit_widths_with_tails() {
        // 2/3/4/8 bits × shapes whose widths are NOT multiples of the group
        // size (tail groups) and whose code streams are NOT byte-aligned.
        testing::quick("pack-roundtrip-widths", |rng| {
            let m = testing::gen_dim(rng, 1, 9);
            let n = testing::gen_dim(rng, 1, 77);
            let bits = [2u32, 3, 4, 8][rng.below(4)];
            let group = [3usize, 5, 16, 32][rng.below(4)];
            let w = testing::gen_matrix(rng, m, n);
            let p = PackedMatrix::pack(&w, bits, group);
            let deq = p.unpack();
            // Packing the dequantized output again is a fixed point.
            let p2 = PackedMatrix::pack(&deq, bits, group);
            let tol = 1e-5 * w.abs_max().max(1.0);
            assert!(
                p2.unpack().max_abs_diff(&deq) <= tol,
                "pack not idempotent at {bits} bits group {group}"
            );
            // And the serialized form round-trips bit-exactly.
            let mut buf = Vec::new();
            p.write_to(&mut buf).unwrap();
            let back = PackedMatrix::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(p, back);
            assert!(back.unpack().max_abs_diff(&deq) == 0.0);
        });
    }

    #[test]
    fn dequant_row_matches_unpack() {
        testing::quick("dequant-row", |rng| {
            let m = testing::gen_dim(rng, 1, 12);
            let n = testing::gen_dim(rng, 1, 50);
            let bits = [2u32, 3, 4, 8][rng.below(4)];
            let w = testing::gen_matrix(rng, m, n);
            let p = PackedMatrix::pack(&w, bits, 7);
            let dense = p.unpack();
            let mut row = vec![0f32; n];
            for i in 0..m {
                p.dequant_row_into(i, &mut row);
                assert_eq!(&row[..], dense.row(i), "row {i}");
            }
        });
    }

    /// Golden-bytes check: the on-disk format must not silently drift.
    /// Hand-assembled: W = [3, -1, 2, 0] at 3 bits, group 4 ⇒ scale
    /// = absmax/qmax = 3/3 = 1.0, codes (q+3) = [6, 2, 5, 3], packed
    /// LSB-first into 0x56, 0x07.
    #[test]
    fn serialized_golden_bytes() {
        let w = Matrix::from_vec(1, 4, vec![3.0, -1.0, 2.0, 0.0]);
        let p = PackedMatrix::pack(&w, 3, 4);
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let expect: Vec<u8> = [
            &b"ODP1"[..],              // magic
            &1u32.to_le_bytes()[..],   // rows
            &4u32.to_le_bytes()[..],   // cols
            &3u32.to_le_bytes()[..],   // bits
            &4u32.to_le_bytes()[..],   // group_size
            &2u32.to_le_bytes()[..],   // ncodes
            &[0x56u8, 0x07][..],       // codes
            &1u32.to_le_bytes()[..],   // nscales
            &1.0f32.to_le_bytes()[..], // scale
        ]
        .concat();
        assert_eq!(buf, expect, "packed on-disk format drifted");
        // And it decodes back to the exact input (all values on-grid).
        assert_eq!(p.unpack(), w);
    }
}
