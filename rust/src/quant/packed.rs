//! Scheme-exact bit-packed storage for quantized matrices (ODP v2).
//!
//! The deployment container stores each quantizer's **native** codes so the
//! fused serving path decodes exactly the `Q` the pipeline optimized — no
//! Hessian-free re-quantization onto a foreign grid at packing time. A
//! [`PackedMatrix`] is one of three code layouts plus an optional Hadamard
//! incoherence rotation:
//!
//! * [`PackedScheme::Uniform`] — offset-binary b-bit codes
//!   (`code = q + qmax`) with per-row per-group f32 absmax scales. Decode:
//!   `(code − qmax) · scale`.
//! * [`PackedScheme::E8`] — E8-lattice coordinates in **half units**, one
//!   global f32 scale. Each coordinate is stored as
//!   `code = 2·q + 2·lim ∈ [0, 4·lim]` at `bits + 2` bits/coordinate
//!   (`lim = 2^{bits−1}` is the coordinate clamp of the `bits`-bit
//!   operating point). This is wider than the nominal budget — exactness
//!   is the contract; `bits_per_weight()` reports the honest footprint.
//!   Decode: `((code − 2·lim)/2) · scale`.
//! * [`PackedScheme::MxInt`] — offset-binary b-bit mantissas with one
//!   shared power-of-two exponent per block, stored as an `i16`
//!   (`step = 2^e`, [`MX_ZERO_EXP`] marks an all-zero block). Decode:
//!   `(code − mmax) · 2^e`.
//!
//! [`Rotation`] records the QuIP#-style randomized-Hadamard sign diagonals
//! when the codes live in the incoherent basis (LDLQ + `hadamard` runs):
//! `Q = D_m H_m Q̃ H_n D_n` with `Q̃` the stored grid. [`PackedMatrix::unpack`]
//! applies the inverse transform with the exact same op sequence as
//! [`crate::hadamard::Incoherence::unapply`], so the decode reproduces the
//! pipeline's `Q` bit-for-bit; the fused kernels instead rotate the
//! *activations* (`Q·x = D_m H_m (Q̃ · (H_n D_n x))`) and never densify.
//!
//! ## On-disk format (`ODP2`)
//!
//! ```text
//! magic   b"ODP2"
//! u32     scheme tag        (0 = uniform, 1 = e8, 2 = mxint)
//! u32     rotated flag      (0 / 1)
//! u32     rows, u32 cols
//! scheme payload:
//!   uniform: u32 bits, u32 group_size, u32 ncodes, codes,
//!            u32 nscales, f32 scales
//!   e8:      u32 bits, f32 scale, u32 ncodes, codes
//!   mxint:   u32 bits, u32 block, u32 ncodes, codes, u32 nexps, i16 exps
//! rotation payload (iff rotated):
//!   ceil(rows/8) left sign bits, ceil(cols/8) right sign bits (1 = +1)
//! ```
//!
//! All counts are validated against `rows`/`cols`/`bits`/`group` **before**
//! any allocation, and payloads are read through bounded `take` readers, so
//! a truncated or corrupt stream yields `Err` instead of unbounded
//! allocations or out-of-bounds scale indexing. Legacy `ODP1` (uniform-only
//! v1) streams are still readable; writes always emit v2.
//!
//! ## Decode-kernel contract (reference vs specialized)
//!
//! Two decoders coexist, with a tested bit-identity contract between them:
//!
//! * **Reference** — [`PackedMatrix::dequant_row_into`]: a sequential
//!   `BitReader` pulling one code at a time, written to read as the spec
//!   (per-group extents, one scale fetch per group). This is the decoder
//!   every specialized kernel is property-tested against.
//! * **Specialized** — `unpack_codes` dispatches *once per call* on the
//!   stored code width (2/3/4/5/6/8 bits; uniform & MXINT mantissas use
//!   `bits`, E8 coordinates `bits + 2`) to a SWAR kernel that reads `u64`
//!   words from the byte stream and emits a whole chunk of integer codes
//!   per load via shifts/masks. Widths outside the specialized set (1, 7)
//!   fall back to a scalar two-byte-window read. Word reads are bounds
//!   guarded: the bulk loop only runs while a full 8-byte window exists,
//!   with scalar head/tail codes around it, so no read ever leaves the
//!   code buffer. [`PackedMatrix::dequant_row_fast_into`] (codes → f32 row)
//!   is **bit-identical** to the reference: it applies the exact same
//!   per-element expression, only the code extraction differs.
//!   [`PackedMatrix::dot_row_codes`] fuses dequant into the dot instead —
//!   `Σ_g s_g · Σ_{j∈g} (code_j − off)·x_j` — hoisting the scale out of the
//!   group, so its f32 sum agrees with a materialized-row dot only to
//!   rounding (summation order differs), which is the documented contract
//!   of the fused serving kernels built on it.

use crate::hadamard::{fwht_cols, fwht_normalized, fwht_rows, pow2_segments};
use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Largest accepted dimension on deserialization — a corrupt header must
/// not translate into a multi-terabyte allocation attempt.
const MAX_DIM: usize = 1 << 26;

/// Shared-exponent sentinel for an all-zero MXINT block (step = 0).
pub const MX_ZERO_EXP: i16 = i16::MIN;

/// The native code layout of one quantizer family.
#[derive(Clone, Debug, PartialEq)]
pub enum PackedScheme {
    /// Offset-binary b-bit codes + per-row per-group absmax scales.
    Uniform {
        bits: u32,
        group_size: usize,
        codes: Vec<u8>,
        scales: Vec<f32>,
    },
    /// E8 lattice coordinates in half units at `bits + 2` bits/coordinate
    /// plus the single global scale.
    E8 { bits: u32, scale: f32, codes: Vec<u8> },
    /// b-bit mantissas + one shared power-of-two exponent per block.
    MxInt {
        bits: u32,
        block: usize,
        codes: Vec<u8>,
        exps: Vec<i16>,
    },
}

impl PackedScheme {
    fn tag(&self) -> u32 {
        match self {
            PackedScheme::Uniform { .. } => 0,
            PackedScheme::E8 { .. } => 1,
            PackedScheme::MxInt { .. } => 2,
        }
    }

    /// Stored code width in bits per weight (E8 pays 2 extra bits per
    /// coordinate for exactness).
    pub fn code_bits(&self) -> u32 {
        match self {
            PackedScheme::Uniform { bits, .. } => *bits,
            PackedScheme::E8 { bits, .. } => bits + 2,
            PackedScheme::MxInt { bits, .. } => *bits,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PackedScheme::Uniform { .. } => "uniform",
            PackedScheme::E8 { .. } => "e8",
            PackedScheme::MxInt { .. } => "mxint",
        }
    }
}

/// Randomized-Hadamard incoherence metadata: the codes are stored in the
/// rotated basis and `Q = D_m H_m Q̃ H_n D_n` is recovered (or folded into
/// the activations) at decode time.
#[derive(Clone, Debug, PartialEq)]
pub struct Rotation {
    /// `D_m` diagonal, ±1 per output row.
    pub left_signs: Vec<f32>,
    /// `D_n` diagonal, ±1 per input column.
    pub right_signs: Vec<f32>,
}

impl Rotation {
    /// Exact inverse rotation of the full stored matrix — the identical op
    /// sequence as [`crate::hadamard::Incoherence::unapply`] (borrowing the sign diagonals
    /// instead of cloning them), so decodes are bit-exact against the
    /// pipeline's un-rotation.
    pub fn unapply(&self, qt: &Matrix) -> Matrix {
        let mut t = qt.clone();
        fwht_cols(&mut t);
        fwht_rows(&mut t);
        t = t.mul_diag_left(&self.left_signs);
        t.mul_diag_right(&self.right_signs)
    }

    /// `x̃ = H_n D_n x` for `(Q + LR)·x` kernels (x is `cols × b`) —
    /// [`crate::hadamard::Incoherence::apply_acts`] on borrowed signs.
    pub fn rotate_acts(&self, x: &Matrix) -> Matrix {
        let mut t = x.mul_diag_left(&self.right_signs);
        fwht_cols(&mut t);
        t
    }

    /// `y = D_m H_m ỹ` — finish a matmul done in the stored basis
    /// ([`crate::hadamard::Incoherence::unapply_left`]).
    pub fn unrotate_out(&self, y: &Matrix) -> Matrix {
        let mut t = y.clone();
        fwht_cols(&mut t);
        t.mul_diag_left(&self.left_signs)
    }

    /// `x̃ = x D_n H_n` for the activation-layout `X·(Q+LR)ᵀ` kernels
    /// (x is `tokens × cols`; [`crate::hadamard::Incoherence::apply_right`]).
    pub fn rotate_acts_t(&self, x: &Matrix) -> Matrix {
        let mut t = x.mul_diag_right(&self.right_signs);
        fwht_rows(&mut t);
        t
    }

    /// `y = ỹ H_m D_m` — finish a transposed matmul done in the stored
    /// basis (ỹ is `tokens × rows`).
    pub fn unrotate_out_t(&self, y: &Matrix) -> Matrix {
        let mut t = y.clone();
        fwht_rows(&mut t);
        t.mul_diag_right(&self.left_signs)
    }

    /// Slice form of [`Rotation::rotate_acts_t`] for the single-vector
    /// decode kernel: `x̃ = x D_n H_n` without a `Matrix` round-trip. The
    /// op sequence matches the 1-row matrix version exactly, so both paths
    /// produce the identical f32 stream.
    pub fn rotate_vec(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.right_signs.len());
        let mut t: Vec<f32> = x.iter().zip(&self.right_signs).map(|(&v, &s)| v * s).collect();
        for &(s, len) in &pow2_segments(t.len()) {
            fwht_normalized(&mut t[s..s + len]);
        }
        t
    }

    /// Slice form of [`Rotation::unrotate_out_t`]: `y ← (ỹ H_m) D_m` in
    /// place.
    pub fn unrotate_vec(&self, y: &mut [f32]) {
        debug_assert_eq!(y.len(), self.left_signs.len());
        for &(s, len) in &pow2_segments(y.len()) {
            fwht_normalized(&mut y[s..s + len]);
        }
        for (v, &s) in y.iter_mut().zip(&self.left_signs) {
            *v *= s;
        }
    }
}

/// A quantized matrix in its scheme's native packed form, optionally in a
/// rotated (incoherent) basis.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub scheme: PackedScheme,
    pub rotation: Option<Rotation>,
}

impl PackedMatrix {
    /// Quantize `w` with symmetric per-group absmax scales and pack as the
    /// uniform scheme. Exact for weights already on that grid (raw
    /// round-to-nearest uniform output); pipeline `Q` from other schemes
    /// must come through the quantizer's own `Prepared::encode` instead.
    pub fn pack(w: &Matrix, bits: u32, group_size: usize) -> PackedMatrix {
        assert!((1..=8).contains(&bits));
        let (rows, cols) = w.shape();
        let gw = group_size.min(cols).max(1);
        let gpr = cols.div_ceil(gw);
        let qmax = ((1i32 << (bits - 1)) - 1).max(1) as f32;
        let mut scales = vec![0f32; rows * gpr];
        let mut codes = vec![0u8; (rows * cols * bits as usize).div_ceil(8)];
        let mut bitpos = 0usize;
        for i in 0..rows {
            let row = w.row(i);
            for g in 0..gpr {
                let lo = g * gw;
                let hi = ((g + 1) * gw).min(cols);
                let absmax = row[lo..hi].iter().fold(0f32, |a, &v| a.max(v.abs()));
                scales[i * gpr + g] = if absmax > 0.0 { absmax / qmax } else { 1e-12 };
            }
            for (j, &v) in row.iter().enumerate() {
                let s = scales[i * gpr + (j / gw).min(gpr - 1)];
                let q = (v / s).round().clamp(-qmax, qmax) as i32;
                let code = (q + qmax as i32) as u32;
                write_bits(&mut codes, bitpos, bits, code);
                bitpos += bits as usize;
            }
        }
        PackedMatrix {
            rows,
            cols,
            scheme: PackedScheme::Uniform {
                bits,
                group_size: gw,
                codes,
                scales,
            },
            rotation: None,
        }
    }

    /// Attach incoherence-rotation metadata: the stored codes become the
    /// rotated-basis `Q̃` and decodes recover `D_m H_m Q̃ H_n D_n`.
    pub fn with_rotation(mut self, left_signs: Vec<f32>, right_signs: Vec<f32>) -> PackedMatrix {
        assert!(self.rotation.is_none(), "packed matrix already rotated");
        assert_eq!(left_signs.len(), self.rows, "left sign diagonal length");
        assert_eq!(right_signs.len(), self.cols, "right sign diagonal length");
        assert!(
            left_signs.iter().chain(&right_signs).all(|&s| s == 1.0 || s == -1.0),
            "rotation signs must be ±1"
        );
        self.rotation = Some(Rotation {
            left_signs,
            right_signs,
        });
        self
    }

    /// Nominal quantizer bits (the operating point, not the stored width).
    pub fn bits(&self) -> u32 {
        match &self.scheme {
            PackedScheme::Uniform { bits, .. }
            | PackedScheme::E8 { bits, .. }
            | PackedScheme::MxInt { bits, .. } => *bits,
        }
    }

    /// Human-readable scheme label (`"e8+rot"` when rotated).
    pub fn scheme_name(&self) -> String {
        match &self.rotation {
            Some(_) => format!("{}+rot", self.scheme.name()),
            None => self.scheme.name().to_string(),
        }
    }

    /// Dequantize to dense f32 — **bit-exact** against the quantizer output
    /// the codes were encoded from (including the inverse rotation).
    pub fn unpack(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            self.dequant_row_into(i, m.row_mut(i));
        }
        match &self.rotation {
            Some(rot) => rot.unapply(&m),
            None => m,
        }
    }

    /// Dequantize row `i` of the **stored basis** into `out` (length =
    /// `cols`) without touching any other row. For a rotated matrix this is
    /// a row of `Q̃`; the kernels fold the rotation into the activations
    /// instead (see [`Rotation`]). This is the **reference** decoder — a
    /// sequential bit-stream reader walking the row group by group (one
    /// scale/exponent fetch per group, no per-element index arithmetic) —
    /// kept readable as the spec the specialized word-level kernels are
    /// property-tested against; the serving kernels use
    /// [`PackedMatrix::dequant_row_fast_into`] / [`PackedMatrix::dot_row_codes`].
    pub fn dequant_row_into(&self, i: usize, out: &mut [f32]) {
        assert!(i < self.rows, "row {i} out of range");
        assert_eq!(out.len(), self.cols, "dequant_row_into length");
        match &self.scheme {
            PackedScheme::Uniform {
                bits,
                group_size,
                codes,
                scales,
            } => {
                let qmax = ((1i32 << (bits - 1)) - 1).max(1);
                let gpr = self.cols.div_ceil(*group_size);
                let mut reader = BitReader::at(codes, i * self.cols * *bits as usize);
                for (g, chunk) in out.chunks_mut(*group_size).enumerate() {
                    let s = scales[i * gpr + g];
                    for slot in chunk {
                        *slot = (reader.take(*bits) as i32 - qmax) as f32 * s;
                    }
                }
            }
            PackedScheme::E8 { bits, scale, codes } => {
                let cb = bits + 2;
                let two_lim = 2 * super::e8::e8_coord_limit(*bits) as i32;
                let mut reader = BitReader::at(codes, i * self.cols * cb as usize);
                for slot in out.iter_mut() {
                    let code = reader.take(cb) as i32;
                    *slot = (code - two_lim) as f32 / 2.0 * scale;
                }
            }
            PackedScheme::MxInt {
                bits,
                block,
                codes,
                exps,
            } => {
                let mmax = ((1i32 << (bits - 1)) - 1).max(1);
                let bpr = self.cols.div_ceil(*block);
                let mut reader = BitReader::at(codes, i * self.cols * *bits as usize);
                for (b, chunk) in out.chunks_mut(*block).enumerate() {
                    let e = exps[i * bpr + b];
                    if e == MX_ZERO_EXP {
                        // All-zero block: the codes still occupy stream bits.
                        for slot in chunk {
                            reader.take(*bits);
                            *slot = 0.0;
                        }
                        continue;
                    }
                    let step = exp_pow2(e);
                    for slot in chunk {
                        *slot = (reader.take(*bits) as i32 - mmax) as f32 * step;
                    }
                }
            }
        }
    }

    /// Extract row `i`'s raw integer codes through the width-specialized
    /// word-level unpackers ([`unpack_codes`]) into `codes` (resized to
    /// `cols`). The scratch vector lets serving kernels decode thousands of
    /// rows with zero per-row allocation.
    pub fn load_row_codes(&self, i: usize, codes: &mut Vec<i32>) {
        assert!(i < self.rows, "row {i} out of range");
        codes.resize(self.cols, 0);
        let cb = self.scheme.code_bits();
        let buf = match &self.scheme {
            PackedScheme::Uniform { codes, .. }
            | PackedScheme::E8 { codes, .. }
            | PackedScheme::MxInt { codes, .. } => codes,
        };
        unpack_codes(buf, i * self.cols * cb as usize, cb, codes);
    }

    /// Turn row `i`'s extracted codes into the dequantized f32 row —
    /// **bit-identical** to [`PackedMatrix::dequant_row_into`] (the exact
    /// same per-element expression; only the code extraction path differs).
    pub fn dequant_row_from_codes(&self, i: usize, codes: &[i32], out: &mut [f32]) {
        debug_assert_eq!(codes.len(), self.cols);
        assert_eq!(out.len(), self.cols, "dequant_row_from_codes length");
        match &self.scheme {
            PackedScheme::Uniform {
                bits,
                group_size,
                scales,
                ..
            } => {
                let qmax = ((1i32 << (bits - 1)) - 1).max(1);
                let gpr = self.cols.div_ceil(*group_size);
                let groups = out.chunks_mut(*group_size).zip(codes.chunks(*group_size));
                for (g, (ochunk, cchunk)) in groups.enumerate() {
                    let s = scales[i * gpr + g];
                    for (slot, &c) in ochunk.iter_mut().zip(cchunk) {
                        *slot = (c - qmax) as f32 * s;
                    }
                }
            }
            PackedScheme::E8 { bits, scale, .. } => {
                let two_lim = 2 * super::e8::e8_coord_limit(*bits) as i32;
                for (slot, &c) in out.iter_mut().zip(codes) {
                    *slot = (c - two_lim) as f32 / 2.0 * scale;
                }
            }
            PackedScheme::MxInt {
                bits, block, exps, ..
            } => {
                let mmax = ((1i32 << (bits - 1)) - 1).max(1);
                let bpr = self.cols.div_ceil(*block);
                let blocks = out.chunks_mut(*block).zip(codes.chunks(*block));
                for (b, (ochunk, cchunk)) in blocks.enumerate() {
                    let e = exps[i * bpr + b];
                    if e == MX_ZERO_EXP {
                        ochunk.fill(0.0);
                        continue;
                    }
                    let step = exp_pow2(e);
                    for (slot, &c) in ochunk.iter_mut().zip(cchunk) {
                        *slot = (c - mmax) as f32 * step;
                    }
                }
            }
        }
    }

    /// Specialized row decode: word-level code extraction + per-group
    /// scaling, bit-identical to [`PackedMatrix::dequant_row_into`].
    /// `codes` is caller-owned scratch (reused across rows).
    pub fn dequant_row_fast_into(&self, i: usize, codes: &mut Vec<i32>, out: &mut [f32]) {
        self.load_row_codes(i, codes);
        self.dequant_row_from_codes(i, codes, out);
    }

    /// Fused dequant-dot of row `i` with `x`, group-hoisted:
    /// `Σ_g s_g · Σ_{j∈g} (code_j − off)·x_j`. The decoded row is never
    /// materialized and the scale (or shared block step) is applied once
    /// per group, not per element. Summation order differs from dotting a
    /// materialized row, so the result agrees with the reference to f32
    /// rounding, not bitwise — the fused serving kernels' documented
    /// contract.
    pub fn dot_row_codes(&self, i: usize, codes: &[i32], x: &[f32]) -> f32 {
        debug_assert_eq!(codes.len(), self.cols);
        assert_eq!(x.len(), self.cols, "dot_row_codes length");
        match &self.scheme {
            PackedScheme::Uniform {
                bits,
                group_size,
                scales,
                ..
            } => {
                let qmax = ((1i32 << (bits - 1)) - 1).max(1);
                let gpr = self.cols.div_ceil(*group_size);
                let mut acc = 0f32;
                let groups = codes.chunks(*group_size).zip(x.chunks(*group_size));
                for (g, (cchunk, xchunk)) in groups.enumerate() {
                    let mut gsum = 0f32;
                    for (&c, &xv) in cchunk.iter().zip(xchunk) {
                        gsum += (c - qmax) as f32 * xv;
                    }
                    acc += scales[i * gpr + g] * gsum;
                }
                acc
            }
            PackedScheme::E8 { bits, scale, .. } => {
                let two_lim = 2 * super::e8::e8_coord_limit(*bits) as i32;
                let mut acc = 0f32;
                for (&c, &xv) in codes.iter().zip(x) {
                    acc += (c - two_lim) as f32 * xv;
                }
                acc * (0.5 * scale)
            }
            PackedScheme::MxInt {
                bits, block, exps, ..
            } => {
                let mmax = ((1i32 << (bits - 1)) - 1).max(1);
                let bpr = self.cols.div_ceil(*block);
                let mut acc = 0f32;
                let blocks = codes.chunks(*block).zip(x.chunks(*block));
                for (b, (cchunk, xchunk)) in blocks.enumerate() {
                    let e = exps[i * bpr + b];
                    if e == MX_ZERO_EXP {
                        continue;
                    }
                    let mut bsum = 0f32;
                    for (&c, &xv) in cchunk.iter().zip(xchunk) {
                        bsum += (c - mmax) as f32 * xv;
                    }
                    acc += exp_pow2(e) * bsum;
                }
                acc
            }
        }
    }

    /// Serialized byte size — derived from the actual serialized length so
    /// footprint reporting can never drift from the on-disk format.
    pub fn byte_size(&self) -> usize {
        let mut count = ByteCount(0);
        // lint:allow(hot-path-panic) ByteCount's Write impl never errors; write_to has no other failure source
        self.write_to(&mut count).expect("counting writer is infallible");
        count.0
    }

    /// Effective bits per weight of the serialized form.
    pub fn bits_per_weight(&self) -> f64 {
        self.byte_size() as f64 * 8.0 / (self.rows * self.cols) as f64
    }

    pub fn write_to(&self, w: &mut impl std::io::Write) -> Result<()> {
        w.write_all(b"ODP2")?;
        for v in [
            self.scheme.tag(),
            self.rotation.is_some() as u32,
            self.rows as u32,
            self.cols as u32,
        ] {
            w.write_all(&v.to_le_bytes())?;
        }
        match &self.scheme {
            PackedScheme::Uniform {
                bits,
                group_size,
                codes,
                scales,
            } => {
                w.write_all(&bits.to_le_bytes())?;
                w.write_all(&(*group_size as u32).to_le_bytes())?;
                w.write_all(&(codes.len() as u32).to_le_bytes())?;
                w.write_all(codes)?;
                w.write_all(&(scales.len() as u32).to_le_bytes())?;
                for &s in scales {
                    w.write_all(&s.to_le_bytes())?;
                }
            }
            PackedScheme::E8 { bits, scale, codes } => {
                w.write_all(&bits.to_le_bytes())?;
                w.write_all(&scale.to_le_bytes())?;
                w.write_all(&(codes.len() as u32).to_le_bytes())?;
                w.write_all(codes)?;
            }
            PackedScheme::MxInt {
                bits,
                block,
                codes,
                exps,
            } => {
                w.write_all(&bits.to_le_bytes())?;
                w.write_all(&(*block as u32).to_le_bytes())?;
                w.write_all(&(codes.len() as u32).to_le_bytes())?;
                w.write_all(codes)?;
                w.write_all(&(exps.len() as u32).to_le_bytes())?;
                for &e in exps {
                    w.write_all(&e.to_le_bytes())?;
                }
            }
        }
        if let Some(rot) = &self.rotation {
            write_signs(w, &rot.left_signs)?;
            write_signs(w, &rot.right_signs)?;
        }
        Ok(())
    }

    /// Legacy v1 (uniform-only) writer, kept for back-compat tests.
    #[cfg(test)]
    pub(crate) fn write_to_v1(&self, w: &mut impl std::io::Write) -> Result<()> {
        let PackedScheme::Uniform {
            bits,
            group_size,
            codes,
            scales,
        } = &self.scheme
        else {
            bail!("v1 format is uniform-only");
        };
        assert!(self.rotation.is_none(), "v1 format has no rotation");
        w.write_all(b"ODP1")?;
        for v in [self.rows as u32, self.cols as u32, *bits, *group_size as u32] {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&(codes.len() as u32).to_le_bytes())?;
        w.write_all(codes)?;
        w.write_all(&(scales.len() as u32).to_le_bytes())?;
        for &s in scales {
            w.write_all(&s.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn read_from(r: &mut impl std::io::Read) -> Result<PackedMatrix> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        match &magic {
            b"ODP1" => Self::read_v1(r),
            b"ODP2" => Self::read_v2(r),
            other => bail!("bad packed-matrix magic {other:?}"),
        }
    }

    fn read_v1(r: &mut impl std::io::Read) -> Result<PackedMatrix> {
        let rows = read_u32(r)? as usize;
        let cols = read_u32(r)? as usize;
        validate_dims(rows, cols)?;
        Ok(PackedMatrix {
            rows,
            cols,
            scheme: read_uniform_body(r, rows, cols)?,
            rotation: None,
        })
    }

    fn read_v2(r: &mut impl std::io::Read) -> Result<PackedMatrix> {
        let tag = read_u32(r)?;
        let rotated = match read_u32(r)? {
            0 => false,
            1 => true,
            other => bail!("packed matrix: bad rotation flag {other}"),
        };
        let rows = read_u32(r)? as usize;
        let cols = read_u32(r)? as usize;
        validate_dims(rows, cols)?;
        let scheme = match tag {
            0 => read_uniform_body(r, rows, cols)?,
            1 => {
                let bits = read_u32(r)?;
                if !(2..=4).contains(&bits) {
                    bail!("e8 packed matrix: bits {bits} out of range 2..=4");
                }
                let mut b4 = [0u8; 4];
                r.read_exact(&mut b4)?;
                let scale = f32::from_le_bytes(b4);
                if !scale.is_finite() {
                    bail!("e8 packed matrix: non-finite scale");
                }
                let ncodes = read_u32(r)? as usize;
                let expect = (rows * cols * (bits + 2) as usize).div_ceil(8);
                if ncodes != expect {
                    bail!("e8 packed matrix: {ncodes} code bytes, want {expect}");
                }
                let codes = read_bytes(r, ncodes, "codes")?;
                PackedScheme::E8 { bits, scale, codes }
            }
            2 => {
                let bits = read_u32(r)?;
                if !(2..=8).contains(&bits) {
                    bail!("mxint packed matrix: bits {bits} out of range 2..=8");
                }
                let block = read_u32(r)? as usize;
                if block < 1 {
                    bail!("mxint packed matrix: zero block size");
                }
                let ncodes = read_u32(r)? as usize;
                let expect = (rows * cols * bits as usize).div_ceil(8);
                if ncodes != expect {
                    bail!("mxint packed matrix: {ncodes} code bytes, want {expect}");
                }
                let codes = read_bytes(r, ncodes, "codes")?;
                let nexps = read_u32(r)? as usize;
                let expect = rows * cols.div_ceil(block);
                if nexps != expect {
                    bail!("mxint packed matrix: {nexps} exponents, want {expect}");
                }
                let raw = read_bytes(r, nexps * 2, "exponents")?;
                let exps: Vec<i16> = raw
                    .chunks_exact(2)
                    .map(|c| i16::from_le_bytes([c[0], c[1]]))
                    .collect();
                for &e in &exps {
                    if e != MX_ZERO_EXP && !(-149..=127).contains(&i32::from(e)) {
                        bail!("mxint packed matrix: exponent {e} outside f32 range");
                    }
                }
                PackedScheme::MxInt {
                    bits,
                    block,
                    codes,
                    exps,
                }
            }
            other => bail!("packed matrix: unknown scheme tag {other}"),
        };
        let rotation = if rotated {
            Some(Rotation {
                left_signs: read_signs(r, rows)?,
                right_signs: read_signs(r, cols)?,
            })
        } else {
            None
        };
        Ok(PackedMatrix {
            rows,
            cols,
            scheme,
            rotation,
        })
    }
}

/// A `Write` sink that only counts — backs `byte_size()` so the reported
/// footprint is the serialized length by construction.
pub(crate) struct ByteCount(pub usize);

impl std::io::Write for ByteCount {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0 += buf.len();
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn validate_dims(rows: usize, cols: usize) -> Result<()> {
    if rows > MAX_DIM || cols > MAX_DIM {
        bail!("packed matrix: implausible shape {rows}x{cols}");
    }
    Ok(())
}

/// Uniform-scheme payload (bits, group, codes, scales) with every count
/// validated against the header dims — shared by the v1 stream body and
/// the v2 `tag == 0` arm so the two paths cannot drift.
fn read_uniform_body(r: &mut impl std::io::Read, rows: usize, cols: usize) -> Result<PackedScheme> {
    let bits = read_u32(r)?;
    if !(1..=8).contains(&bits) {
        bail!("uniform packed matrix: bits {bits} out of range 1..=8");
    }
    let group_size = read_u32(r)? as usize;
    if group_size < 1 || group_size > cols.max(1) {
        bail!("uniform packed matrix: group size {group_size} invalid for {cols} cols");
    }
    let ncodes = read_u32(r)? as usize;
    let expect = (rows * cols * bits as usize).div_ceil(8);
    if ncodes != expect {
        bail!("uniform packed matrix: {ncodes} code bytes, want {expect} for {rows}x{cols}@{bits}b");
    }
    let codes = read_bytes(r, ncodes, "codes")?;
    let nscales = read_u32(r)? as usize;
    let expect = rows * cols.div_ceil(group_size);
    if nscales != expect {
        bail!("uniform packed matrix: {nscales} scales, want {expect}");
    }
    let scales = read_f32s(r, nscales)?;
    Ok(PackedScheme::Uniform {
        bits,
        group_size,
        codes,
        scales,
    })
}

fn read_u32(r: &mut impl std::io::Read) -> Result<u32> {
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    Ok(u32::from_le_bytes(b4))
}

/// Read exactly `n` bytes through a bounded reader: a truncated stream
/// errors out after consuming only what exists instead of pre-allocating
/// `n` bytes on the word of a possibly-corrupt header.
fn read_bytes(r: &mut impl std::io::Read, n: usize, what: &str) -> Result<Vec<u8>> {
    use std::io::Read as _;
    let mut buf = Vec::new();
    r.by_ref().take(n as u64).read_to_end(&mut buf)?;
    if buf.len() != n {
        bail!("packed matrix truncated: {what} wants {n} bytes, got {}", buf.len());
    }
    Ok(buf)
}

fn read_f32s(r: &mut impl std::io::Read, n: usize) -> Result<Vec<f32>> {
    let raw = read_bytes(r, n * 4, "scales")?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn write_signs(w: &mut impl std::io::Write, signs: &[f32]) -> Result<()> {
    let mut bytes = vec![0u8; signs.len().div_ceil(8)];
    for (i, &s) in signs.iter().enumerate() {
        if s > 0.0 {
            bytes[i / 8] |= 1 << (i % 8);
        }
    }
    w.write_all(&bytes)?;
    Ok(())
}

fn read_signs(r: &mut impl std::io::Read, n: usize) -> Result<Vec<f32>> {
    let bytes = read_bytes(r, n.div_ceil(8), "rotation signs")?;
    let mut signs = Vec::with_capacity(n);
    for i in 0..n {
        signs.push(if bytes[i / 8] & (1 << (i % 8)) != 0 { 1.0 } else { -1.0 });
    }
    Ok(signs)
}

/// Extract the power-of-two exponent of `step` from its bit pattern, so
/// `exp_pow2(pow2_exponent(step)) == step` **bit-exactly** (normal and
/// denormal). `None` when `step` is not a positive power of two.
pub(crate) fn pow2_exponent(step: f32) -> Option<i16> {
    if !(step > 0.0 && step.is_finite()) {
        return None;
    }
    let bits = step.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32;
    let mantissa = bits & 0x7f_ffff;
    if exp == 0 {
        // Denormal: step = 2^{trailing - 149}; a power of two has exactly
        // one mantissa bit set.
        if mantissa.count_ones() == 1 {
            Some((mantissa.trailing_zeros() as i32 - 149) as i16)
        } else {
            None
        }
    } else if mantissa == 0 {
        Some((exp - 127) as i16)
    } else {
        None
    }
}

/// Exact `2^e` as f32 for `e ∈ [-149, 127]`, built from the bit pattern.
pub(crate) fn exp_pow2(e: i16) -> f32 {
    let e = e as i32;
    debug_assert!((-149..=127).contains(&e), "exponent {e} out of f32 range");
    if e >= -126 {
        f32::from_bits(((e + 127) as u32) << 23)
    } else {
        f32::from_bits(1u32 << (e + 149))
    }
}

/// Scalar code read through a two-byte window (a code is at most 8 bits
/// wide, so with a ≤7-bit intra-byte offset it spans at most 16 bits).
/// Bytes past the buffer read as 0, mirroring [`BitReader::refill`].
#[inline]
fn read_code(buf: &[u8], bitpos: usize, bits: u32) -> i32 {
    let byte = bitpos / 8;
    let lo = *buf.get(byte).unwrap_or(&0) as u32;
    let hi = *buf.get(byte + 1).unwrap_or(&0) as u32;
    (((lo | (hi << 8)) >> (bitpos % 8)) & ((1u32 << bits) - 1)) as i32
}

/// SWAR bulk extraction: after a scalar head reaches a byte boundary, each
/// iteration reads one little-endian `u64` word and emits `cpc` codes via
/// shifts/masks, advancing `cpc·bits/8` whole bytes (`cpc·bits` must be a
/// multiple of 8 and ≤ 64). The bulk loop only runs while a full 8-byte
/// window exists; remaining codes decode through the scalar tail.
#[inline]
fn unpack_swar(buf: &[u8], start_bit: usize, bits: u32, cpc: usize, out: &mut [i32]) {
    let chunk_bits = cpc * bits as usize;
    debug_assert!(chunk_bits <= 64 && chunk_bits % 8 == 0);
    let mask = (1u64 << bits) - 1;
    let n = out.len();
    let mut k = 0usize;
    let mut bitpos = start_bit;
    // Head: codes until the stream is byte-aligned (row starts at
    // `i·cols·bits`, whose residue always reaches 0 in ≤ 8 steps for the
    // widths dispatched here; an unreachable alignment just means the whole
    // row decodes through this scalar loop, which stays correct).
    while k < n && bitpos % 8 != 0 {
        out[k] = read_code(buf, bitpos, bits);
        bitpos += bits as usize;
        k += 1;
    }
    let mut byte = bitpos / 8;
    while n - k >= cpc && byte + 8 <= buf.len() {
        // lint:allow(hot-path-panic) the loop guard `byte + 8 <= buf.len()` makes the 8-byte slice exact
        let w = u64::from_le_bytes(buf[byte..byte + 8].try_into().unwrap());
        let mut shift = 0u32;
        for slot in &mut out[k..k + cpc] {
            *slot = ((w >> shift) & mask) as i32;
            shift += bits;
        }
        k += cpc;
        byte += chunk_bits / 8;
    }
    // Tail: whatever the guarded bulk loop could not cover.
    bitpos = byte * 8;
    while k < n {
        out[k] = read_code(buf, bitpos, bits);
        bitpos += bits as usize;
        k += 1;
    }
}

/// Decode `out.len()` consecutive codes of stored width `bits` starting at
/// absolute bit offset `start_bit`. Dispatches **once per call** on the
/// width to a word-level SWAR kernel (2/3/4/5/6/8-bit — every width the
/// uniform/MXINT/E8 layouts emit); other widths take a scalar
/// two-byte-window path. Bit-identical to reading each code through
/// [`BitReader`] (property-tested below).
pub(crate) fn unpack_codes(buf: &[u8], start_bit: usize, bits: u32, out: &mut [i32]) {
    match bits {
        // 32 codes per u64 word.
        2 => unpack_swar(buf, start_bit, 2, 32, out),
        // 8 codes per 24-bit chunk (3 bytes).
        3 => unpack_swar(buf, start_bit, 3, 8, out),
        // 16 codes per u64 word.
        4 => unpack_swar(buf, start_bit, 4, 16, out),
        // 8 codes per 40-bit chunk (5 bytes).
        5 => unpack_swar(buf, start_bit, 5, 8, out),
        // 8 codes per 48-bit chunk (6 bytes).
        6 => unpack_swar(buf, start_bit, 6, 8, out),
        // 8 codes per u64 word.
        8 => unpack_swar(buf, start_bit, 8, 8, out),
        _ => {
            let mut bitpos = start_bit;
            for slot in out {
                *slot = read_code(buf, bitpos, bits);
                bitpos += bits as usize;
            }
        }
    }
}

/// Sequential LSB-first bit-stream reader over the packed code buffer.
struct BitReader<'a> {
    buf: &'a [u8],
    /// Next byte to refill from.
    byte: usize,
    /// Bit accumulator (LSB-aligned) and its fill level.
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    /// Position the reader at an absolute bit offset.
    fn at(buf: &'a [u8], bitpos: usize) -> BitReader<'a> {
        let byte = bitpos / 8;
        let skip = (bitpos % 8) as u32;
        let mut r = BitReader {
            buf,
            byte,
            acc: 0,
            nbits: 0,
        };
        if skip > 0 {
            r.refill(skip);
            r.acc >>= skip;
            r.nbits -= skip;
        }
        r
    }

    #[inline]
    fn refill(&mut self, want: u32) {
        while self.nbits < want {
            let b = if self.byte < self.buf.len() {
                self.buf[self.byte]
            } else {
                0
            };
            self.byte += 1;
            self.acc |= (b as u64) << self.nbits;
            self.nbits += 8;
        }
    }

    #[inline]
    fn take(&mut self, n: u32) -> u32 {
        self.refill(n);
        let v = (self.acc & ((1u64 << n) - 1)) as u32;
        self.acc >>= n;
        self.nbits -= n;
        v
    }
}

pub(crate) fn write_bits(buf: &mut [u8], bitpos: usize, nbits: u32, value: u32) {
    for b in 0..nbits {
        let bit = (value >> b) & 1;
        let pos = bitpos + b as usize;
        if bit != 0 {
            buf[pos / 8] |= 1 << (pos % 8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::Incoherence;
    use crate::quant::{make_quantizer, Quantizer as _};
    use crate::testing;
    use crate::util::rng::Pcg64;

    /// Bit-at-a-time reference reader (the original implementation) used to
    /// cross-check the streaming [`BitReader`].
    fn read_bits(buf: &[u8], bitpos: usize, nbits: u32) -> u32 {
        let mut v = 0u32;
        for b in 0..nbits {
            let pos = bitpos + b as usize;
            if buf[pos / 8] & (1 << (pos % 8)) != 0 {
                v |= 1 << b;
            }
        }
        v
    }

    #[test]
    fn pack_unpack_matches_uniform_quantizer() {
        testing::quick("pack-roundtrip", |rng| {
            let m = testing::gen_dim(rng, 1, 12);
            let n = testing::gen_dim(rng, 1, 70);
            let bits = 2 + rng.below(3) as u32;
            let w = testing::gen_matrix(rng, m, n);
            let packed = PackedMatrix::pack(&w, bits, 32);
            let deq = packed.unpack();
            // Same rounding as the uniform quantizer with group 32.
            let q = crate::quant::UniformQuantizer::new(bits, 32);
            use crate::quant::Quantizer as _;
            let direct = q.quantize(&w).deq;
            assert!(deq.max_abs_diff(&direct) < 1e-5);
        });
    }

    #[test]
    fn native_codes_roundtrip_bit_exactly_per_scheme() {
        // The tentpole contract: encode(quantizer output) decodes to the
        // identical f32 matrix — zero error, any scheme, any shape.
        testing::quick("native-codes-exact", |rng| {
            let m = testing::gen_dim(rng, 1, 14);
            let n = testing::gen_dim(rng, 1, 60);
            let scheme = ["uniform", "e8", "mxint"][rng.below(3)];
            let bits = 2 + rng.below(3) as u32;
            let group = [3usize, 8, 16, 32][rng.below(4)];
            let w = testing::gen_matrix(rng, m, n);
            let quant = make_quantizer(scheme, bits, group).unwrap();
            let out = quant.quantize(&w);
            assert_eq!(out.packed.rows, m);
            assert_eq!(out.packed.cols, n);
            assert_eq!(
                out.packed.unpack().max_abs_diff(&out.deq),
                0.0,
                "{scheme}@{bits}b g{group} native codes not bit-exact"
            );
            // And the serialized form round-trips structurally + bitwise.
            let mut buf = Vec::new();
            out.packed.write_to(&mut buf).unwrap();
            let back = PackedMatrix::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(out.packed, back);
            assert_eq!(back.unpack().max_abs_diff(&out.deq), 0.0);
            assert_eq!(buf.len(), out.packed.byte_size(), "byte_size drifted");
        });
    }

    #[test]
    fn rotated_codes_decode_bit_exactly() {
        // Uniform/e8/mxint codes in the incoherent basis: unpack() must
        // reproduce Incoherence::unapply(Q̃) with zero error.
        testing::quick("rotated-codes-exact", |rng| {
            let m = testing::gen_dim(rng, 2, 20);
            let n = testing::gen_dim(rng, 2, 40);
            let scheme = ["uniform", "e8", "mxint"][rng.below(3)];
            let w = testing::gen_matrix(rng, m, n);
            let inc = Incoherence::new(m, n, rng);
            let quant = make_quantizer(scheme, 3, 8).unwrap();
            let out = quant.quantize(&inc.apply(&w));
            let reference = inc.unapply(&out.deq);
            let packed = out
                .packed
                .with_rotation(inc.left_signs.clone(), inc.right_signs.clone());
            assert_eq!(packed.unpack().max_abs_diff(&reference), 0.0, "{scheme}");
            let mut buf = Vec::new();
            packed.write_to(&mut buf).unwrap();
            let back = PackedMatrix::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(packed, back);
            assert_eq!(back.unpack().max_abs_diff(&reference), 0.0);
        });
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Pcg64::new(130, 1);
        let w = Matrix::randn(9, 33, 1.0, &mut rng);
        let p = PackedMatrix::pack(&w, 2, 16);
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let q = PackedMatrix::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(p, q);
        assert!(p.unpack().max_abs_diff(&q.unpack()) == 0.0);
    }

    #[test]
    fn footprint_matches_advertised_bits() {
        let mut rng = Pcg64::new(131, 1);
        let w = Matrix::randn(128, 256, 1.0, &mut rng);
        let p = PackedMatrix::pack(&w, 2, 64);
        // 2 bits + 32-bit scale per 64 weights = 2.5 bits + header dust.
        let bpw = p.bits_per_weight();
        assert!(bpw < 2.6, "bits/weight = {bpw}");
        assert!(bpw >= 2.5);
    }

    #[test]
    fn bit_io_roundtrip() {
        let mut buf = vec![0u8; 16];
        let vals = [5u32, 0, 7, 3, 1, 6, 2, 4];
        for (i, &v) in vals.iter().enumerate() {
            write_bits(&mut buf, i * 3, 3, v);
        }
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(read_bits(&buf, i * 3, 3), v);
        }
    }

    #[test]
    fn bit_reader_matches_reference_at_any_offset() {
        let mut rng = Pcg64::new(200, 1);
        let buf: Vec<u8> = (0..64).map(|_| rng.below(256) as u8).collect();
        for bits in [2u32, 3, 4, 5, 7, 8] {
            for start in 0..16 {
                let mut reader = BitReader::at(&buf, start);
                let mut pos = start;
                for _ in 0..40 {
                    assert_eq!(
                        reader.take(bits),
                        read_bits(&buf, pos, bits),
                        "bits={bits} start={start} pos={pos}"
                    );
                    pos += bits as usize;
                }
            }
        }
    }

    #[test]
    fn pack_unpack_roundtrip_all_bit_widths_with_tails() {
        // 2/3/4/8 bits × shapes whose widths are NOT multiples of the group
        // size (tail groups) and whose code streams are NOT byte-aligned.
        testing::quick("pack-roundtrip-widths", |rng| {
            let m = testing::gen_dim(rng, 1, 9);
            let n = testing::gen_dim(rng, 1, 77);
            let bits = [2u32, 3, 4, 8][rng.below(4)];
            let group = [3usize, 5, 16, 32][rng.below(4)];
            let w = testing::gen_matrix(rng, m, n);
            let p = PackedMatrix::pack(&w, bits, group);
            let deq = p.unpack();
            // Packing the dequantized output again is a fixed point.
            let p2 = PackedMatrix::pack(&deq, bits, group);
            let tol = 1e-5 * w.abs_max().max(1.0);
            assert!(
                p2.unpack().max_abs_diff(&deq) <= tol,
                "pack not idempotent at {bits} bits group {group}"
            );
            // And the serialized form round-trips bit-exactly.
            let mut buf = Vec::new();
            p.write_to(&mut buf).unwrap();
            let back = PackedMatrix::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(p, back);
            assert!(back.unpack().max_abs_diff(&deq) == 0.0);
        });
    }

    #[test]
    fn dequant_row_matches_unpack() {
        testing::quick("dequant-row", |rng| {
            let m = testing::gen_dim(rng, 1, 12);
            let n = testing::gen_dim(rng, 1, 50);
            let bits = [2u32, 3, 4, 8][rng.below(4)];
            let w = testing::gen_matrix(rng, m, n);
            let p = PackedMatrix::pack(&w, bits, 7);
            let dense = p.unpack();
            let mut row = vec![0f32; n];
            for i in 0..m {
                p.dequant_row_into(i, &mut row);
                assert_eq!(&row[..], dense.row(i), "row {i}");
            }
        });
    }

    #[test]
    fn specialized_unpackers_match_bitreader_at_any_offset() {
        // The word-level SWAR kernels must agree with the scalar reference
        // for every width, at every starting offset, through buffer tails
        // where the guarded u64 bulk loop has to hand off to scalar codes.
        testing::quick("unpack-codes-exact", |rng| {
            let buf: Vec<u8> = (0..2 + rng.below(96)).map(|_| rng.below(256) as u8).collect();
            let bits = 1 + rng.below(8) as u32; // 1..=8 incl. fallback widths
            let start = rng.below(buf.len().min(8) * 8);
            let max_codes = (buf.len() * 8).saturating_sub(start) / bits as usize;
            let n = rng.below(max_codes + 1);
            let mut out = vec![0i32; n];
            unpack_codes(&buf, start, bits, &mut out);
            for (k, &got) in out.iter().enumerate() {
                let want = read_bits(&buf, start + k * bits as usize, bits) as i32;
                assert_eq!(got, want, "bits={bits} start={start} code {k}");
            }
        });
    }

    #[test]
    fn fast_row_decode_is_bit_identical_per_scheme() {
        // The decode-kernel contract: the specialized word-level row decode
        // reproduces the reference BitReader decode **bit-exactly** for
        // every scheme × bit-width × ragged tail × random row, including
        // codes stored in the Hadamard-rotated basis.
        testing::quick("fast-row-decode-exact", |rng| {
            let m = testing::gen_dim(rng, 1, 14);
            let n = testing::gen_dim(rng, 1, 77);
            let group = [3usize, 5, 8, 32][rng.below(4)];
            let w = testing::gen_matrix(rng, m, n);
            let packed = match rng.below(3) {
                // Uniform straight through pack() so widths 5..=8 (which no
                // quantizer emits) are covered too.
                0 => PackedMatrix::pack(&w, 2 + rng.below(7) as u32, group),
                _ => {
                    let scheme = ["e8", "mxint"][rng.below(2)];
                    let bits = 2 + rng.below(3) as u32;
                    let quant = make_quantizer(scheme, bits, group).unwrap();
                    quant.quantize(&w).packed
                }
            };
            // Rotation metadata must not perturb the stored-basis decode.
            let packed = if m >= 2 && n >= 2 && rng.below(2) == 1 {
                let inc = Incoherence::new(m, n, rng);
                let mut p = packed;
                p.rotation = Some(Rotation {
                    left_signs: inc.left_signs.clone(),
                    right_signs: inc.right_signs.clone(),
                });
                p
            } else {
                packed
            };
            let mut reference = vec![0f32; n];
            let mut fast = vec![0f32; n];
            let mut codes = Vec::new();
            for _ in 0..4 {
                let i = rng.below(m);
                packed.dequant_row_into(i, &mut reference);
                packed.dequant_row_fast_into(i, &mut codes, &mut fast);
                for (j, (&a, &b)) in reference.iter().zip(&fast).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{}@{}b row {i} col {j}: {a} vs {b}",
                        packed.scheme.name(),
                        packed.bits()
                    );
                }
            }
        });
    }

    #[test]
    fn fused_dot_matches_materialized_row_dot() {
        // dot_row_codes hoists the scale out of each group, so it agrees
        // with (decoded row)·x to f32 rounding — not bitwise.
        testing::quick("fused-dot", |rng| {
            let m = testing::gen_dim(rng, 1, 10);
            let n = testing::gen_dim(rng, 1, 70);
            let scheme = ["uniform", "e8", "mxint"][rng.below(3)];
            let bits = 2 + rng.below(3) as u32;
            let group = [3usize, 8, 32][rng.below(3)];
            let w = testing::gen_matrix(rng, m, n);
            let packed = make_quantizer(scheme, bits, group).unwrap().quantize(&w).packed;
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut row = vec![0f32; n];
            let mut codes = Vec::new();
            for i in 0..m {
                packed.dequant_row_into(i, &mut row);
                let want: f32 = row.iter().zip(&x).map(|(&wv, &xv)| wv * xv).sum();
                packed.load_row_codes(i, &mut codes);
                let got = packed.dot_row_codes(i, &codes, &x);
                let mag: f32 = row.iter().map(|v| v.abs()).sum();
                let tol = 1e-4 * want.abs().max(mag).max(1e-3);
                assert!(
                    (got - want).abs() <= tol,
                    "{scheme}@{bits}b row {i}: fused {got} vs reference {want}"
                );
            }
        });
    }

    #[test]
    fn rotation_vector_helpers_match_matrix_ops() {
        // The slice-form rotation used by the single-vector decode kernel
        // must replay the 1-row matrix ops bit-for-bit.
        testing::quick("rotation-vec", |rng| {
            let m = testing::gen_dim(rng, 2, 24);
            let n = testing::gen_dim(rng, 2, 24);
            let inc = Incoherence::new(m, n, rng);
            let rot = Rotation {
                left_signs: inc.left_signs.clone(),
                right_signs: inc.right_signs.clone(),
            };
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let xm = Matrix::from_vec(1, n, x.clone());
            let want = rot.rotate_acts_t(&xm);
            let got = rot.rotate_vec(&x);
            assert_eq!(&got[..], want.row(0), "rotate_vec diverged");
            let y: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
            let ym = Matrix::from_vec(1, m, y.clone());
            let want = rot.unrotate_out_t(&ym);
            let mut got = y;
            rot.unrotate_vec(&mut got);
            assert_eq!(&got[..], want.row(0), "unrotate_vec diverged");
        });
    }

    #[test]
    fn pow2_exponent_roundtrips_full_f32_range() {
        for e in -149i16..=127 {
            let step = exp_pow2(e);
            assert!(step > 0.0 && step.is_finite());
            assert_eq!(pow2_exponent(step), Some(e), "e={e}");
        }
        assert_eq!(pow2_exponent(0.0), None);
        assert_eq!(pow2_exponent(3.0), None);
        assert_eq!(pow2_exponent(f32::INFINITY), None);
        assert_eq!(pow2_exponent(-2.0), None);
    }

    /// Golden-bytes check: the v2 uniform layout must not silently drift.
    /// Hand-assembled: W = [3, -1, 2, 0] at 3 bits, group 4 ⇒ scale
    /// = absmax/qmax = 3/3 = 1.0, codes (q+3) = [6, 2, 5, 3], packed
    /// LSB-first into 0x56, 0x07.
    #[test]
    fn serialized_golden_bytes_uniform_v2() {
        let w = Matrix::from_vec(1, 4, vec![3.0, -1.0, 2.0, 0.0]);
        let p = PackedMatrix::pack(&w, 3, 4);
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let expect: Vec<u8> = [
            &b"ODP2"[..],              // magic
            &0u32.to_le_bytes()[..],   // scheme tag: uniform
            &0u32.to_le_bytes()[..],   // not rotated
            &1u32.to_le_bytes()[..],   // rows
            &4u32.to_le_bytes()[..],   // cols
            &3u32.to_le_bytes()[..],   // bits
            &4u32.to_le_bytes()[..],   // group_size
            &2u32.to_le_bytes()[..],   // ncodes
            &[0x56u8, 0x07][..],       // codes
            &1u32.to_le_bytes()[..],   // nscales
            &1.0f32.to_le_bytes()[..], // scale
        ]
        .concat();
        assert_eq!(buf, expect, "packed on-disk format drifted");
        // And it decodes back to the exact input (all values on-grid).
        assert_eq!(p.unpack(), w);
    }

    /// E8 golden bytes: 2-bit operating point ⇒ lim 2, 4 bits/coordinate,
    /// codes = 2q + 4. Q̃ = [1, -0.5, 2, 0.5, 0, -2, 1.5, -1] at scale 0.5
    /// ⇒ codes [6, 3, 8, 5, 4, 0, 7, 2] → bytes 0x36, 0x58, 0x04, 0x27.
    #[test]
    fn serialized_golden_bytes_e8() {
        let vals = [1.0f32, -0.5, 2.0, 0.5, 0.0, -2.0, 1.5, -1.0];
        let mut codes = vec![0u8; 4];
        for (i, &q) in vals.iter().enumerate() {
            write_bits(&mut codes, i * 4, 4, ((2.0 * q) as i32 + 4) as u32);
        }
        let p = PackedMatrix {
            rows: 1,
            cols: 8,
            scheme: PackedScheme::E8 {
                bits: 2,
                scale: 0.5,
                codes,
            },
            rotation: None,
        };
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let expect: Vec<u8> = [
            &b"ODP2"[..],
            &1u32.to_le_bytes()[..], // scheme tag: e8
            &0u32.to_le_bytes()[..], // not rotated
            &1u32.to_le_bytes()[..], // rows
            &8u32.to_le_bytes()[..], // cols
            &2u32.to_le_bytes()[..], // bits
            &0.5f32.to_le_bytes()[..],
            &4u32.to_le_bytes()[..], // ncodes
            &[0x36u8, 0x58, 0x04, 0x27][..],
        ]
        .concat();
        assert_eq!(buf, expect, "e8 on-disk format drifted");
        let deq = p.unpack();
        for (j, &q) in vals.iter().enumerate() {
            assert_eq!(deq.at(0, j), q * 0.5, "coord {j}");
        }
    }

    /// MXINT golden bytes: 3-bit mantissas (mmax 3), block 4. One block
    /// with step 2^-1: Q = [1.5, -0.5, 0, 1.0] ⇒ mantissas [3, -1, 0, 2]
    /// ⇒ codes (m+3) = [6, 2, 3, 5] packed LSB-first → bytes 0xD6, 0x0A.
    #[test]
    fn serialized_golden_bytes_mxint() {
        let mut codes = vec![0u8; 2];
        for (i, &m) in [3i32, -1, 0, 2].iter().enumerate() {
            write_bits(&mut codes, i * 3, 3, (m + 3) as u32);
        }
        let p = PackedMatrix {
            rows: 1,
            cols: 4,
            scheme: PackedScheme::MxInt {
                bits: 3,
                block: 4,
                codes,
                exps: vec![-1],
            },
            rotation: None,
        };
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let expect: Vec<u8> = [
            &b"ODP2"[..],
            &2u32.to_le_bytes()[..], // scheme tag: mxint
            &0u32.to_le_bytes()[..], // not rotated
            &1u32.to_le_bytes()[..], // rows
            &4u32.to_le_bytes()[..], // cols
            &3u32.to_le_bytes()[..], // bits
            &4u32.to_le_bytes()[..], // block
            &2u32.to_le_bytes()[..], // ncodes
            &[0xD6u8, 0x0A][..],
            &1u32.to_le_bytes()[..], // nexps
            &(-1i16).to_le_bytes()[..],
        ]
        .concat();
        assert_eq!(buf, expect, "mxint on-disk format drifted");
        assert_eq!(
            p.unpack(),
            Matrix::from_vec(1, 4, vec![1.5, -0.5, 0.0, 1.0])
        );
    }

    /// Rotation golden bytes: sign diagonals append as LSB-first bitmaps.
    #[test]
    fn serialized_golden_bytes_rotation() {
        let w = Matrix::from_vec(2, 4, vec![3.0, -1.0, 2.0, 0.0, 1.0, 1.0, -3.0, 2.0]);
        let p = PackedMatrix::pack(&w, 3, 4)
            .with_rotation(vec![1.0, -1.0], vec![-1.0, 1.0, 1.0, -1.0]);
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        // Header now flags rotation and the payload gains two sign bytes.
        assert_eq!(&buf[4..8], &0u32.to_le_bytes()); // uniform tag
        assert_eq!(&buf[8..12], &1u32.to_le_bytes()); // rotated
        let tail = &buf[buf.len() - 2..];
        assert_eq!(tail, &[0b01u8, 0b0110]);
        let back = PackedMatrix::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back, p);
    }

    /// v1 → v2 back-compat: legacy `ODP1` streams (uniform-only) still read
    /// into the identical matrix.
    #[test]
    fn reads_legacy_v1_stream() {
        let mut rng = Pcg64::new(133, 1);
        let w = Matrix::randn(7, 29, 1.0, &mut rng);
        let p = PackedMatrix::pack(&w, 4, 8);
        let mut v1 = Vec::new();
        p.write_to_v1(&mut v1).unwrap();
        // The golden v1 prefix: magic + rows + cols + bits + group.
        assert_eq!(&v1[..4], b"ODP1");
        let back = PackedMatrix::read_from(&mut v1.as_slice()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.unpack().max_abs_diff(&p.unpack()), 0.0);
    }

    /// A corrupt or truncated stream must error out instead of allocating
    /// unbounded buffers or panicking later in `dequant_row_into`.
    #[test]
    fn corrupt_streams_are_rejected() {
        let mut rng = Pcg64::new(134, 1);
        let w = Matrix::randn(5, 17, 1.0, &mut rng);
        let p = PackedMatrix::pack(&w, 3, 8);
        let mut good = Vec::new();
        p.write_to(&mut good).unwrap();

        // Truncation at every prefix length fails cleanly.
        for cut in 0..good.len() {
            assert!(
                PackedMatrix::read_from(&mut &good[..cut]).is_err(),
                "truncated at {cut} bytes did not error"
            );
        }

        // ncodes lying about its length (huge claim, tiny stream).
        let ncodes_off = 4 + 4 * 6; // magic + tag,rot,rows,cols,bits,group
        let mut bad = good.clone();
        bad[ncodes_off..ncodes_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(PackedMatrix::read_from(&mut bad.as_slice()).is_err());

        // Absurd dims are rejected before any payload read.
        let mut bad = good.clone();
        bad[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(PackedMatrix::read_from(&mut bad.as_slice()).is_err());

        // Unknown scheme tag.
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&9u32.to_le_bytes());
        assert!(PackedMatrix::read_from(&mut bad.as_slice()).is_err());

        // Same lie in a v1 header: ncodes mismatch must error.
        let mut v1 = Vec::new();
        p.write_to_v1(&mut v1).unwrap();
        let mut bad = v1.clone();
        bad[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(PackedMatrix::read_from(&mut bad.as_slice()).is_err());
        for cut in 0..v1.len() {
            assert!(PackedMatrix::read_from(&mut &v1[..cut]).is_err());
        }
    }
}
