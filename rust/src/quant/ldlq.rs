//! LDLQ / GPTQ error-feedback quantization.
//!
//! Quantizes the columns of `W` sequentially; after quantizing a column
//! block, the rounding error is propagated into the not-yet-quantized
//! columns through the Cholesky factor of the inverse Hessian, greedily
//! minimizing the activation-aware error `tr((W−Q) H (W−Q)^T)`. This is the
//! `Quantize` step CALDERA (and QuIP/OPTQ) use; the paper's Algorithm 1
//! calls it at every outer iteration on `W − L_{t-1} R_{t-1}`.
//!
//! Derivation sketch (GPTQ form): with `H⁻¹ = Uᵀ U` (U upper-triangular),
//! processing column k and distributing the error
//! `e = (w_k − q_k)/U_kk` onto columns j>k as `w_j ← w_j − e·U_kj`
//! keeps the objective's already-paid cost fixed and re-optimizes the rest.

use crate::linalg::{cholesky_jittered, solve_lower, solve_lower_transpose};
use crate::tensor::Matrix;

/// Run blocked LDLQ. `round` maps a column block (m × b, already
/// error-adjusted) plus its absolute column offset to its quantized
/// (dequantized) values. `block` is the feedback granularity: error is
/// propagated after each block of that many columns (1 = scalar GPTQ,
/// 8 = E8 blocks).
pub fn ldlq_quantize(
    w: &Matrix,
    h: &Matrix,
    block: usize,
    round: impl Fn(&Matrix, usize) -> Matrix,
) -> Matrix {
    let (m, n) = w.shape();
    assert_eq!(h.shape(), (n, n), "Hessian must be n×n");
    let block = block.max(1);

    // U upper-triangular with H^{-1} = U^T U:
    //   H = C Cᵀ  ⇒  H⁻¹ = C⁻ᵀ C⁻¹. We need an upper-tri V with
    //   H⁻¹ = Vᵀ V... note C⁻¹ is lower-tri, so H⁻¹ = (C⁻¹)ᵀ (C⁻¹) with
    //   (C⁻¹)ᵀ upper: take U = (C⁻¹)ᵀ? Then Uᵀ U = C⁻¹ C⁻ᵀ ≠ H⁻¹.
    // The GPTQ recursion only needs, for each k, the row vector
    //   u_k = H⁻¹[k, k:] / sqrt(H⁻¹[k, k])  restricted to the trailing
    // submatrix of the *remaining* columns. The standard trick: U =
    // chol_upper(H⁻¹) computed on the reversed index order, or simply the
    // explicit recursion below, which we implement via one full inverse and
    // an in-place trailing update (O(n³), fine at our sizes).
    let (c, _lambda) = cholesky_jittered(h, 1e-4).expect("Hessian not factorizable");
    // H^{-1} = C^{-T} C^{-1}: solve twice against the identity.
    let hinv = {
        let y = solve_lower(&c, &Matrix::eye(n));
        solve_lower_transpose(&c, &y)
    };

    let mut work = w.clone(); // columns get error-adjusted in place
    let mut q = Matrix::zeros(m, n);
    let mut hinv = hinv; // trailing submatrix updated via Schur complement

    let mut k = 0;
    while k < n {
        let b = block.min(n - k);
        // Quantize the adjusted block.
        let cols = work.slice(0, m, k, k + b);
        let qcols = round(&cols, k);
        for i in 0..m {
            for j in 0..b {
                *q.at_mut(i, k + j) = qcols.at(i, j);
            }
        }
        if k + b >= n {
            break;
        }
        // Error feedback: E = (cols − qcols) (m×b);
        // W[:, k+b:] -= E @ inv(Hinv_bb) @ Hinv_b,rest
        // where Hinv_bb is the b×b leading block of the current trailing
        // inverse-Hessian. (For b=1 this reduces to the familiar
        // e/U_kk · U_k,rest update.)
        let e = cols.sub(&qcols);
        let hbb = hinv.slice(k, k + b, k, k + b);
        let hbr = hinv.slice(k, k + b, k + b, n);
        // Solve Hbb X = Hbr (b×rest) via its Cholesky (Hinv is SPD, so is
        // any principal block).
        let (cb, _l) = cholesky_jittered(&hbb, 1e-8).expect("block not SPD");
        let y = solve_lower(&cb, &hbr);
        let x = solve_lower_transpose(&cb, &y); // b × rest
        let upd = e.dot(&x); // m × rest
        for i in 0..m {
            for (j, &u) in upd.row(i).iter().enumerate() {
                *work.at_mut(i, k + b + j) -= u;
            }
        }
        // Schur-complement the trailing inverse Hessian:
        // Hinv_rest ← Hinv_rr − Hinv_rb Hbb⁻¹ Hinv_br = Hrr − Xᵀ Hbr... note
        // X = Hbb⁻¹ Hbr, so correction = Hbr^T X? (rest×b)(b×rest):
        let corr = hbr.tdot(&x); // rest × rest
        for i in 0..(n - k - b) {
            for j in 0..(n - k - b) {
                *hinv.at_mut(k + b + i, k + b + j) -= corr.at(i, j);
            }
        }
        k += b;
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{hessian_error, Quantizer, UniformQuantizer};
    use crate::testing;
    use crate::util::rng::Pcg64;

    /// With an identity Hessian, LDLQ degenerates to round-to-nearest.
    #[test]
    fn identity_hessian_is_rtn() {
        let mut rng = Pcg64::new(110, 1);
        let w = Matrix::randn(6, 10, 1.0, &mut rng);
        let h = Matrix::eye(10);
        let quant = UniformQuantizer::new(3, usize::MAX);
        let prep = quant.prepare(&w);
        let q = ldlq_quantize(&w, &h, 1, |c, c0| prep.round_columns(c, c0));
        let rtn = quant.quantize(&w);
        assert!(q.max_abs_diff(&rtn.deq) < 1e-5);
    }

    /// On a correlated Hessian LDLQ should strictly beat RTN most of the
    /// time — check the aggregate over many trials.
    #[test]
    fn beats_rtn_on_correlated_hessian() {
        let mut wins = 0;
        let trials = 25;
        for t in 0..trials {
            let mut rng = Pcg64::new(111, t + 1);
            let m = 8;
            let n = 16;
            let w = Matrix::randn(m, n, 1.0, &mut rng);
            // Strongly correlated activations → informative Hessian.
            let base = Matrix::randn(n, 4, 1.0, &mut rng);
            let noise = Matrix::randn(n, n, 0.1, &mut rng);
            let f = base.dot_t(&base).add(&noise.dot_t(&noise));
            let quant = UniformQuantizer::new(2, usize::MAX);
            let prep = quant.prepare(&w);
            let q = ldlq_quantize(&w, &f, 1, |c, c0| prep.round_columns(c, c0));
            let rtn = quant.quantize(&w);
            let e_ldlq = hessian_error(&w, &q, &f);
            let e_rtn = hessian_error(&w, &rtn.deq, &f);
            if e_ldlq < e_rtn {
                wins += 1;
            }
        }
        assert!(wins * 10 >= trials * 8, "LDLQ won only {wins}/{trials}");
    }

    /// Blocked feedback (b=8) must beat RTN *in aggregate*. (Per-case it can
    /// lose: the feedback adjustment can push values past the frozen scale
    /// range and clip — the same clipping GPTQ exhibits — so we check the
    /// mean over many problems plus a no-catastrophe bound per case.)
    #[test]
    fn blocked_feedback_sane() {
        let mut sum_b = 0.0f64;
        let mut sum_r = 0.0f64;
        for t in 0..32u64 {
            let mut rng = Pcg64::new(0xb10c, t + 1);
            let m = testing::gen_dim(&mut rng, 4, 12);
            let n = 8 * testing::gen_dim(&mut rng, 2, 4);
            let w = testing::gen_matrix(&mut rng, m, n);
            let h = testing::gen_spd(&mut rng, n);
            let quant = UniformQuantizer::new(2, usize::MAX);
            let prep = quant.prepare(&w);
            let q = ldlq_quantize(&w, &h, 8, |c, c0| prep.round_columns(c, c0));
            let rtn = quant.quantize(&w).deq;
            let e_b = hessian_error(&w, &q, &h);
            let e_r = hessian_error(&w, &rtn, &h);
            assert!(e_b <= e_r * 4.0 + 1e-6, "catastrophic: {e_b:.3e} vs {e_r:.3e}");
            // Normalize per-problem so no single case dominates the mean.
            sum_b += e_b / e_r.max(1e-12);
            sum_r += 1.0;
        }
        assert!(sum_b <= sum_r, "blocked LDLQ mean ratio {}", sum_b / sum_r);
    }

    /// Non-multiple block sizes and tiny matrices don't crash.
    #[test]
    fn edge_shapes() {
        let mut rng = Pcg64::new(112, 1);
        for &(m, n, b) in &[(1usize, 1usize, 1usize), (2, 3, 8), (5, 7, 3)] {
            let w = Matrix::randn(m, n, 1.0, &mut rng);
            let h = testing::gen_spd(&mut rng, n);
            let quant = UniformQuantizer::new(2, usize::MAX);
            let prep = quant.prepare(&w);
            let q = ldlq_quantize(&w, &h, b, |c, c0| prep.round_columns(c, c0));
            assert_eq!(q.shape(), (m, n));
            assert!(q.is_finite());
        }
    }
}
