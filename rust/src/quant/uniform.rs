//! Symmetric uniform b-bit quantizer with per-(row-)group absmax scales.

use super::packed::{write_bits, PackedMatrix, PackedScheme};
use super::{Prepared, Quantizer};
use crate::tensor::Matrix;

/// Symmetric uniform quantizer: values in a group are mapped to
/// `round(w / s)` clamped to `[-(2^{b-1}-1), 2^{b-1}-1]`, `s = absmax / qmax`.
///
/// Groups are contiguous runs of `group_size` entries within a row
/// (`usize::MAX` = one group per row, the GPTQ per-output-channel default).
#[derive(Clone, Debug)]
pub struct UniformQuantizer {
    pub bits: u32,
    pub group_size: usize,
}

impl UniformQuantizer {
    pub fn new(bits: u32, group_size: usize) -> UniformQuantizer {
        assert!((1..=8).contains(&bits), "uniform bits must be 1..=8");
        UniformQuantizer { bits, group_size }
    }

    #[inline]
    fn qmax(&self) -> f32 {
        ((1i32 << (self.bits - 1)) - 1).max(1) as f32
    }

    fn groups_per_row(&self, cols: usize) -> usize {
        if self.group_size == usize::MAX || self.group_size >= cols {
            1
        } else {
            cols.div_ceil(self.group_size)
        }
    }

    fn group_width(&self, cols: usize) -> usize {
        if self.group_size == usize::MAX || self.group_size >= cols {
            cols
        } else {
            self.group_size
        }
    }

    /// Per-row, per-group absmax scales.
    fn compute_scales(&self, w: &Matrix) -> Vec<f32> {
        let (m, n) = w.shape();
        let gw = self.group_width(n);
        let gpr = self.groups_per_row(n);
        let qmax = self.qmax();
        let mut scales = vec![0f32; m * gpr];
        for i in 0..m {
            let row = w.row(i);
            for g in 0..gpr {
                let lo = g * gw;
                let hi = ((g + 1) * gw).min(n);
                let absmax = row[lo..hi].iter().fold(0f32, |a, &v| a.max(v.abs()));
                // Floor the scale so an all-zero group stays exactly zero.
                scales[i * gpr + g] = if absmax > 0.0 { absmax / qmax } else { 1e-12 };
            }
        }
        scales
    }
}

impl Quantizer for UniformQuantizer {
    fn name(&self) -> String {
        let g = if self.group_size == usize::MAX {
            "row".to_string()
        } else {
            format!("g{}", self.group_size)
        };
        format!("uniform{}b-{}", self.bits, g)
    }

    fn bits(&self) -> f64 {
        self.bits as f64
    }

    fn bits_with_overhead(&self, rows: usize, cols: usize) -> f64 {
        // 16-bit scale per group.
        let gpr = self.groups_per_row(cols);
        self.bits as f64 + (rows * gpr * 16) as f64 / (rows * cols) as f64
    }

    fn prepare<'a>(&'a self, w: &Matrix) -> Box<dyn Prepared + 'a> {
        let scales = self.compute_scales(w);
        Box::new(PreparedUniform {
            q: self.clone(),
            cols: w.cols(),
            scales,
        })
    }
}

struct PreparedUniform {
    q: UniformQuantizer,
    cols: usize,
    scales: Vec<f32>,
}

impl Prepared for PreparedUniform {
    fn round_columns(&self, cols: &Matrix, c0: usize) -> Matrix {
        let (m, b) = cols.shape();
        let gw = self.q.group_width(self.cols);
        let gpr = self.q.groups_per_row(self.cols);
        let qmax = self.q.qmax();
        let mut out = Matrix::zeros(m, b);
        for i in 0..m {
            let src = cols.row(i);
            let dst = out.row_mut(i);
            for j in 0..b {
                let g = ((c0 + j) / gw).min(gpr - 1);
                let s = self.scales[i * gpr + g];
                let q = (src[j] / s).round().clamp(-qmax, qmax);
                dst[j] = q * s;
            }
        }
        out
    }

    fn scale_metric(&self) -> f32 {
        let n = self.scales.len().max(1);
        (self.scales.iter().map(|&s| s as f64).sum::<f64>() / n as f64) as f32
    }

    fn encode(&self, deq: &Matrix) -> PackedMatrix {
        let (m, n) = deq.shape();
        assert_eq!(n, self.cols, "encode width mismatch");
        let gw = self.q.group_width(n);
        let gpr = self.q.groups_per_row(n);
        let qmax = self.q.qmax() as i32;
        let bits = self.q.bits;
        let mut codes = vec![0u8; (m * n * bits as usize).div_ceil(8)];
        let mut bitpos = 0usize;
        for i in 0..m {
            for (j, &v) in deq.row(i).iter().enumerate() {
                let s = self.scales[i * gpr + (j / gw).min(gpr - 1)];
                // `v` is `q·s` for an integral `q` in range, so the divide
                // recovers `q` to well under half an ulp — decode recomputes
                // the identical `q·s` product and is therefore bit-exact.
                let q = ((v / s).round() as i32).clamp(-qmax, qmax);
                write_bits(&mut codes, bitpos, bits, (q + qmax) as u32);
                bitpos += bits as usize;
            }
        }
        PackedMatrix {
            rows: m,
            cols: n,
            scheme: PackedScheme::Uniform {
                bits,
                group_size: gw,
                codes,
                scales: self.scales.clone(),
            },
            rotation: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        testing::quick("uniform-halfstep", |rng| {
            let m = testing::gen_dim(rng, 1, 16);
            let n = testing::gen_dim(rng, 1, 64);
            let bits = 2 + (rng.below(3) as u32); // 2..4
            let w = testing::gen_matrix(rng, m, n);
            let q = UniformQuantizer::new(bits, usize::MAX);
            let out = q.quantize(&w);
            // Every entry within half a step of its row scale.
            let qmax = ((1 << (bits - 1)) - 1) as f32;
            for i in 0..m {
                let absmax = w.row(i).iter().fold(0f32, |a, &v| a.max(v.abs()));
                let step = absmax / qmax;
                for j in 0..n {
                    let err = (w.at(i, j) - out.deq.at(i, j)).abs();
                    assert!(err <= step * 0.5 + 1e-5, "err={err} step={step}");
                }
            }
        });
    }

    #[test]
    fn grouped_scales_respect_groups() {
        // Two groups with wildly different ranges: a grouped quantizer must
        // give the small group a small scale (much lower error there).
        let mut w = Matrix::zeros(1, 8);
        for j in 0..4 {
            *w.at_mut(0, j) = 100.0 * (j as f32 - 1.5);
        }
        for j in 4..8 {
            *w.at_mut(0, j) = 0.01 * (j as f32 - 5.5);
        }
        let grouped = UniformQuantizer::new(3, 4).quantize(&w);
        let global = UniformQuantizer::new(3, usize::MAX).quantize(&w);
        let err_g: f32 = (4..8).map(|j| (w.at(0, j) - grouped.deq.at(0, j)).abs()).sum();
        let err_r: f32 = (4..8).map(|j| (w.at(0, j) - global.deq.at(0, j)).abs()).sum();
        assert!(err_g < err_r * 0.1, "grouped={err_g} global={err_r}");
    }

    #[test]
    fn zero_matrix_stays_zero() {
        let w = Matrix::zeros(4, 16);
        let out = UniformQuantizer::new(2, 8).quantize(&w);
        assert_eq!(out.deq, w);
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Pcg64::new(90, 1);
        let w = Matrix::randn(16, 64, 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let out = UniformQuantizer::new(bits, usize::MAX).quantize(&w);
            let err = out.deq.sub(&w).frob_norm();
            assert!(err < last, "bits={bits}: {err} !< {last}");
            last = err;
        }
    }

    #[test]
    fn scale_metric_tracks_dynamic_range() {
        let mut rng = Pcg64::new(91, 1);
        let w = Matrix::randn(8, 32, 1.0, &mut rng);
        let big = w.scale(10.0);
        let q = UniformQuantizer::new(2, usize::MAX);
        assert!(q.quantize(&big).scale > 5.0 * q.quantize(&w).scale);
    }

    #[test]
    fn bits_overhead_accounting() {
        let q = UniformQuantizer::new(2, 64);
        // 128 cols → 2 groups/row → 32 scale bits per 128 weights = 0.25.
        assert!((q.bits_with_overhead(16, 128) - 2.25).abs() < 1e-9);
    }
}
