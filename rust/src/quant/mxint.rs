//! MXINT shared-exponent block quantizer (Darvish Rouhani et al., ISCA'23),
//! used by the paper's Table 11 ablation (3-bit, block size 32).
//!
//! Each block of `block` consecutive weights shares one power-of-two
//! exponent; elements are signed fixed-point mantissas with `bits-1`
//! magnitude bits. The shared exponent is chosen so the block's absmax just
//! fits.

use super::packed::{exp_pow2, pow2_exponent, write_bits, PackedMatrix, PackedScheme, MX_ZERO_EXP};
use super::{Prepared, Quantizer};
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct MxInt {
    pub bits: u32,
    pub block: usize,
}

impl MxInt {
    pub fn new(bits: u32, block: usize) -> MxInt {
        assert!((2..=8).contains(&bits), "mxint bits must be 2..=8");
        assert!(block >= 1);
        MxInt { bits, block }
    }

    /// Mantissa levels on each side of zero: 2^{bits-1} - 1.
    #[inline]
    fn mmax(&self) -> f32 {
        ((1i32 << (self.bits - 1)) - 1).max(1) as f32
    }

    /// Shared power-of-two step for a block with the given absmax.
    fn block_step(&self, absmax: f32) -> f32 {
        if absmax <= 0.0 {
            return 0.0;
        }
        // Smallest power-of-two step with absmax/step <= mmax. The step is
        // built from its bit pattern (not `powf`) so it is an exact power
        // of two for any libm — the packed container stores just the
        // exponent and must rebuild the identical f32.
        let raw = absmax / self.mmax();
        let e = (raw.log2().ceil() as i32).clamp(-149, 127) as i16;
        exp_pow2(e)
    }

    fn compute_steps(&self, w: &Matrix) -> Vec<f32> {
        let (m, n) = w.shape();
        let bpr = n.div_ceil(self.block);
        let mut steps = vec![0f32; m * bpr];
        for i in 0..m {
            let row = w.row(i);
            for b in 0..bpr {
                let lo = b * self.block;
                let hi = ((b + 1) * self.block).min(n);
                let absmax = row[lo..hi].iter().fold(0f32, |a, &v| a.max(v.abs()));
                steps[i * bpr + b] = self.block_step(absmax);
            }
        }
        steps
    }
}

impl Quantizer for MxInt {
    fn name(&self) -> String {
        format!("mxint{}b-b{}", self.bits, self.block)
    }

    fn bits(&self) -> f64 {
        self.bits as f64
    }

    fn bits_with_overhead(&self, _rows: usize, _cols: usize) -> f64 {
        // 8-bit shared exponent per block.
        self.bits as f64 + 8.0 / self.block as f64
    }

    fn prepare<'a>(&'a self, w: &Matrix) -> Box<dyn Prepared + 'a> {
        Box::new(PreparedMx {
            q: self.clone(),
            cols: w.cols(),
            steps: self.compute_steps(w),
        })
    }

    fn feedback_block(&self) -> usize {
        self.block
    }
}

struct PreparedMx {
    q: MxInt,
    cols: usize,
    steps: Vec<f32>,
}

impl Prepared for PreparedMx {
    fn round_columns(&self, cols: &Matrix, c0: usize) -> Matrix {
        let (m, b) = cols.shape();
        let bpr = self.cols.div_ceil(self.q.block);
        let mmax = self.q.mmax();
        let mut out = Matrix::zeros(m, b);
        for i in 0..m {
            let src = cols.row(i);
            let dst = out.row_mut(i);
            for j in 0..b {
                let blk = ((c0 + j) / self.q.block).min(bpr - 1);
                let step = self.steps[i * bpr + blk];
                dst[j] = if step == 0.0 {
                    0.0
                } else {
                    (src[j] / step).round().clamp(-mmax, mmax) * step
                };
            }
        }
        out
    }

    fn scale_metric(&self) -> f32 {
        let nz: Vec<f32> = self.steps.iter().copied().filter(|&s| s > 0.0).collect();
        if nz.is_empty() {
            return 0.0;
        }
        (nz.iter().map(|&s| s as f64).sum::<f64>() / nz.len() as f64) as f32
    }

    fn encode(&self, deq: &Matrix) -> PackedMatrix {
        let (m, n) = deq.shape();
        assert_eq!(n, self.cols, "encode width mismatch");
        let bpr = self.cols.div_ceil(self.q.block);
        let mmax = self.q.mmax() as i32;
        let bits = self.q.bits;
        // Exponents come from the steps' own bit patterns, so the decoder
        // rebuilds the identical f32 step (normal or denormal).
        let mut exps = Vec::with_capacity(self.steps.len());
        for &s in &self.steps {
            exps.push(if s == 0.0 {
                MX_ZERO_EXP
            } else {
                pow2_exponent(s).expect("mxint step is not a power of two")
            });
        }
        let mut codes = vec![0u8; (m * n * bits as usize).div_ceil(8)];
        let mut bitpos = 0usize;
        for i in 0..m {
            for (j, &v) in deq.row(i).iter().enumerate() {
                let step = self.steps[i * bpr + (j / self.q.block).min(bpr.max(1) - 1)];
                let q = if step == 0.0 {
                    0
                } else {
                    ((v / step).round() as i32).clamp(-mmax, mmax)
                };
                write_bits(&mut codes, bitpos, bits, (q + mmax) as u32);
                bitpos += bits as usize;
            }
        }
        PackedMatrix {
            rows: m,
            cols: n,
            scheme: PackedScheme::MxInt {
                bits,
                block: self.q.block,
                codes,
                exps,
            },
            rotation: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Pcg64;

    #[test]
    fn steps_are_powers_of_two() {
        let mut rng = Pcg64::new(120, 1);
        let w = Matrix::randn(4, 64, 3.0, &mut rng);
        let q = MxInt::new(3, 32);
        let steps = q.compute_steps(&w);
        for &s in &steps {
            assert!(s > 0.0);
            let e = s.log2();
            assert!((e - e.round()).abs() < 1e-5, "step {s} not pow2");
        }
    }

    #[test]
    fn error_bounded_by_half_step() {
        testing::quick("mxint-halfstep", |rng| {
            let m = testing::gen_dim(rng, 1, 8);
            let n = testing::gen_dim(rng, 1, 96);
            let w = testing::gen_matrix(rng, m, n);
            let q = MxInt::new(3, 32);
            let out = q.quantize(&w);
            let steps = q.compute_steps(&w);
            let bpr = n.div_ceil(32);
            for i in 0..m {
                for j in 0..n {
                    let step = steps[i * bpr + j / 32];
                    let err = (w.at(i, j) - out.deq.at(i, j)).abs();
                    assert!(err <= step * 0.5 + 1e-6, "err={err} step={step}");
                }
            }
        });
    }

    #[test]
    fn absmax_representable() {
        // The block's largest element must round to within half a step —
        // i.e. the chosen exponent never clips the absmax.
        let w = Matrix::from_vec(1, 4, vec![0.1, -7.3, 2.0, 0.0]);
        let q = MxInt::new(3, 4);
        let out = q.quantize(&w);
        let step = q.compute_steps(&w)[0];
        assert!((w.at(0, 1) - out.deq.at(0, 1)).abs() <= step * 0.5 + 1e-6);
    }

    #[test]
    fn more_bits_monotone() {
        let mut rng = Pcg64::new(121, 1);
        let w = Matrix::randn(8, 64, 1.0, &mut rng);
        let e3 = MxInt::new(3, 32).quantize(&w).deq.sub(&w).frob_norm();
        let e4 = MxInt::new(4, 32).quantize(&w).deq.sub(&w).frob_norm();
        let e6 = MxInt::new(6, 32).quantize(&w).deq.sub(&w).frob_norm();
        assert!(e4 < e3 && e6 < e4, "{e3} {e4} {e6}");
    }

    #[test]
    fn overhead_bits() {
        let q = MxInt::new(3, 32);
        assert!((q.bits_with_overhead(1, 320) - 3.25).abs() < 1e-9);
    }

    #[test]
    fn zero_block_stays_zero() {
        let w = Matrix::zeros(2, 64);
        let out = MxInt::new(3, 32).quantize(&w);
        assert_eq!(out.deq, w);
        assert_eq!(out.scale, 0.0);
    }
}
