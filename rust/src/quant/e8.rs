//! E8 lattice block quantizer (the QuIP# workhorse, paper §4.1).
//!
//! Weights are processed in blocks of 8; each block is scaled by a global
//! per-matrix scale `s` and rounded to the nearest point of the E8 lattice
//! (`E8 = D8 ∪ (D8 + ½·1)`, the densest packing in 8-D). The nearest-point
//! search is the exact Conway–Sloane algorithm (round-and-fix-parity for D8,
//! done for both cosets). Coordinates are clamped to ±`COORD_LIMIT` so the
//! effective codebook matches a 2-bit/weight budget like QuIP#'s E8P; we
//! use direct lattice rounding instead of their 2¹⁶-entry entropy-shaped
//! codebook (see DESIGN.md §2 — scale/error dynamics are what matter here).
//!
//! The global scale is chosen by a golden-ratio-free grid search minimizing
//! ‖W − Q(W)‖_F on a subsample — this is what makes the Figure-2
//! "quantization scale" respond when ODLRI smooths the residual.

use super::packed::{write_bits, PackedMatrix, PackedScheme};
use super::{Prepared, Quantizer};
use crate::tensor::Matrix;

const COORD_LIMIT: f32 = 2.0;

/// Coordinate clamp of the `bits`-bit operating point: 2-bit → ±2 (≈ E8P's
/// ball), each extra bit doubles the radius. Shared with the packed-code
/// decoder, which stores coordinates in half units of this limit.
pub(crate) fn e8_coord_limit(bits: u32) -> f32 {
    COORD_LIMIT * (1 << (bits - 2)) as f32
}

/// E8 lattice quantizer at a nominal `bits`/weight operating point (the
/// paper always uses 2; the knob scales the coordinate clamp).
#[derive(Clone, Debug)]
pub struct E8Lattice {
    pub bits: u32,
    /// Number of candidate scales in the search grid.
    grid: usize,
}

impl E8Lattice {
    pub fn new(bits: u32) -> E8Lattice {
        assert!((2..=4).contains(&bits), "E8 operating points: 2..=4 bits");
        E8Lattice { bits, grid: 24 }
    }

    fn coord_limit(&self) -> f32 {
        e8_coord_limit(self.bits)
    }

    /// Pick the global scale by grid search on (a subsample of) W.
    fn search_scale(&self, w: &Matrix) -> f32 {
        let data = w.as_slice();
        let n = data.len();
        if n == 0 {
            return 1.0;
        }
        // RMS of the weights sets the search window.
        let rms = {
            let s: f64 = data.iter().map(|&v| (v as f64) * (v as f64)).sum();
            ((s / n as f64).sqrt() as f32).max(1e-12)
        };
        // Subsample at most 4096 blocks for the search.
        let nblocks = n / 8;
        let stride = (nblocks / 4096).max(1);
        let lim = self.coord_limit();
        let mut best = (f64::INFINITY, rms);
        for gi in 0..self.grid {
            // Scales from 0.3·rms to 3·rms, geometric.
            let t = gi as f32 / (self.grid - 1) as f32;
            let s = rms * 0.3 * (10.0f32).powf(t);
            let mut err = 0f64;
            let mut b = 0;
            while (b + 1) * 8 <= n {
                if (b / 8) % stride == 0 || stride == 1 {
                    let blk = &data[b * 8..b * 8 + 8];
                    let mut scaled = [0f32; 8];
                    for (o, &v) in scaled.iter_mut().zip(blk) {
                        *o = v / s;
                    }
                    let q = nearest_e8_clamped(&scaled, lim);
                    for k in 0..8 {
                        let d = (scaled[k] - q[k]) * s;
                        err += (d as f64) * (d as f64);
                    }
                }
                b += 1;
            }
            if err < best.0 {
                best = (err, s);
            }
        }
        best.1
    }

    fn quantize_with_scale(&self, w: &Matrix, s: f32) -> Matrix {
        let (m, n) = w.shape();
        let lim = self.coord_limit();
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let src = w.row(i);
            let dst = out.row_mut(i);
            let mut j = 0;
            while j + 8 <= n {
                let mut blk = [0f32; 8];
                for k in 0..8 {
                    blk[k] = src[j + k] / s;
                }
                let q = nearest_e8_clamped(&blk, lim);
                for k in 0..8 {
                    dst[j + k] = q[k] * s;
                }
                j += 8;
            }
            // Tail (< 8): scalar rounding to half-integers (E8's 1-D shadow).
            for k in j..n {
                let v = src[k] / s;
                dst[k] = (v * 2.0).round().clamp(-2.0 * lim, 2.0 * lim) / 2.0 * s;
            }
        }
        out
    }
}

/// Exact nearest point of D8 (integer vectors with even coordinate sum).
fn nearest_d8(x: &[f32; 8]) -> [f32; 8] {
    let mut r = [0f32; 8];
    let mut sum = 0i64;
    let mut worst = 0usize;
    let mut worst_gap = -1f32;
    for k in 0..8 {
        r[k] = x[k].round();
        sum += r[k] as i64;
        let gap = (x[k] - r[k]).abs();
        // The coordinate whose rounding was most marginal is the cheapest
        // one to flip if the parity is wrong.
        if gap > worst_gap {
            worst_gap = gap;
            worst = k;
        }
    }
    if sum.rem_euclid(2) != 0 {
        // Flip the worst coordinate toward x to fix parity.
        let k = worst;
        r[k] += if x[k] >= r[k] { 1.0 } else { -1.0 };
    }
    r
}

/// Exact nearest point of E8 = D8 ∪ (D8 + ½·1).
pub fn nearest_e8(x: &[f32; 8]) -> [f32; 8] {
    let a = nearest_d8(x);
    let mut shifted = [0f32; 8];
    for k in 0..8 {
        shifted[k] = x[k] - 0.5;
    }
    let mut b = nearest_d8(&shifted);
    for v in b.iter_mut() {
        *v += 0.5;
    }
    let da: f32 = (0..8).map(|k| (x[k] - a[k]) * (x[k] - a[k])).sum();
    let db: f32 = (0..8).map(|k| (x[k] - b[k]) * (x[k] - b[k])).sum();
    if da <= db {
        a
    } else {
        b
    }
}

/// Nearest E8 point with coordinates clamped to ±lim (finite codebook).
fn nearest_e8_clamped(x: &[f32; 8], lim: f32) -> [f32; 8] {
    let mut c = *x;
    for v in c.iter_mut() {
        *v = v.clamp(-lim, lim);
    }
    let mut q = nearest_e8(&c);
    // Clamp can break parity at the boundary; accept the small bias there
    // (boundary points are rare after incoherence processing).
    for v in q.iter_mut() {
        *v = v.clamp(-lim, lim);
    }
    q
}

impl Quantizer for E8Lattice {
    fn name(&self) -> String {
        format!("e8-{}b", self.bits)
    }

    fn bits(&self) -> f64 {
        self.bits as f64
    }

    fn bits_with_overhead(&self, rows: usize, cols: usize) -> f64 {
        // One 32-bit global scale per matrix — negligible but counted.
        self.bits as f64 + 32.0 / (rows * cols) as f64
    }

    fn prepare<'a>(&'a self, w: &Matrix) -> Box<dyn Prepared + 'a> {
        let s = self.search_scale(w);
        Box::new(PreparedE8 { q: self.clone(), s })
    }

    fn feedback_block(&self) -> usize {
        8
    }
}

struct PreparedE8 {
    q: E8Lattice,
    s: f32,
}

impl Prepared for PreparedE8 {
    fn round_columns(&self, cols: &Matrix, _c0: usize) -> Matrix {
        self.q.quantize_with_scale(cols, self.s)
    }

    fn scale_metric(&self) -> f32 {
        self.s
    }

    fn encode(&self, deq: &Matrix) -> PackedMatrix {
        let (m, n) = deq.shape();
        let two_lim = (2.0 * self.q.coord_limit()) as i32;
        let cb = self.q.bits + 2; // half-unit coordinates need 2 extra bits
        let mut codes = vec![0u8; (m * n * cb as usize).div_ceil(8)];
        let mut bitpos = 0usize;
        for i in 0..m {
            for &v in deq.row(i) {
                // `v` is `q·s` for a half-integer lattice coordinate `q`
                // within ±lim; `2v/s` recovers the integer `2q` exactly and
                // decode recomputes the identical `(2q/2)·s` product.
                let c = ((v * 2.0 / self.s).round() as i32).clamp(-two_lim, two_lim);
                write_bits(&mut codes, bitpos, cb, (c + two_lim) as u32);
                bitpos += cb as usize;
            }
        }
        PackedMatrix {
            rows: m,
            cols: n,
            scheme: PackedScheme::E8 {
                bits: self.q.bits,
                scale: self.s,
                codes,
            },
            rotation: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Pcg64;

    fn in_e8(p: &[f32; 8]) -> bool {
        // All-integer with even sum, or all half-odd-integer with even sum·2.
        let frac0 = p.iter().all(|v| (v - v.round()).abs() < 1e-5);
        let frac_half = p.iter().all(|v| ((v - 0.5) - (v - 0.5).round()).abs() < 1e-5);
        if frac0 {
            let s: i64 = p.iter().map(|&v| v.round() as i64).sum();
            s.rem_euclid(2) == 0
        } else if frac_half {
            let s: i64 = p.iter().map(|&v| (v - 0.5).round() as i64).sum();
            // D8 + ½: underlying D8 point has even sum.
            s.rem_euclid(2) == 0
        } else {
            false
        }
    }

    #[test]
    fn nearest_returns_lattice_points() {
        testing::quick("e8-membership", |rng| {
            let mut x = [0f32; 8];
            for v in x.iter_mut() {
                *v = rng.normal_f32() * 2.0;
            }
            let p = nearest_e8(&x);
            assert!(in_e8(&p), "{p:?} not in E8 (input {x:?})");
        });
    }

    #[test]
    fn nearest_is_locally_optimal() {
        // No single ±1 coordinate move (staying in the lattice) can beat the
        // returned point — a strong spot-check of Conway–Sloane correctness.
        testing::quick("e8-local-opt", |rng| {
            let mut x = [0f32; 8];
            for v in x.iter_mut() {
                *v = rng.normal_f32() * 1.5;
            }
            let p = nearest_e8(&x);
            let d0: f32 = (0..8).map(|k| (x[k] - p[k]) * (x[k] - p[k])).sum();
            // E8 closest-vector is within squared distance 1 of any point
            // (covering radius = 1).
            assert!(d0 <= 1.0 + 1e-4, "covering radius violated: {d0}");
            // Moving any pair of coordinates by ±1 (D8-preserving moves):
            for a in 0..8 {
                for b in 0..8 {
                    if a == b {
                        continue;
                    }
                    for (da, db) in [(1.0, 1.0), (1.0, -1.0), (-1.0, 1.0), (-1.0, -1.0)] {
                        let mut q = p;
                        q[a] += da;
                        q[b] += db;
                        let d: f32 = (0..8).map(|k| (x[k] - q[k]) * (x[k] - q[k])).sum();
                        assert!(d >= d0 - 1e-4, "better neighbor found");
                    }
                }
            }
        });
    }

    #[test]
    fn exact_lattice_points_are_fixed() {
        let p = [1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]; // sum 2, in D8
        assert_eq!(nearest_e8(&p), p);
        let h = [0.5f32; 8]; // D8 + ½ with underlying zero vector
        assert_eq!(nearest_e8(&h), h);
    }

    #[test]
    fn quantize_error_scales_with_scale() {
        let mut rng = Pcg64::new(100, 1);
        let w = Matrix::randn(16, 64, 1.0, &mut rng);
        let q = E8Lattice::new(2);
        let out = q.quantize(&w);
        assert!(out.deq.is_finite());
        // Normalized error at 2 bits should be substantial but < 1.
        let rel = out.deq.sub(&w).frob_norm() / w.frob_norm();
        assert!(rel > 0.01 && rel < 0.9, "rel={rel}");
    }

    #[test]
    fn scale_responds_to_outliers() {
        // Planting big outliers inflates the searched scale; removing them
        // (what ODLRI effectively does) shrinks it — the Figure-2 mechanism.
        let mut rng = Pcg64::new(101, 1);
        let w = Matrix::randn(16, 64, 1.0, &mut rng);
        let mut spiky = w.clone();
        for j in 0..4 {
            spiky.scale_col(j, 40.0);
        }
        let q = E8Lattice::new(2);
        let s_plain = q.quantize(&w).scale;
        let s_spiky = q.quantize(&spiky).scale;
        assert!(s_spiky > s_plain * 1.5, "plain={s_plain} spiky={s_spiky}");
    }

    #[test]
    fn handles_non_multiple_of_8() {
        let mut rng = Pcg64::new(102, 1);
        let w = Matrix::randn(3, 13, 1.0, &mut rng);
        let out = E8Lattice::new(2).quantize(&w);
        assert_eq!(out.deq.shape(), (3, 13));
        assert!(out.deq.is_finite());
    }
}
