//! PJRT/XLA execution engine (feature `xla`).
//!
//! This is the only module that touches the `xla` binding crate. It is
//! compiled only with `--features xla`, which additionally requires adding
//! the vendored `xla` crate to `Cargo.toml` (not shipped in the offline
//! vendor set). Input validation happens in [`super::Runtime::exec`]; this
//! engine only compiles, caches, and runs executables.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use super::{Manifest, Value};

impl Value {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))?
            }
            Value::I32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape().map_err(|e| anyhow!("{e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Value::F32 {
                shape: dims,
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            }),
            xla::ElementType::S32 => Ok(Value::I32 {
                shape: dims,
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// PJRT client + artifact directory + executable cache.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtEngine {
    pub fn open(dir: &Path) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtEngine {
            client,
            dir: dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn executable(
        &self,
        manifest: &Manifest,
        name: &str,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self
            .cache
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(name)
        {
            return Ok(exe.clone());
        }
        let spec = manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = Arc::new(exe);
        self.cache
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn warm(&self, manifest: &Manifest, name: &str) -> Result<()> {
        self.executable(manifest, name).map(|_| ())
    }

    pub fn exec(&self, manifest: &Manifest, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let exe = self.executable(manifest, name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts.iter().map(Value::from_literal).collect()
    }
}
